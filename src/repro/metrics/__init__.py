from repro.metrics.scores import (dice_coefficient, dose_score, dvh_score,
                                  one_way_anova)

__all__ = ["dose_score", "dvh_score", "dice_coefficient", "one_way_anova"]
