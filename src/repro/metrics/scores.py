"""Evaluation metrics from the paper's three use cases.

* dose score / DVH score — OpenKBP Challenge metrics (§III.A.2): lower
  is better.  Dose score = masked voxel MAE; DVH score = mean |Δ| over
  DVH summary statistics (D99/D50/D1 per ROI) between predicted and true
  dose.
* DSC — Dice similarity coefficient (§III.B.2 / §III.C.2).
* one-way ANOVA — the robustness test used for Fig 15 (p = 0.9097),
  implemented from first principles on numpy (F statistic + p-value via
  the regularized incomplete beta function).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def dose_score(pred: np.ndarray, true: np.ndarray, mask: np.ndarray) -> float:
    """Masked voxel-wise MAE (OpenKBP dose score)."""
    m = mask.astype(bool)
    return float(np.abs(pred[m] - true[m]).mean())


def _dvh_stats(dose: np.ndarray, roi: np.ndarray) -> np.ndarray:
    vox = dose[roi.astype(bool)]
    if vox.size == 0:
        return np.zeros(3)
    return np.percentile(vox, [1, 50, 99])      # D99, D50, D1 (dose-at-volume)


def dvh_score(pred: np.ndarray, true: np.ndarray, rois: Sequence[np.ndarray]) -> float:
    """Mean |Δ| of DVH summary statistics over ROIs (OpenKBP DVH score)."""
    diffs: List[float] = []
    for roi in rois:
        d = np.abs(_dvh_stats(pred, roi) - _dvh_stats(true, roi))
        diffs.extend(d.tolist())
    return float(np.mean(diffs)) if diffs else 0.0


def dice_coefficient(pred_labels: np.ndarray, true_labels: np.ndarray,
                     num_classes: int, ignore_background: bool = True) -> float:
    """Mean DSC over (foreground) classes."""
    scores = []
    start = 1 if ignore_background else 0
    for c in range(start, num_classes):
        p = pred_labels == c
        t = true_labels == c
        denom = p.sum() + t.sum()
        if denom == 0:
            continue
        scores.append(2.0 * np.logical_and(p, t).sum() / denom)
    return float(np.mean(scores)) if scores else 1.0


# --- ANOVA (no scipy available) --------------------------------------------


def _betacf(a, b, x, itmax=200, eps=3e-9):
    am, bm, az = 1.0, 1.0, 1.0
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    bz = 1.0 - qab * x / qap
    for m in range(1, itmax + 1):
        em = float(m)
        tem = em + em
        d = em * (b - m) * x / ((qam + tem) * (a + tem))
        ap = az + d * am
        bp = bz + d * bm
        d = -(a + em) * (qab + em) * x / ((a + tem) * (qap + tem))
        app = ap + d * az
        bpp = bp + d * bz
        aold = az
        am, bm = ap / bpp, bp / bpp
        az, bz = app / bpp, 1.0
        if abs(az - aold) < eps * abs(az):
            return az
    return az


def _betainc(a, b, x):
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0:
        return 0.0
    if x >= 1:
        return 1.0
    from math import exp, lgamma, log
    lbeta = lgamma(a + b) - lgamma(a) - lgamma(b) + a * log(x) + b * log(1 - x)
    bt = exp(lbeta)
    if x < (a + 1) / (a + b + 2):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1 - x) / b


def one_way_anova(groups: Sequence[np.ndarray]):
    """F statistic and p-value for k independent groups (Fig 15's test)."""
    groups = [np.asarray(g, dtype=np.float64) for g in groups if len(g) > 0]
    k = len(groups)
    n = sum(len(g) for g in groups)
    grand = np.concatenate(groups).mean()
    ss_between = sum(len(g) * (g.mean() - grand) ** 2 for g in groups)
    ss_within = sum(((g - g.mean()) ** 2).sum() for g in groups)
    df1, df2 = k - 1, n - k
    if df1 <= 0 or df2 <= 0 or ss_within == 0:
        return 0.0, 1.0
    f = (ss_between / df1) / (ss_within / df2)
    # p = P(F_{df1,df2} > f) = I_{df2/(df2+df1 f)}(df2/2, df1/2)
    p = _betainc(df2 / 2.0, df1 / 2.0, df2 / (df2 + df1 * f))
    return float(f), float(min(max(p, 0.0), 1.0))
