"""Site partitioning — IID and non-IID splits (paper §III.A.1, Figs 6/10/13).

The OpenKBP dataset carries no site metadata, so the paper *simulates*
federation by partitioning cases across 8 sites: evenly (IID) or with a
skewed case-count distribution (non-IID).  BraTS'21 and PanSeg carry real
site identifiers; their per-site case counts (Figs 10/13) are encoded
here so the benchmarks reproduce the same imbalance.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

# Paper Fig 6: 200 training / 40 validation cases over 8 sites.
OPENKBP_IID_TRAIN = (25,) * 8
OPENKBP_IID_VAL = (5,) * 8
# non-IID: skewed counts (largest site 48, smallest 12 — §III.A.4 cites
# site 0 = 48 and site 7 = 12 explicitly; interior sites interpolated).
OPENKBP_NONIID_TRAIN = (48, 36, 30, 24, 20, 16, 14, 12)
OPENKBP_NONIID_VAL = (10, 7, 6, 5, 4, 3, 3, 2)

# BraTS 2021 (Fig 10): 227 cases over 8 real sites, ~70/10/20 split per site.
BRATS_SITE_CASES = (52, 44, 35, 28, 24, 18, 14, 12)
# PanSeg (Fig 13): 384 T1 MRI over 5 institutions.
PANSEG_SITE_CASES = (110, 92, 74, 60, 48)

assert sum(OPENKBP_NONIID_TRAIN) == 200
assert sum(OPENKBP_IID_TRAIN) == 200
assert sum(BRATS_SITE_CASES) == 227
assert sum(PANSEG_SITE_CASES) == 384


def partition_indices(num_cases: int, site_counts: Sequence[int],
                      seed: int = 0) -> List[np.ndarray]:
    """Randomly partition ``num_cases`` indices into per-site groups."""
    assert sum(site_counts) <= num_cases, (sum(site_counts), num_cases)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_cases)
    out, ofs = [], 0
    for c in site_counts:
        out.append(np.sort(perm[ofs: ofs + c]))
        ofs += c
    return out


def dirichlet_label_partition(labels: np.ndarray, num_sites: int,
                              alpha: float = 0.5, seed: int = 0) -> List[np.ndarray]:
    """Label-skew non-IID partitioning (Dirichlet), the standard FL
    heterogeneity protocol for classification-style data."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    site_idx: List[list] = [[] for _ in range(num_sites)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_sites)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for s, part in enumerate(np.split(idx, cuts)):
            site_idx[s].extend(part.tolist())
    return [np.sort(np.array(s, dtype=np.int64)) for s in site_idx]
