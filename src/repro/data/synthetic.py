"""Synthetic data generators with controllable inter-site heterogeneity.

Real OpenKBP/BraTS/PanSeg volumes are not redistributable in this
environment, so the pipelines generate *learnable* synthetic tasks with
matched shapes and an explicit non-IID knob:

* ``TokenTaskGenerator`` — language-model streams from a site-specific
  mixture of markov generators over the vocabulary.  ``heterogeneity=0``
  gives IID sites; larger values bias each site toward its own token
  sub-range (the LM analogue of inter-institution distribution shift).

* ``DoseTaskGenerator`` — OpenKBP-like volumes: a CT-like background,
  spherical PTV + OAR masks, and a dose field computed as an analytic
  function of the geometry (so the mapping is learnable).  Site
  heterogeneity shifts organ geometry statistics per site.

* ``SegTaskGenerator``  — BraTS/PanSeg-like: multi-channel volumes with
  blob-shaped foreground classes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Token streams (for the 10 assigned LLM-family architectures)
# ---------------------------------------------------------------------------


@dataclass
class TokenTaskGenerator:
    vocab_size: int
    num_sites: int
    heterogeneity: float = 0.0          # 0 = IID
    num_codebooks: int = 1
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # each site draws from a site-biased unigram prior + shared bigram rule
        self.site_offsets = rng.integers(0, self.vocab_size, self.num_sites)
        self.mix_w = rng.normal(size=(8,))

    def _site_rng(self, site: int, step: int):
        return np.random.default_rng(
            (self.seed * 1000003 + site * 10007 + step) % (2 ** 63))

    def sample(self, site: int, step: int, batch: int, seq_len: int) -> np.ndarray:
        """Markov-ish integer streams: t_{i+1} = f(t_i) + noise, where the
        noise distribution is site-biased under heterogeneity."""
        rng = self._site_rng(site, step)
        shape = (batch, seq_len, self.num_codebooks) if self.num_codebooks > 1 \
            else (batch, seq_len)
        v = self.vocab_size
        base = rng.integers(0, v, (shape[0],) + shape[2:] if len(shape) > 2 else (shape[0],))
        toks = np.zeros(shape, dtype=np.int32)
        cur = base
        bias = int(self.site_offsets[site] * self.heterogeneity)
        # narrow noise keeps the bigram task learnable (entropy ~ln(v/8));
        # heterogeneity shifts each site's transition BIAS, not the noise
        width = max(v // 8, 8)
        for i in range(seq_len):
            drift = (cur * 31 + 17) % v
            noise = rng.integers(0, width, drift.shape)
            cur = (drift + noise + bias) % v
            if len(shape) > 2:
                toks[:, i, :] = cur
            else:
                toks[:, i] = cur
        return toks

    def stacked_batches(self, step: int, local_steps: int, per_site_batch: int,
                        seq_len: int) -> Dict[str, np.ndarray]:
        """[S, K, B, L(, C)] token batches for one FL round."""
        out = np.stack([
            np.stack([self.sample(s, step * local_steps + k, per_site_batch, seq_len)
                      for k in range(local_steps)])
            for s in range(self.num_sites)])
        return {"tokens": out}

    def traced_stacked_batches(self, key, local_steps: int,
                               per_site_batch: int, seq_len: int):
        """Traced [S, K, B, L(, C)] batches from a jax PRNG key — the
        compiled round engine's on-device data path: the same markov
        transition family and per-site heterogeneity bias as
        :meth:`sample`, but produced inside the jitted scan so batch
        generation never touches the host.  Streams differ from the
        numpy generators (cross-path parity needs the host generators).
        """
        import jax
        import jax.numpy as jnp
        v = self.vocab_size
        width = max(v // 8, 8)
        shape = (self.num_sites, local_steps, per_site_batch)
        if self.num_codebooks > 1:
            shape = shape + (self.num_codebooks,)
        bias = (self.site_offsets * self.heterogeneity).astype(np.int32)
        bias = jnp.asarray(bias).reshape((-1,) + (1,) * (len(shape) - 1))
        k_base, k_steps = jax.random.split(key)
        cur = jax.random.randint(k_base, shape, 0, v, dtype=jnp.int32)

        def step(cur, k):
            drift = (cur * 31 + 17) % v
            noise = jax.random.randint(k, cur.shape, 0, width, dtype=jnp.int32)
            cur = (drift + noise + bias) % v
            return cur, cur

        _, toks = jax.lax.scan(step, cur, jax.random.split(k_steps, seq_len))
        # [L, S, K, B(, C)] → [S, K, B, L(, C)]
        return {"tokens": jnp.moveaxis(toks, 0, 3)}


# ---------------------------------------------------------------------------
# Volumetric tasks (SA-Net)
# ---------------------------------------------------------------------------


def _sphere_mask(shape, center, radius):
    zz, yy, xx = np.meshgrid(*[np.arange(s) for s in shape], indexing="ij")
    d2 = ((zz - center[0]) ** 2 + (yy - center[1]) ** 2 + (xx - center[2]) ** 2)
    return (d2 <= radius ** 2).astype(np.float32)


@dataclass
class DoseTaskGenerator:
    """OpenKBP-like: CT + PTV + OAR masks -> analytic dose field.

    ``site_pools`` emulates the paper's non-IID protocol (case-count
    imbalance over a common distribution): smaller sites resample from
    fewer distinct cases, so Individual training on them overfits —
    Fig 9's size-vs-accuracy effect.
    """

    volume: Tuple[int, int, int] = (32, 32, 32)
    num_oars: int = 2
    num_sites: int = 8
    heterogeneity: float = 0.0
    seed: int = 0
    site_pools: Optional[Tuple[int, ...]] = None

    @property
    def in_channels(self) -> int:
        return 1 + 1 + self.num_oars        # CT + PTV + OARs

    def sample(self, site: int, step: int, batch: int) -> Dict[str, np.ndarray]:
        if self.site_pools is not None:
            step = step % max(self.site_pools[site], 1)
        rng = np.random.default_rng(self.seed * 7919 + site * 101 + step)
        d, h, w = self.volume
        vol = np.zeros((batch, d, h, w, self.in_channels), np.float32)
        dose = np.zeros((batch, d, h, w, 1), np.float32)
        mask = np.zeros((batch, d, h, w, 1), np.float32)
        # site-dependent geometry statistics = non-IID heterogeneity
        shift = self.heterogeneity * (site - self.num_sites / 2) / self.num_sites
        for b in range(batch):
            ct = rng.normal(0.0, 0.3, (d, h, w)).astype(np.float32)
            body = _sphere_mask((d, h, w), (d / 2, h / 2, w / 2), 0.45 * d)
            ct = ct * body
            # wide geometric variability: data QUANTITY must matter for the
            # paper's size-vs-accuracy effect to be observable
            center = np.array([d, h, w]) * (0.5 + shift + rng.uniform(-0.14, 0.14, 3))
            r_ptv = d * rng.uniform(0.06, 0.18)
            ptv = _sphere_mask((d, h, w), center, r_ptv)
            oars = []
            for k in range(self.num_oars):
                oc = center + np.array([0, (k + 1) * r_ptv * 2.2, 0]) \
                    * (1 if k % 2 == 0 else -1)
                oars.append(_sphere_mask((d, h, w), oc, r_ptv * 0.8))
            # analytic dose: prescription inside PTV, exponential falloff,
            # OAR sparing notches — a deterministic function of the masks
            zz, yy, xx = np.meshgrid(*[np.arange(s) for s in (d, h, w)], indexing="ij")
            dist = np.sqrt((zz - center[0]) ** 2 + (yy - center[1]) ** 2
                           + (xx - center[2]) ** 2)
            field = 70.0 * np.exp(-np.maximum(dist - r_ptv, 0) / (0.15 * d))
            for o in oars:
                field = field * (1.0 - 0.35 * o)
            field = field * body
            vol[b, ..., 0] = ct
            vol[b, ..., 1] = ptv
            for k, o in enumerate(oars):
                vol[b, ..., 2 + k] = o
            dose[b, ..., 0] = field / 70.0
            mask[b, ..., 0] = body
        return {"volume": vol, "dose": dose, "mask": mask}

    def stacked_batches(self, step: int, local_steps: int, per_site_batch: int):
        def one(s, k):
            return self.sample(s, step * local_steps + k, per_site_batch)
        sites = []
        for s in range(self.num_sites):
            ks = [one(s, k) for k in range(local_steps)]
            sites.append({k: np.stack([x[k] for x in ks]) for k in ks[0]})
        return {k: np.stack([s[k] for s in sites]) for k in sites[0]}

    def traced_stacked_batches(self, key, local_steps: int,
                               per_site_batch: int):
        """Traced [S, K, B, …] dose batches from a jax PRNG key — the
        compiled round engine's on-device path for the SA-Net dose task:
        the same geometry family, analytic dose law and per-site
        heterogeneity shift as :meth:`sample`, produced inside the jitted
        scan (streams differ from the numpy generators, like the token
        generator's traced twin; ``site_pools`` case recycling indexes by
        host step and stays host-side)."""
        import jax
        import jax.numpy as jnp
        d, h, w = self.volume
        s_, k_, b_ = self.num_sites, local_steps, per_site_batch
        grid = jnp.stack(jnp.meshgrid(jnp.arange(d), jnp.arange(h),
                                      jnp.arange(w), indexing="ij")
                         ).astype(jnp.float32)               # [3, d, h, w]
        dims = jnp.asarray([d, h, w], jnp.float32)

        def sphere(center, radius):
            d2 = jnp.sum((grid - center[:, None, None, None]) ** 2, axis=0)
            return (d2 <= radius * radius).astype(jnp.float32)

        body = sphere(dims / 2, 0.45 * d)
        shifts = (self.heterogeneity
                  * (jnp.arange(s_) - s_ / 2) / s_).astype(jnp.float32)

        def case(k, shift):
            k_ct, k_c, k_r = jax.random.split(k, 3)
            ct = 0.3 * jax.random.normal(k_ct, (d, h, w)) * body
            center = dims * (0.5 + shift
                             + jax.random.uniform(k_c, (3,), minval=-0.14,
                                                  maxval=0.14))
            r_ptv = d * jax.random.uniform(k_r, minval=0.06, maxval=0.18)
            ptv = sphere(center, r_ptv)
            oars = [sphere(center + jnp.asarray([0.0, (j + 1) * 2.2, 0.0])
                           * r_ptv * (1.0 if j % 2 == 0 else -1.0),
                           r_ptv * 0.8)
                    for j in range(self.num_oars)]
            dist = jnp.sqrt(jnp.sum((grid - center[:, None, None, None]) ** 2,
                                    axis=0))
            field = 70.0 * jnp.exp(-jnp.maximum(dist - r_ptv, 0.0)
                                   / (0.15 * d))
            for o in oars:
                field = field * (1.0 - 0.35 * o)
            field = field * body
            return {"volume": jnp.stack([ct, ptv] + oars, axis=-1),
                    "dose": (field / 70.0)[..., None],
                    "mask": body[..., None]}

        keys = jax.random.split(key, s_ * k_ * b_).reshape(
            (s_, k_, b_) + jax.random.split(key, 2).shape[1:])
        f = jax.vmap(jax.vmap(jax.vmap(case, in_axes=(0, None)),
                              in_axes=(0, None)), in_axes=(0, 0))
        return f(keys, shifts)


@dataclass
class SegTaskGenerator:
    """BraTS/PanSeg-like: channels -> voxel labels (blob classes).

    ``site_pools`` limits how many distinct cases a site owns (the paper's
    non-IID protocol is case-COUNT imbalance over an otherwise common
    distribution): smaller sites recycle a smaller pool.
    """

    volume: Tuple[int, int, int] = (32, 32, 32)
    in_channels: int = 4
    num_classes: int = 4
    num_sites: int = 8
    heterogeneity: float = 0.0
    seed: int = 0
    site_pools: Optional[Tuple[int, ...]] = None

    def sample(self, site: int, step: int, batch: int) -> Dict[str, np.ndarray]:
        if self.site_pools is not None:
            step = step % max(self.site_pools[site], 1)
        rng = np.random.default_rng(self.seed * 104729 + site * 211 + step)
        d, h, w = self.volume
        vol = np.zeros((batch, d, h, w, self.in_channels), np.float32)
        labels = np.zeros((batch, d, h, w), np.int32)
        shift = self.heterogeneity * (site - self.num_sites / 2) / self.num_sites
        for b in range(batch):
            lab = np.zeros((d, h, w), np.int32)
            for c in range(1, self.num_classes):
                center = np.array([d, h, w]) * (0.5 + shift + rng.uniform(-0.15, 0.15, 3))
                r = d * rng.uniform(0.10, 0.20) / c
                lab = np.where(_sphere_mask((d, h, w), center, r) > 0, c, lab)
            base = rng.normal(0, 0.15, (d, h, w, self.in_channels)).astype(np.float32)
            for ch in range(self.in_channels):
                base[..., ch] += lab * (0.5 + 0.25 * ch)   # strong class signal
            vol[b] = base
            labels[b] = lab
        return {"volume": vol, "labels": labels}

    def stacked_batches(self, step: int, local_steps: int, per_site_batch: int):
        sites = []
        for s in range(self.num_sites):
            ks = [self.sample(s, step * local_steps + k, per_site_batch)
                  for k in range(local_steps)]
            sites.append({k: np.stack([x[k] for x in ks]) for k in ks[0]})
        return {k: np.stack([s[k] for s in sites]) for k in sites[0]}

    def traced_stacked_batches(self, key, local_steps: int,
                               per_site_batch: int):
        """Traced [S, K, B, …] segmentation batches from a jax PRNG key —
        same blob-class law and heterogeneity shift as :meth:`sample`,
        on-device (streams differ from numpy; ``site_pools`` stays
        host-side)."""
        import jax
        import jax.numpy as jnp
        d, h, w = self.volume
        s_, k_, b_ = self.num_sites, local_steps, per_site_batch
        grid = jnp.stack(jnp.meshgrid(jnp.arange(d), jnp.arange(h),
                                      jnp.arange(w), indexing="ij")
                         ).astype(jnp.float32)               # [3, d, h, w]
        dims = jnp.asarray([d, h, w], jnp.float32)
        shifts = (self.heterogeneity
                  * (jnp.arange(s_) - s_ / 2) / s_).astype(jnp.float32)
        ch_gain = jnp.asarray([0.5 + 0.25 * c
                               for c in range(self.in_channels)], jnp.float32)

        def case(k, shift):
            k_noise, *k_cls = jax.random.split(k, self.num_classes + 1)
            lab = jnp.zeros((d, h, w), jnp.int32)
            for c in range(1, self.num_classes):
                k_c, k_r = jax.random.split(k_cls[c - 1])
                center = dims * (0.5 + shift
                                 + jax.random.uniform(k_c, (3,), minval=-0.15,
                                                      maxval=0.15))
                r = d * jax.random.uniform(k_r, minval=0.10, maxval=0.20) / c
                d2 = jnp.sum((grid - center[:, None, None, None]) ** 2,
                             axis=0)
                lab = jnp.where(d2 <= r * r, c, lab)
            base = 0.15 * jax.random.normal(k_noise,
                                            (d, h, w, self.in_channels))
            base = base + lab[..., None].astype(jnp.float32) * ch_gain
            return {"volume": base.astype(jnp.float32), "labels": lab}

        keys = jax.random.split(key, s_ * k_ * b_).reshape(
            (s_, k_, b_) + jax.random.split(key, 2).shape[1:])
        f = jax.vmap(jax.vmap(jax.vmap(case, in_axes=(0, None)),
                              in_axes=(0, None)), in_axes=(0, 0))
        return f(keys, shifts)
