"""One ``FederatedJob`` API — the paper's unified communication stack.

The headline FedKBP+ claim is that participants run the *same* FL
scripts whether colocated on one workstation or spread across machines.
This module is that surface: a declarative job object that owns task
construction (tokens/dose/seg), strategy, dropout schedule,
checkpointing and metrics, and executes rounds through a pluggable
:class:`Transport`:

  * :class:`StackedTransport` — the vmapped/jitted single-process
    simulator (fast default; every strategy incl. GCML gossip).
  * :class:`ThreadTransport`  — every site is a real ``Peer`` with its
    own server socket, driven by an in-process thread; rounds go through
    ``AggregationServer`` / ``CoordinationServer`` over real TCP.
  * :class:`TcpTransport`     — same wire protocol, but each site is its
    own OS process (the paper's deployment shape).

Three more seams sit on top of the transport seam:

  * the **topology seam** (:mod:`repro.core.topology`):
    ``topology="pods:K"`` turns the flat star into a two-tier pod
    federation — per-pod partial aggregation, then a cross-pod combine.
    On the stacked simulator that is a segment-reduce by pod id inside
    the compiled round (``AggregationEngine.aggregate_pods``); on the
    socket transports it is a real server hierarchy
    (:mod:`repro.comms.pods`): one ``AggregationServer`` per pod plus a
    root combiner that pod-leader relays re-upload partials to, with
    ``result.comm`` splitting intra-pod vs cross-pod wire bytes.
    ``pod_dropout=N`` churns whole pods (Algorithm 2 at the pod tier);
  * the **scheduler seam** (:mod:`repro.core.session`): ``SyncScheduler``
    keeps barrier rounds, ``BufferedScheduler`` gives FedBuff-style
    buffered-async aggregation — on the stacked simulator *and* on the
    TCP server, since both fold uploads through the same
    ``StreamingAccumulator``; under a pods topology the choice applies
    *per tier* (``Topology(intra_scheduler=…, inter_scheduler=…)``);
  * the **compression seam** (:mod:`repro.comms.compression`):
    ``compression="int8" | "fp8" | "topk-sparse"`` quantizes each site's
    upload as a per-chunk-scaled delta against the global it last
    pulled, with a client-side error-feedback residual carried across
    rounds; payloads decode in ``AggregationServer._handle("upload")``
    (and at gossip receivers) before the accumulator fold, so one codec
    implementation serves all three transports at once.

    job = FederatedJob(task=TaskConfig(kind="tokens", arch="qwen3-8b",
                                       sites=4, heterogeneity=0.5),
                       strategy="fedavg", rounds=12)
    result = job.run()                        # local, one process
    result = job.replace(transport="tcp").run()   # real multi-process TCP
    result = job.replace(compression="int8").run()  # ~4x smaller uploads

``job.run(rounds)`` is the only round loop in the codebase — examples,
the train CLI and the benchmarks all drive it; ``result.comm`` reports
the run's upload/download byte volume (real wire bytes on the socket
transports, simulated payload bytes on the stacked simulator).

The per-round lifecycle (pull → local steps → upload → fold →
broadcast), the stale-upload rejection and staleness-discount rules,
and how the seams compose are documented in ``docs/architecture.md``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.compression import (KEEP_GLOBALS_DEFAULT, Codec,
                                     DownlinkCompressor, UploadCompressor,
                                     decode_download, decode_upload,
                                     resolve_codec, tree_payload_nbytes)
from repro.comms.transport import WireConfig
from repro.configs.base import FederationConfig, MeshConfig
from repro.core import federation as F
from repro.core import stacking
from repro.core.adversary import parse_adversary
from repro.core.agg_engine import (StreamingAccumulator, parse_aggregator,
                                   per_site_nbytes)
from repro.core.sampling import (ClientSampler, compose_participation,
                                 resolve_sampler)
from repro.core.session import (BufferedScheduler, JobResult, RoundRecorder,
                                RoundScheduler, SyncScheduler,
                                availability_masks, check_engine_tag,
                                check_privacy_tag, resolve_scheduler)
from repro.core.strategies import base as strat_base
from repro.core.topology import FLAT, Topology, resolve_topology
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Task construction (tokens / dose / seg) — declarative and picklable, so
# TcpTransport site processes can rebuild the exact task from the job alone.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TaskConfig:
    """What the federation trains on.  ``kind`` ∈ {tokens, dose, seg}."""

    kind: str = "tokens"
    sites: int = 4
    batch: int = 4                      # per-site batch per local step
    heterogeneity: float = 0.0          # non-IID knob (0 = IID)
    seed: int = 0                       # data seed (independent of job seed)
    # -- tokens ------------------------------------------------------------
    arch: str = "smollm-135m"
    reduced: bool = True
    seq: int = 64
    # -- volumetric (dose / seg) -------------------------------------------
    volume: Tuple[int, int, int] = (16, 16, 16)
    num_oars: int = 2                   # dose: OAR channels
    in_channels: int = 2                # seg: input channels
    num_classes: int = 3                # seg: label classes
    base_filters: int = 8
    num_levels: int = 2
    site_pools: Optional[Tuple[int, ...]] = None   # per-site distinct cases

    def model_config(self):
        """The model config this task trains (ModelConfig or SANetConfig)."""
        from repro.models.sanet import SANetConfig
        if self.kind == "tokens":
            from repro.configs.registry import get_arch
            arch = get_arch(self.arch)
            return arch.reduced() if self.reduced else arch.CONFIG
        if self.kind == "dose":
            return SANetConfig(in_channels=2 + self.num_oars, out_channels=1,
                               base_filters=self.base_filters,
                               num_levels=self.num_levels, task="dose")
        if self.kind == "seg":
            return SANetConfig(in_channels=self.in_channels,
                               out_channels=self.num_classes,
                               base_filters=self.base_filters,
                               num_levels=self.num_levels, task="segmentation")
        raise ValueError(f"unknown task kind {self.kind!r}")

    def build(self) -> "TaskBundle":
        if self.kind == "tokens":
            return _build_token_task(self)
        if self.kind in ("dose", "seg"):
            return _build_volume_task(self)
        raise ValueError(f"unknown task kind {self.kind!r}")


@dataclass
class TaskBundle:
    """Built task: loss/init fns + batch samplers over the generator."""

    task: TaskConfig
    loss_fn: Callable
    logits_fn: Optional[Callable]
    init_fn: Callable
    model_cfg: Any
    sample: Callable[[int, int], Dict[str, np.ndarray]]   # (site, step) -> [B,…]
    stacked: Callable[[int, int], Dict[str, np.ndarray]]  # (round, K) -> [S,K,B,…]
    # traced (key, K, B) -> [S,K,B,…] batch sampler for the compiled
    # round engine's on-device data path (token AND dose/seg tasks);
    # None when no traced generator applies (site_pools case recycling
    # is host-only)
    traced_stacked: Optional[Callable] = None

    @staticmethod
    def pooled_view(b: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Concatenate the site axis into one site's batch
        ([S, K, B, …] → [1, K, S·B, …]) — the paper's Pooled upper
        baseline.  The ONE definition of the pooled layout, shared by
        the per-round loop and the scan engine's chunk builder."""
        return {k: np.reshape(np.swapaxes(x, 0, 1),
                              (1, x.shape[1], -1) + x.shape[3:])
                for k, x in b.items()}

    def round_batches(self, round_index: int, local_steps: int,
                      pooled: bool = False):
        """[S, K, B, …] batches for one round (K = local steps); with
        ``pooled``, the :meth:`pooled_view` of them."""
        b = self.stacked(round_index, local_steps)
        if pooled:
            b = self.pooled_view(b)
        return jax.tree.map(jnp.asarray, b)

    def site_batches(self, site: int, round_index: int, local_steps: int):
        """[1, K, B, …] — one site's slice of :meth:`round_batches`.  The
        sample indexing (``round·K + k``) must mirror the generators'
        ``stacked_batches``; transport parity depends on it, and
        regenerating only this site's data keeps workers O(B) instead of
        O(S·B) per round."""
        ks = [self.sample(site, round_index * local_steps + k)
              for k in range(local_steps)]
        b = {k: np.stack([x[k] for x in ks])[None] for k in ks[0]}
        return jax.tree.map(jnp.asarray, b)


def _build_token_task(task: TaskConfig) -> TaskBundle:
    from repro.data.synthetic import TokenTaskGenerator
    from repro.models import transformer as T
    cfg = task.model_config()
    gen = TokenTaskGenerator(vocab_size=cfg.vocab_size, num_sites=task.sites,
                             heterogeneity=task.heterogeneity,
                             num_codebooks=cfg.num_codebooks, seed=task.seed)

    def logits_fn(params, batch):
        logits, _ = T.forward(params, batch["tokens"], cfg)
        return logits[:, :-1], batch["tokens"][:, 1:]

    return TaskBundle(
        task=task,
        loss_fn=lambda p, b: T.next_token_loss(p, b, cfg),
        logits_fn=logits_fn,
        init_fn=lambda k: T.init(k, cfg),
        model_cfg=cfg,
        sample=lambda site, step: {
            "tokens": gen.sample(site, step, task.batch, task.seq)},
        stacked=lambda rnd, k: gen.stacked_batches(rnd, k, task.batch,
                                                   task.seq),
        traced_stacked=lambda key, k, b: gen.traced_stacked_batches(
            key, k, b, task.seq))


def _build_volume_task(task: TaskConfig) -> TaskBundle:
    from repro.data.synthetic import DoseTaskGenerator, SegTaskGenerator
    from repro.models import sanet as sanet_mod
    scfg = task.model_config()
    if task.kind == "dose":
        gen = DoseTaskGenerator(volume=task.volume, num_oars=task.num_oars,
                                num_sites=task.sites,
                                heterogeneity=task.heterogeneity,
                                seed=task.seed, site_pools=task.site_pools)
        loss_fn = lambda p, b: sanet_mod.dose_loss(p, b, scfg)

        def logits_fn(params, batch):
            pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
            # dose regression viewed as binary high/low for DCML regions
            logits = jnp.concatenate([pred, -pred], axis=-1)
            labels = (batch["dose"][..., 0] > 0.5).astype(jnp.int32)
            return logits, labels
    else:
        gen = SegTaskGenerator(volume=task.volume, in_channels=task.in_channels,
                               num_classes=task.num_classes,
                               num_sites=task.sites,
                               heterogeneity=task.heterogeneity,
                               seed=task.seed, site_pools=task.site_pools)
        loss_fn = lambda p, b: sanet_mod.segmentation_loss(p, b, scfg)

        def logits_fn(params, batch):
            pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
            return pred, batch["labels"]

    return TaskBundle(
        task=task, loss_fn=loss_fn, logits_fn=logits_fn,
        init_fn=lambda k: sanet_mod.sanet_init(k, scfg), model_cfg=scfg,
        sample=lambda site, step: gen.sample(site, step, task.batch),
        stacked=lambda rnd, k: gen.stacked_batches(rnd, k, task.batch),
        # jnp generator: device_data=True covers the SA-Net tasks too;
        # site_pools recycling indexes by host step, so it stays host-side
        traced_stacked=(gen.traced_stacked_batches
                        if task.site_pools is None else None))


# ---------------------------------------------------------------------------
# The job
# ---------------------------------------------------------------------------


@dataclass
class FederatedJob:
    """A fully-specified federated run; ``run()`` executes it through the
    configured transport and scheduler.  Declarative and picklable — the
    TCP transport ships the job itself to every site process."""

    task: TaskConfig = field(default_factory=TaskConfig)
    strategy: str = "fedavg"
    rounds: int = 10
    local_steps: int = 1
    # optimizer / strategy hyper-parameters
    lr: float = 1e-3
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    prox_mu: float = 0.01
    gcml_lambda: float = 0.5
    gcml_contrast_beta: float = 1.0
    dcml_lr: Optional[float] = None     # default: lr
    # Algorithm-2 dropout schedule
    max_dropout: int = 0
    dropout_scenario: str = "disconnect"
    case_counts: Optional[Tuple[int, ...]] = None   # Eq. 1 m_i (None=uniform)
    # cross-device client sampling (repro.core.sampling): "none" |
    # "uniform:K" | "poisson:q" — which sites are *scheduled* each round,
    # intersected with the Algorithm-2 availability masks; Eq. 1 weights
    # are 1/π inclusion-probability reweighted (Horvitz–Thompson) so the
    # sampled aggregate is unbiased for the dense one
    sample: Union[str, "ClientSampler"] = "none"
    # execution
    transport: Union[str, "Transport"] = "stacked"
    scheduler: Union[str, RoundScheduler] = "sync"
    # federation topology: "flat" (one star) or "pods:K" / a Topology —
    # two tiers of aggregation (per-pod partials → cross-pod combine)
    # honored by every transport; see repro.core.topology
    topology: Union[str, Topology] = "flat"
    pod_dropout: int = 0                # Algorithm-2 churn at the pod tier
    compression: Union[str, Codec] = "none"   # upload codec (comms seam)
    error_feedback: bool = True         # carry quantization residual
    # download codec: the server keeps per-site error-feedback residual
    # references and broadcasts each install as a quantized delta against
    # that site's last-acknowledged global (dense bootstrap for new or
    # evicted references — same rejoin rule as uploads).  fedavg/fedprox
    # sync rounds only; secure_agg downloads stay dense (the masked sum
    # is the only thing the server may materialize).
    down_compression: Union[str, Codec] = "none"
    # privacy tier (repro.privacy).  DP-SGD is ON iff dp_clip > 0:
    # per-site/per-example gradient clipping + Gaussian noise inside
    # every site update (all transports, compiled into the scan engine),
    # with the Rényi accountant's (ε, δ) on ``result.privacy``.
    # secure_agg=True masks uploads pairwise in fixed-point int64 so the
    # aggregation point only ever sees their sum (socket transports,
    # sync schedulers, compression="none"; dropped/lease-expired sites
    # are repaired by seed recovery).
    dp_clip: float = 0.0
    dp_noise_multiplier: float = 0.0
    dp_delta: float = 1e-5
    dp_mode: str = "per-site"           # clipping unit: per-site | per-example
    secure_agg: bool = False
    # Byzantine-robustness tier (repro.core.adversary + the robust
    # combine seam on the aggregation engine).  ``aggregator`` selects
    # the site→global rule applied to the round's active uploads:
    # "fedavg" (Eq. 1 weighted mean) | "trimmed:f" (coordinate-wise
    # trimmed mean, f per side) | "median" | "krum:f" (pick the upload
    # with the smallest distance score) | "normclip:c" (per-upload L2
    # clip to c before the weighted mean).  ``adversary`` injects a
    # deterministic fault plan — "sign_flip:f" | "label_flip:f" |
    # "scale:c:f" | "noise:s:f" — where f seeded sites perturb what they
    # expose to aggregation, bit-identically on the stacked engines and
    # the socket workers.  ``round_deadline_s`` bounds the socket
    # transports' sync barrier (after the deadline the round proceeds
    # with whoever folded; stragglers are acked stale).
    # ``max_upload_norm`` rejects norm-outlier uploads at the server
    # with a typed ack (non-finite uploads are always rejected).
    aggregator: str = "fedavg"
    adversary: Optional[str] = None
    round_deadline_s: Optional[float] = None
    max_upload_norm: Optional[float] = None
    seed: int = 0                       # init + dropout + pairing seed
    io_timeout: float = 120.0           # socket-transport exchange bound
    # deployable wire (socket transports): hello auth secret, optional
    # TLS, chunked streaming threshold, retry/backoff, fault injection —
    # see repro.comms.transport.WireConfig
    wire: WireConfig = field(default_factory=WireConfig)
    # elastic membership (socket transports): sites lease their seat and
    # renew by heartbeat; a site silent for lease_ttl seconds is expired
    # into the round's Algorithm-2 dropout accounting instead of
    # deadlocking the barrier.  None = fixed roster (the paper's setup).
    lease_ttl: Optional[float] = None
    # stacked-transport round engine (repro.core.round_engine): "auto"
    # compiles chunks of rounds into one donated lax.scan and falls back
    # to the per-round loop where the scan can't replicate semantics;
    # "scan" insists (raises on unsupported combos); "loop" forces the
    # retired per-round driver (the parity oracle)
    round_engine: str = "auto"
    chunk_rounds: Optional[int] = None  # rounds per compiled chunk (None=auto)
    device_data: bool = False           # generate batches on-device (tokens)
    # stacked transport: partition the [S, N] engine buffer and the
    # vmapped site-update axis across a device mesh (shard_map), and
    # materialize only the sampled rows per round (gather-by-index into
    # a [K, N] working buffer) — the cross-device engine that lets a
    # 10,000-site job at 1% sampling run on one box
    shard_sites: bool = False
    # bookkeeping
    checkpoint_dir: Optional[str] = None
    ckpt_every: int = 10
    verbose: bool = False
    log_every: Optional[int] = None

    # -- derived -----------------------------------------------------------

    @property
    def train_sites(self) -> int:
        """Sites in the *training* federation (Pooled trains as 1 site
        over the concatenated data)."""
        return 1 if self.strategy == "pooled" else self.task.sites

    @property
    def topo(self) -> Topology:
        return resolve_topology(self.topology)

    @property
    def dp(self):
        """The job's :class:`~repro.privacy.DPConfig`, or None (off)."""
        if self.dp_clip <= 0 and self.dp_noise_multiplier <= 0:
            return None
        from repro.privacy import DPConfig
        return DPConfig(clip=self.dp_clip,
                        noise_multiplier=self.dp_noise_multiplier,
                        delta=self.dp_delta, mode=self.dp_mode,
                        seed=self.seed)

    def dp_tag(self) -> Optional[List[Any]]:
        """Checkpoint-meta fingerprint of the DP settings — a resume
        with a different mechanism must refuse, not splice streams."""
        dp = self.dp
        if dp is None:
            return None
        return [dp.clip, dp.noise_multiplier, dp.mode, dp.seed]

    @property
    def mask_secret(self) -> str:
        """The shared secret the pairwise mask seeds derive from: the
        wire auth secret when set (the deployed configuration), else a
        seed-derived default so offline tests run without one."""
        return self.wire.secret or f"fedkbp-mask:{self.seed}"

    def privacy_report(self, rounds: Optional[int] = None
                       ) -> Optional[Dict[str, Any]]:
        """``JobResult.privacy``: accountant output + mechanism settings
        (None when no privacy mechanism is on).  ε accounts the FULL
        logical run of ``rounds`` — a crash-resumed invocation replays
        the same noise stream, it does not spend new budget."""
        dp = self.dp
        if dp is None and not self.secure_agg:
            return None
        rep: Dict[str, Any] = {"secure_agg": bool(self.secure_agg)}
        if dp is None:
            rep["mechanism"] = "none"
            return rep
        from repro.privacy import gaussian_epsilon
        steps = (self.rounds if rounds is None else rounds) * self.local_steps
        rep.update({
            "mechanism": "dp-sgd", "mode": dp.mode, "clip": dp.clip,
            "noise_multiplier": dp.noise_multiplier, "delta": dp.delta,
            "steps": steps, "accountant": "rdp-gaussian",
            "epsilon": gaussian_epsilon(dp.noise_multiplier, steps,
                                        dp.delta)})
        # privacy amplification by subsampling: under poisson:q client
        # sampling each site's round contribution is released only with
        # probability q, so the accountant composes the subsampled
        # Gaussian mechanism instead (ε_sub ≤ ε).  uniform:K is sampling
        # WITHOUT replacement — the Poisson amplification theorem does
        # not cover it, so it conservatively keeps the unsampled ε.
        sampler = self.sampler
        if sampler.kind == "poisson" and self.sampled:
            q = sampler.inclusion_probability(self.task.sites)
            rep.update({
                "sampling_rate": q, "accountant": "rdp-sgm-poisson",
                "epsilon": gaussian_epsilon(dp.noise_multiplier, steps,
                                            dp.delta, sampling_rate=q)})
        return rep

    def replace(self, **kw) -> "FederatedJob":
        return dataclasses.replace(self, **kw)

    @property
    def sampler(self) -> ClientSampler:
        """The job's resolved :class:`~repro.core.sampling.ClientSampler`."""
        return resolve_sampler(self.sample)

    @property
    def aggregator_spec(self):
        """The job's parsed :class:`~repro.core.agg_engine.AggregatorSpec`."""
        return parse_aggregator(self.aggregator)

    @property
    def adversary_plan(self):
        """The job's parsed :class:`~repro.core.adversary.AdversaryPlan`,
        or None when every site is honest."""
        return parse_adversary(self.adversary, seed=self.seed)

    @property
    def sampled(self) -> bool:
        """True when client sampling actually thins participation
        (``uniform:S`` and ``poisson:1.0`` are the dense run)."""
        return not self.sampler.is_trivial(self.task.sites)

    def participation(self, rounds: int) -> Tuple[np.ndarray, np.ndarray]:
        """``(participate, scale)`` for the run: the [rounds, S] bool
        participation schedule (sampled ∩ available, with the
        deterministic availability-precedence rule on empty rounds) and
        the [rounds, S] float32 Horvitz–Thompson ``1/π`` weight scale.
        Pure function of the job config, so every transport, engine and
        distributed worker replays one schedule."""
        if self.pod_dropout and not self.topo.is_pods:
            raise ValueError("pod_dropout requires a pods topology "
                             "(--topology pods:K)")
        if self.sampled and self.strategy == "pooled":
            raise ValueError("client sampling is meaningless for the "
                             "pooled centralized baseline; use sample="
                             "'none'")
        avail = availability_masks(self.task.sites, self.max_dropout,
                                   self.seed, rounds, topology=self.topo,
                                   pod_dropout=self.pod_dropout)
        return compose_participation(self.sampler, avail, self.seed)

    def masks(self, rounds: int) -> np.ndarray:
        """The run's [rounds, S] participation schedule — Algorithm-2
        availability (site-tier churn composed with ``pod_dropout``
        pod-tier churn) intersected with the client-sampling schedule.
        THE mask source for every transport, so distributed workers and
        the driver replay one schedule.  Without sampling this is the
        availability schedule verbatim."""
        return self.participation(rounds)[0]

    def weight_scale(self, rounds: int) -> np.ndarray:
        """[rounds, S] float32 Eq. 1 inclusion-probability factors
        (``1/π`` on sampled rows, ``1.0`` on fallback rounds); the
        engines multiply this into ``normalized_weights`` only when
        :attr:`sampled` is True, keeping unsampled runs bit-identical."""
        return self.participation(rounds)[1]

    def tier_schedulers(self) -> Tuple[RoundScheduler, RoundScheduler]:
        """(intra-pod, cross-pod) schedulers: the topology's per-tier
        overrides, falling back to the job's scheduler at both tiers."""
        topo = self.topo
        return (resolve_scheduler(topo.intra_scheduler
                                  if topo.intra_scheduler is not None
                                  else self.scheduler),
                resolve_scheduler(topo.inter_scheduler
                                  if topo.inter_scheduler is not None
                                  else self.scheduler))

    def federation(self, num_sites: Optional[int] = None,
                   strategy: Optional[str] = None) -> FederationConfig:
        sites = self.train_sites if num_sites is None else num_sites
        counts = self.case_counts
        if counts is not None and len(counts) != sites:
            counts = None               # e.g. a 1-site worker view
        return FederationConfig(
            num_sites=sites, strategy=strategy or self.strategy,
            local_steps=self.local_steps, rounds=self.rounds,
            prox_mu=self.prox_mu, gcml_lambda=self.gcml_lambda,
            gcml_contrast_beta=self.gcml_contrast_beta,
            max_dropout_sites=self.max_dropout,
            dropout_scenario=self.dropout_scenario,
            site_case_counts=counts)

    def context(self, bundle: Optional[TaskBundle] = None,
                num_sites: Optional[int] = None,
                strategy: Optional[str] = None,
                dp_site_base: int = 0) -> F.FLContext:
        """The FLContext view of this job (stacked or per-site worker).
        The topology rides along only on the full-federation view — a
        worker's 1-site (or otherwise resized) context is flat, since
        tiering happens at its aggregation point, not inside its rounds.
        ``dp_site_base`` maps the view's site rows to global site ids so
        a socket worker draws the same DP noise as its stacked twin."""
        bundle = bundle or self.task.build()
        fed = self.federation(num_sites, strategy)
        topo = self.topo if num_sites is None and self.strategy != "pooled" \
            else FLAT
        return F.FLContext(
            fed=fed, mesh=MeshConfig.for_sites(fed.num_sites),
            case_weights=jnp.asarray(fed.case_weights()),
            loss_fn=bundle.loss_fn, logits_fn=bundle.logits_fn,
            optimizer=adamw(self.lr, weight_decay=self.weight_decay),
            grad_clip=self.grad_clip, dcml_lr=self.dcml_lr or self.lr,
            topology=topo, privacy=self.dp, dp_site_base=dp_site_base,
            aggregator=self.aggregator_spec,
            # in-round fault injection runs only on the full-federation
            # stacked view; a worker's 1-site (or local-strategy resized)
            # context stays honest — socket workers perturb their wire
            # payload host-side at the same seam instead
            adversary=(self.adversary_plan
                       if num_sites is None and strategy is None else None))

    def recorder(self, rounds: int, num_sites: int) -> RoundRecorder:
        return RoundRecorder(rounds, verbose=self.verbose,
                             log_every=self.log_every,
                             checkpoint_dir=self.checkpoint_dir,
                             ckpt_every=self.ckpt_every, num_sites=num_sites)

    def run(self, rounds: Optional[int] = None,
            resume: bool = False) -> JobResult:
        """Execute the federation — the one round loop.

        ``resume=True`` re-enters a killed/crashed job from the newest
        usable checkpoint under ``checkpoint_dir`` instead of round 0:
        the stacked engines reload their full carry (fl_state + engine
        buffers + EF residuals), the socket transports reload the driver
        global and every site's own state at the newest round all of
        them share.  With nothing on disk the run starts fresh
        (``result.resumed_from`` is None).  At checkpoint-aligned
        boundaries the resumed loss trajectory is identical to an
        uninterrupted run."""
        return resolve_transport(self.transport).execute(
            self, self.rounds if rounds is None else rounds, resume=resume)


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


class Transport:
    """Execution backend protocol: run ``rounds`` FL rounds of ``job``
    (optionally re-entering from the job's checkpoints)."""

    name = "base"

    def execute(self, job: FederatedJob, rounds: int,
                resume: bool = False) -> JobResult:
        raise NotImplementedError


def _driver_resume_round(job: FederatedJob, resume: bool) -> Optional[int]:
    """Stacked transport: the newest ``driver_state`` checkpoint round,
    or None for a fresh start.  ``resume=True`` without a
    ``checkpoint_dir`` has nothing to resume from and raises."""
    if not resume:
        return None
    if not job.checkpoint_dir:
        raise ValueError("run(resume=True) needs checkpoint_dir set")
    from repro.checkpoint import CheckpointStore
    saved = CheckpointStore(Path(job.checkpoint_dir)).saved_rounds(
        "driver_state")
    return saved[-1] if saved else None


def _socket_resume_point(job: FederatedJob, num_sites: int):
    """Socket transports: ``(resume_round, global)`` — the newest round
    present in the driver's "global" store AND every site's own
    sub-store, i.e. the round every participant can re-enter from.
    ``(None, None)`` when no common round survived (fresh start)."""
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(Path(job.checkpoint_dir))
    common = set(store.saved_rounds("global"))
    for i in range(num_sites):
        sub = CheckpointStore(Path(job.checkpoint_dir) / f"site{i}")
        common &= set(sub.saved_rounds("state"))
    if not common:
        return None, None
    rr = max(common)
    like = job.task.build().init_fn(jax.random.PRNGKey(job.seed))
    g, _ = store.load("global", rr, like)
    return rr, g


def _socket_down_refs(job: FederatedJob, rr: int, num_sites: int):
    """Per-site downlink references the aggregation server persisted at
    resume round ``rr`` (tags ``downref{sid}``) → the ``initial_down``
    map a restarted server seeds its :class:`DownlinkCompressor` from.
    Sites without a saved reference simply re-enter through a dense
    bootstrap — resume never deadlocks on a missing tag."""
    from repro.checkpoint import CheckpointStore
    store = CheckpointStore(Path(job.checkpoint_dir))
    like = job.task.build().init_fn(jax.random.PRNGKey(job.seed))
    out = {}
    for sid in range(num_sites):
        tag = f"downref{sid}"
        if rr in set(store.saved_rounds(tag)):
            held, meta = store.load(tag, rr, like)
            out[sid] = (held, int(meta["held_round"]))
    return out or None


def _validate_robustness(job: FederatedJob) -> None:
    """Fail-loud composition guards for the robustness seams, shared by
    every transport.  Robust rules need to SEE the round's individual
    plaintext uploads side by side; compositions that hide, quantize or
    stream them away are typed errors, never silent downgrades."""
    spec = job.aggregator_spec          # raises on a malformed spec string
    plan = job.adversary_plan           # raises on a malformed plan string
    if (not spec.robust and plan is None and job.max_upload_norm is None
            and job.round_deadline_s is None):
        return
    if job.strategy == "pooled":
        raise ValueError("the pooled centralized baseline has no "
                         "federation to attack or robustly aggregate")
    sites = job.task.sites
    if ((spec.robust or plan is not None)
            and resolve_codec(job.compression).name != "none"):
        raise ValueError(
            "robust aggregation and the adversary harness operate on "
            "plaintext fp32 uploads; delta-quantized uploads would fold "
            "attacker-shaped residuals into honest error feedback — use "
            "compression='none'")
    if spec.robust and job.secure_agg:
        raise ValueError(
            "robust rules rank individual uploads; secure aggregation "
            "masks every upload so only their sum is visible — the rule "
            "would rank ciphertext.  Disable secure_agg or use "
            "aggregator='fedavg'")
    if job.max_upload_norm is not None and job.secure_agg:
        raise ValueError(
            "max_upload_norm inspects per-upload L2 norms; secure "
            "aggregation uploads fixed-point ciphertext whose norm is "
            "meaningless — disable one of them")
    if (plan is not None or spec.robust) and job.shard_sites:
        raise ValueError(
            "the sharded engine folds partial sums per device shard and "
            "runs local-strategy contexts — it has neither the full "
            "[S, N] buffer a robust rule needs nor an in-round fault "
            "seam; run robustness jobs with shard_sites=False")
    if spec.rank_based:
        if job.strategy not in ("fedavg", "fedprox"):
            raise ValueError(
                "rank-based robust rules (trimmed/median/krum) combine "
                f"centrally-aggregated uploads; strategy {job.strategy!r} "
                "has no central combine — use fedavg/fedprox (or "
                "aggregator='normclip:c', which gossip honors too)")
        intra_s, inter_s = job.tier_schedulers()
        if (isinstance(intra_s, BufferedScheduler)
                or isinstance(inter_s, BufferedScheduler)):
            raise ValueError(
                "rank-based robust rules need the round's uploads side "
                "by side; a buffered scheduler folds each arrival into a "
                "running sum and discards it — use scheduler='sync'")
        if spec.name == "trimmed" and 2 * spec.f >= sites:
            raise ValueError(
                f"trimmed:{spec.f} discards 2f={2 * spec.f} of {sites} "
                "uploads — the trim must leave a majority (2f < S)")
        if spec.name == "krum" and spec.f > max(sites - 3, 0):
            raise ValueError(
                f"krum:{spec.f} scores each upload against its "
                f"S−f−2 nearest neighbours and needs S ≥ f+3 (S={sites})")
    if (spec.name == "normclip"
            and job.strategy not in ("fedavg", "fedprox", "gcml")):
        raise ValueError(
            "normclip bounds uploads at a central fold (fedavg/fedprox) "
            f"or incoming gossip deltas (gcml), not {job.strategy!r}")
    if (job.round_deadline_s is not None
            and resolve_scheduler(job.scheduler).name != "sync"):
        raise ValueError(
            "round_deadline_s bounds the sync barrier; scheduler "
            f"{job.scheduler!r} has no barrier to bound")


def _validate_down(job: FederatedJob) -> None:
    """Fail-loud composition guards for download compression
    (``down_compression``), shared by every transport.  The download
    codec needs a server that tracks one reference trajectory per site;
    compositions without that server — or whose threat model forbids
    it — are typed errors, never silent dense downgrades."""
    if resolve_codec(job.down_compression).name == "none":
        return
    if job.strategy not in ("fedavg", "fedprox"):
        raise ValueError(
            "down_compression encodes the server's broadcast against "
            "per-site held references; only the centrally-aggregated "
            "strategies (fedavg/fedprox) have that broadcast, not "
            f"{job.strategy!r}")
    if job.secure_agg:
        raise ValueError(
            "secure_agg downloads stay dense: the masked protocol lets "
            "the server materialize only the aggregate sum, while "
            "down_compression requires it to track what each site holds "
            "— disable one of them")
    intra_s, inter_s = job.tier_schedulers()
    if (isinstance(resolve_scheduler(job.scheduler), BufferedScheduler)
            or isinstance(intra_s, BufferedScheduler)
            or isinstance(inter_s, BufferedScheduler)):
        raise ValueError(
            "buffered-async sites pull whichever global version is "
            "newest out of the keep_globals ring, not a per-site "
            "residual stream; down_compression needs scheduler='sync'")
    if job.aggregator_spec.robust or job.adversary_plan is not None:
        raise ValueError(
            "robust aggregation rules and the adversary harness rank "
            "plaintext uploads against ONE shared broadcast; "
            "down_compression gives every site a different decoded "
            "install, so upload distances would mix honest quantization "
            "drift with attacker signal — use down_compression='none'")
    if job.shard_sites:
        raise ValueError(
            "shard_sites=True broadcasts the global through the mesh "
            "collective, not the download codec; run down_compression "
            "jobs with shard_sites=False")


class StackedTransport(Transport):
    """Single-process vmapped simulator (all strategies, all schedulers).

    Rounds run on the compiled scan engine
    (:mod:`repro.core.round_engine`) by default — chunks of rounds fused
    into one donated ``lax.scan`` — with the retired per-round loops
    below kept as the parity oracle (``round_engine="loop"``) and as the
    fallback for the combinations the scan cannot replicate
    (``topk-sparse`` uploads, buffered staleness past the decode ring).
    """

    name = "stacked"

    def execute(self, job: FederatedJob, rounds: int,
                resume: bool = False) -> JobResult:
        scheduler = resolve_scheduler(job.scheduler)
        codec = resolve_codec(job.compression)
        down_codec = resolve_codec(job.down_compression)
        down = down_codec.name != "none"
        buffered = isinstance(scheduler, BufferedScheduler)
        _validate_robustness(job)
        _validate_down(job)
        if job.round_deadline_s is not None:
            raise ValueError(
                "round_deadline_s bounds a real wall-clock barrier; the "
                "stacked simulator has none — run on transport='thread' "
                "or 'tcp'")
        if job.max_upload_norm is not None:
            raise ValueError(
                "max_upload_norm is server-side upload sanitation; the "
                "stacked simulator has no server — run on "
                "transport='thread' or 'tcp'")
        if job.adversary_plan is not None and buffered:
            raise ValueError(
                "the stacked buffered loop trains local-only contexts "
                "with no in-round fault seam; run adversarial buffered "
                "jobs on the thread/tcp transports")
        if job.aggregator_spec.robust and buffered:
            raise ValueError(
                "the stacked buffered loop folds arrivals into a plain "
                "running sum; robust buffered rounds (normclip) run on "
                "the thread/tcp transports' server")
        if job.secure_agg:
            raise ValueError(
                "secure_agg masks real uploads between distrusting "
                "participants — there is no wire to protect inside the "
                "stacked simulator; run it on transport='thread' or 'tcp'")
        topo = job.topo
        if topo.is_pods:
            topo.validate(job.task.sites)
            if job.strategy not in ("fedavg", "fedprox"):
                raise ValueError(
                    "a pods topology needs a centrally-aggregated strategy "
                    f"(fedavg/fedprox), not {job.strategy!r}")
            intra_s, inter_s = job.tier_schedulers()
            if (buffered or isinstance(intra_s, BufferedScheduler)
                    or isinstance(inter_s, BufferedScheduler)):
                raise ValueError(
                    "the stacked simulator runs pods synchronously at both "
                    "tiers; buffered per-tier compositions run on the "
                    "thread/tcp transports")
        if buffered and job.strategy != "fedavg":
            raise ValueError("buffered-async scheduling currently supports "
                             f"fedavg only, not {job.strategy!r}")
        if (not buffered and codec.name != "none"
                and job.strategy not in ("fedavg", "fedprox")):
            raise ValueError(
                "compression on the stacked transport currently supports "
                f"fedavg/fedprox only, not {job.strategy!r}; run gcml "
                "compression on the thread/tcp transports")
        if job.sampled and job.device_data:
            raise ValueError(
                "client sampling precomputes its schedule host-side (a "
                "pure function of (seed, round)); device_data=True "
                "regenerates availability on device and would ignore it — "
                "run sampled jobs with host batches")
        bundle = job.task.build()
        if job.round_engine not in ("auto", "scan", "loop"):
            raise ValueError(f"unknown round_engine {job.round_engine!r}; "
                             "known: auto, scan, loop")
        resume_round = _driver_resume_round(job, resume)
        if job.shard_sites:
            from repro.core import round_engine
            return round_engine.execute_sharded(job, bundle, scheduler,
                                                codec, rounds,
                                                resume_round=resume_round)
        if job.round_engine != "loop":
            from repro.core import round_engine
            res = round_engine.execute_stacked(
                job, bundle, scheduler, codec, rounds,
                resume_round=resume_round,
                down_codec=down_codec if down else None)
            if res is not None:
                return res
            if job.round_engine == "scan":
                raise ValueError(
                    f"round_engine='scan' cannot run this job (codec "
                    f"{codec.name!r} / scheduler {scheduler.name!r} take "
                    "the host path); use round_engine='auto' or 'loop'")
        if job.device_data:
            raise ValueError("device_data=True requires the scan engine")
        if buffered:
            if resume_round is not None:
                raise ValueError(
                    "the buffered host loop carries a mid-round accumulator "
                    "that is not checkpointable; resume buffered jobs on "
                    "the scan engine (round_engine='auto')")
            return self._execute_buffered(job, bundle, scheduler, rounds,
                                          codec)
        if codec.name != "none" or down:
            return self._execute_compressed(
                job, bundle, scheduler, rounds, codec, resume_round,
                down_codec=down_codec if down else None)
        return self._execute_sync(job, bundle, scheduler, rounds,
                                  resume_round)

    def _execute_sync(self, job, bundle, scheduler, rounds,
                      resume_round=None) -> JobResult:
        ctx = job.context(bundle)
        strategy = strat_base.get_strategy(job.strategy)
        state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
        fl_round = F.build_fl_round(ctx)
        fl_step = None                  # AOT-compiled once, timed separately
        compile_s = 0.0
        masks = job.masks(rounds)
        # client sampling: the [rounds, S] 1/π Eq. 1 factor — only
        # threaded when sampling actually thins participation, so dense
        # runs keep a bit-identical round_inputs structure
        wscale = job.weight_scale(rounds) if job.sampled else None
        pair_rng = np.random.default_rng(job.seed)
        recorder = job.recorder(rounds, ctx.fed.num_sites)
        start_round = 0
        if resume_round is not None:
            lmeta = recorder.store.meta("driver_state", resume_round)
            check_engine_tag(lmeta, "sync-loop")
            check_privacy_tag(lmeta, job.dp_tag())
            loaded, _ = recorder.store.load(
                "driver_state", resume_round, {"fl_state": state})
            state = jax.tree.map(jnp.asarray, loaded["fl_state"])
            start_round = resume_round + 1
            # replay the pairing draws the completed rounds consumed, so
            # a resumed gossip schedule continues where the dead run was
            for rr in range(start_round):
                F.make_round_inputs(ctx, rng=pair_rng, round_index=rr,
                                    active=masks[rr])
        for r in range(start_round, rounds):
            b = bundle.round_batches(r, job.local_steps,
                                     pooled=(job.strategy == "pooled"))
            ri = F.make_round_inputs(ctx, rng=pair_rng, round_index=r,
                                     active=masks[r])
            if wscale is not None:
                ri["weight_scale"] = jnp.asarray(wscale[r])
            extra = {}
            if strategy.needs_val_batch:
                ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
                ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
            if strategy.needs_pairing:
                extra = {"partner": ri["partner"].tolist(),
                         "is_receiver": ri["is_receiver"].tolist()}
            if fl_step is None:         # warm up: keep compile out of step_s
                t_c = time.perf_counter()
                fl_step = jax.jit(fl_round).lower(state, b, ri).compile()
                compile_s = time.perf_counter() - t_c
            t_step = time.time()
            state, metrics = fl_step(state, b, ri)
            jax.block_until_ready(state)
            extra["step_s"] = time.time() - t_step   # compute-only round time
            recorder.record(r, np.asarray(metrics["loss"]), masks[r],
                            global_fn=lambda: F.global_model(state, ctx),
                            extra=extra)
            recorder.save_state(
                r, lambda: {"fl_state": jax.tree.map(np.asarray, state)},
                meta={"engine": "sync-loop", "dp": job.dp_tag()})
        comm = None
        if job.strategy in ("fedavg", "fedprox"):
            # no wire in-process: report what the equivalent socket run
            # would upload/download (one fp32 model per active site per
            # round, each direction; with pods, plus one partial/global
            # per active pod on the cross-pod link).  A resumed run
            # counts only the rounds it actually executed.
            nbytes = per_site_nbytes(state["params"])
            if ctx.topology.is_pods:
                from repro.core.topology import simulated_pods_comm
                comm = simulated_pods_comm(ctx.topology, masks[start_round:],
                                           nbytes)
            else:
                uploads = int(masks[start_round:].sum())
                comm = {"upload_bytes": uploads * nbytes,
                        "download_bytes": uploads * nbytes,
                        "total_bytes": 2 * uploads * nbytes,
                        "upload_count": uploads, "download_count": uploads,
                        "compression": "none", "down_compression": "none",
                        "simulated": True}
        return recorder.result(F.global_model(state, ctx),
                               transport=self.name, scheduler=scheduler.name,
                               state=state, comm=comm, compile_s=compile_s,
                               resumed_from=resume_round,
                               privacy=job.privacy_report(rounds))

    def _execute_compressed(self, job, bundle, scheduler, rounds,
                            codec, resume_round=None,
                            down_codec=None) -> JobResult:
        """Sync rounds with the upload path routed through the codec:
        every active site's post-training weights are delta-encoded
        against the last broadcast global (error-feedback residual
        carried across rounds), immediately decoded, and folded into the
        :class:`StreamingAccumulator` at the site's case weight — the
        exact client/server path the socket transports drive against the
        ``AggregationServer``, simulated in process.  The first round
        uploads full (quantized) weights; deltas start once a global
        exists, mirroring a server that never saw the initialization.

        With ``down_codec`` (bidirectional compression) the broadcast
        rides the codec seam too: a :class:`DownlinkCompressor` tracks
        each site's held reference server-side and every install is a
        quantized delta decoded through :func:`decode_download`; the
        site's next upload then anchors to its OWN decoded install, and
        a site whose reference left the ``keep_globals`` window
        bootstraps dense both ways (the rejoin rule).  The scan engine's
        ``compressed-scan-bidir`` path is the compiled twin — byte
        accounting is bit-identical on CPU.

        FedProx runs its local half (``fedprox-local``) with the
        proximal anchor re-pinned to each broadcast global; a pods
        topology folds through per-pod accumulators first and combines
        the partials at the pod weights — the simulated twin of the
        :class:`~repro.comms.pods.PodTransport` server stack."""
        local_strategy = ("fedprox-local" if job.strategy == "fedprox"
                          else "individual")
        ctx = job.context(bundle, strategy=local_strategy)  # local-only
        num_sites = ctx.fed.num_sites
        topo = job.topo
        pod_of = topo.pod_of(num_sites)
        state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
        fl_round = F.build_fl_round(ctx)
        local_round = None
        compile_s = 0.0
        masks = job.masks(rounds)
        wscale = job.weight_scale(rounds) if job.sampled else None
        case_w = np.asarray(job.federation().case_weights())
        comps = [UploadCompressor(codec, job.error_feedback)
                 for _ in range(num_sites)]
        down = down_codec is not None and down_codec.name != "none"
        keep = KEEP_GLOBALS_DEFAULT
        engine_tag = "compressed-loop-bidir" if down else "compressed-loop"
        server_down = DownlinkCompressor(down_codec) if down else None
        site_refs: List[Any] = [None] * num_sites   # decoded installs
        down_acked: List[Optional[int]] = [None] * num_sites
        last_active = np.full(num_sites, -keep, np.int64)
        reference = None                     # last broadcast global (fp32)
        global_params = jax.tree.map(np.asarray, F.global_model(state, ctx))
        recorder = job.recorder(rounds, num_sites)
        # the reference/residual like: one site's (unstacked) zero tree
        site_zero = jax.tree.map(lambda x: np.zeros(x.shape[1:], np.float32),
                                 state["params"])
        start_round = 0
        if resume_round is not None:
            lmeta = recorder.store.meta("driver_state", resume_round)
            check_engine_tag(lmeta, engine_tag)
            check_privacy_tag(lmeta, job.dp_tag())
            like = {"fl_state": state, "reference": site_zero,
                    "residuals": [site_zero for _ in range(num_sites)]}
            if down:
                like["down_refs"] = [site_zero for _ in range(num_sites)]
            loaded, _ = recorder.store.load("driver_state", resume_round,
                                            like)
            state = jax.tree.map(jnp.asarray, loaded["fl_state"])
            reference = jax.tree.map(np.asarray, loaded["reference"])
            global_params = reference
            for i, has in enumerate(lmeta.get("has_residual",
                                              [False] * num_sites)):
                if has:
                    comps[i].residual = loaded["residuals"][i]
            if down:
                for i, acked in enumerate(lmeta.get("down_acked",
                                                    [None] * num_sites)):
                    if acked is not None:
                        site_refs[i] = jax.tree.map(np.asarray,
                                                    loaded["down_refs"][i])
                        down_acked[i] = int(acked)
                        server_down.restore(i, site_refs[i], int(acked))
            start_round = resume_round + 1
            # the bootstrap schedule is a pure function of the masks:
            # replay participation so rejoin gaps survive the restart
            for rr in range(start_round):
                last_active[masks[rr]] = rr
        for r in range(start_round, rounds):
            b = bundle.round_batches(r, job.local_steps)
            ri = F.make_round_inputs(ctx, active=masks[r])
            if local_round is None:          # warm up once (compile_s)
                t_c = time.perf_counter()
                local_round = jax.jit(fl_round).lower(state, b, ri).compile()
                compile_s = time.perf_counter() - t_c
            t_step = time.time()
            state, metrics = local_round(state, b, ri)
            jax.block_until_ready(state)
            active_idx = [int(i) for i in np.flatnonzero(masks[r])]
            # two-tier fold: sites stream into their pod's accumulator,
            # pod partials stream into the root at the pod's folded
            # weight (flat topology = the one-accumulator special case)
            pods = [StreamingAccumulator() for _ in range(topo.num_pods)]
            root = StreamingAccumulator()
            round_bytes = 0
            round_down_bytes = 0
            for site in active_idx:
                params_site = jax.tree.map(
                    lambda x: np.asarray(x[site], np.float32), state["params"])
                if down:
                    # bidirectional: the upload anchors to the site's OWN
                    # decoded install; past the keep window both ends
                    # bootstrap dense (matches _bootstrap_masks exactly)
                    up_ref = (None if r - int(last_active[site]) >= keep
                              else site_refs[site])
                else:
                    up_ref = reference
                enc, cmeta = comps[site].encode(params_site, up_ref)
                round_bytes += tree_payload_nbytes(enc)
                w = 1.0 if topo.intra == "uniform" else float(case_w[site])
                if wscale is not None:     # Horvitz–Thompson 1/π factor
                    w *= float(wscale[r, site])
                pods[int(pod_of[site])].fold(
                    decode_upload(enc, cmeta, up_ref), w)
            for acc in pods:
                if acc.count:
                    pw = 1.0 if topo.inter == "uniform" else acc.weight_total
                    root.fold(acc.finalize(), pw)
            if root.count:
                global_params = root.finalize()
                reference = global_params
                if down:
                    # socket ordering: advance the round clock, evict
                    # stale references, THEN serve this round's downloads
                    server_down.evict_stale(r + 1, keep)
                    installs = []
                    for site in active_idx:
                        payload, dmeta = server_down.encode(
                            site, global_params, r + 1,
                            acked_round=down_acked[site])
                        round_down_bytes += tree_payload_nbytes(payload)
                        inst = decode_download(payload, dmeta,
                                               site_refs[site])
                        site_refs[site] = inst
                        down_acked[site] = r + 1
                        installs.append(inst)
                    state = _set_param_rows(state, active_idx, installs)
                else:
                    state = _set_param_sites(state, active_idx, global_params)
                if local_strategy == "fedprox-local":   # Eq. 2 anchor —
                    # the exact global even under down compression (the
                    # scan's vmapped body broadcasts ONE anchor; parity)
                    state = {**state, "strategy": {"global": jax.tree.map(
                        lambda g: jnp.asarray(g, jnp.float32),
                        global_params)}}
            last_active[masks[r]] = r
            extra = {"step_s": time.time() - t_step,
                     "upload_bytes": round_bytes}
            if down:
                extra["download_bytes"] = round_down_bytes
            recorder.record(r, np.asarray(metrics["loss"]), masks[r],
                            global_fn=lambda: global_params, extra=extra)

            def _ckpt_tree(state=state, reference=reference,
                           refs=tuple(site_refs)):
                t = {"fl_state": jax.tree.map(np.asarray, state),
                     "reference": (reference if reference is not None
                                   else site_zero),
                     "residuals": [c.residual if c.residual is not None
                                   else site_zero for c in comps]}
                if down:
                    t["down_refs"] = [rf if rf is not None else site_zero
                                      for rf in refs]
                return t
            meta = {"engine": engine_tag, "dp": job.dp_tag(),
                    "has_residual": [c.residual is not None for c in comps]}
            if down:
                meta["down_acked"] = list(down_acked)
            recorder.save_state(r, _ckpt_tree, meta=meta)
        comm = _compressor_comm(comps, codec,
                                per_site_nbytes(state["params"]),
                                down=server_down,
                                down_name=down_codec.name if down else "none")
        if topo.is_pods:
            from repro.core.topology import simulated_pods_comm
            comm.update(simulated_pods_comm(
                topo, masks[start_round:], per_site_nbytes(state["params"]),
                intra_upload_bytes=comm["upload_bytes"],
                intra_download_bytes=(comm["download_bytes"] if down
                                      else None),
                compression=codec.name,
                down_compression=down_codec.name if down else "none"))
        return recorder.result(global_params, transport=self.name,
                               scheduler=scheduler.name, state=state,
                               comm=comm, compile_s=compile_s,
                               resumed_from=resume_round,
                               privacy=job.privacy_report(rounds))

    def _execute_buffered(self, job, bundle, scheduler, rounds,
                          codec) -> JobResult:
        """FedBuff-style buffered async, simulated: every round all active
        sites train locally, then 'arrive' in random order; each arrival
        folds into the :class:`StreamingAccumulator` at a staleness-
        discounted weight, and the buffer finalizes into a new global
        whenever ``scheduler.ready`` fires (K of S).  After uploading,
        sites pull the latest global — exactly the site loop the socket
        transports run against the buffered ``AggregationServer``.

        With a compression codec, each arrival is delta-encoded against
        the global *version* that site last pulled (a bounded ring of
        recent globals provides the decode references, mirroring the
        server's ``keep_globals`` window) and decoded before the fold.
        """
        from collections import OrderedDict
        ctx = job.context(bundle, strategy="individual")   # local-only rounds
        num_sites = ctx.fed.num_sites
        state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
        fl_round = F.build_fl_round(ctx)
        local_round = None
        compile_s = 0.0
        masks = job.masks(rounds)
        case_w = np.asarray(job.federation().case_weights())
        acc = StreamingAccumulator()
        order_rng = np.random.default_rng(job.seed + 13)
        version = 0
        base_version = np.zeros(num_sites, np.int64)
        global_params = jax.tree.map(np.asarray, F.global_model(state, ctx))
        compress = codec.name != "none"
        comps = [UploadCompressor(codec, job.error_feedback)
                 for _ in range(num_sites)]
        # version → global, the delta decode references, as an O(1) ring:
        # finalize appends, eviction pops the oldest entry — no rebuild
        # scan over the history per arrival.  The init model is version 0
        # (every site starts from it).
        globals_by_version: "OrderedDict[int, Any]" = OrderedDict(
            {0: global_params})
        recorder = job.recorder(rounds, num_sites)
        for r in range(rounds):
            b = bundle.round_batches(r, job.local_steps)
            ri = F.make_round_inputs(ctx, active=masks[r])
            if local_round is None:          # warm up once (compile_s)
                t_c = time.perf_counter()
                local_round = jax.jit(fl_round).lower(state, b, ri).compile()
                compile_s = time.perf_counter() - t_c
            t_step = time.time()
            state, metrics = local_round(state, b, ri)
            jax.block_until_ready(state)
            active_idx = np.flatnonzero(masks[r])
            uploaded: List[int] = []
            for site in order_rng.permutation(active_idx):
                site = int(site)
                discount = scheduler.discount(version - int(base_version[site]))
                if discount is None:                 # too stale: resync only
                    state = _set_param_sites(state, [site], global_params)
                    base_version[site] = version
                    continue
                upload = jax.tree.map(
                    lambda x: np.asarray(x[site], np.float32), state["params"])
                if compress:
                    ref = globals_by_version.get(int(base_version[site]))
                    enc, cmeta = comps[site].encode(upload, ref)
                    upload = decode_upload(enc, cmeta, ref)
                acc.fold(upload, float(case_w[site]) * discount)
                uploaded.append(site)
                if scheduler.ready(acc.count, len(active_idx)):
                    global_params = acc.finalize()
                    version += 1
                    if compress:
                        globals_by_version[version] = global_params
                        while len(globals_by_version) > KEEP_GLOBALS_DEFAULT:
                            globals_by_version.popitem(last=False)
            if uploaded:                             # pull latest global
                state = _set_param_sites(state, uploaded, global_params)
                base_version[np.asarray(uploaded)] = version
            recorder.record(r, np.asarray(metrics["loss"]), masks[r],
                            global_fn=lambda: global_params,
                            extra={"version": version,
                                   "step_s": time.time() - t_step})
        comm = (_compressor_comm(comps, codec,
                                 per_site_nbytes(state["params"]))
                if compress else None)
        return recorder.result(global_params, transport=self.name,
                               scheduler=scheduler.name, state=state,
                               comm=comm, compile_s=compile_s,
                               privacy=job.privacy_report(rounds))




def _compressor_comm(comps: List[UploadCompressor], codec: Codec,
                     download_nbytes: int,
                     down: Optional[DownlinkCompressor] = None,
                     down_name: str = "none") -> Dict[str, Any]:
    """Aggregate compressor counters into the JobResult comm dict
    (stacked simulator: payload bytes, no framing/header overhead).
    Without a :class:`DownlinkCompressor` downloads are uncompressed
    fp32 (one dense global per upload)."""
    uploads = sum(c.encodes for c in comps)
    up_bytes = sum(c.encoded_bytes for c in comps)
    if down is not None:
        down_bytes, down_raw = down.encoded_bytes, down.raw_bytes
        down_count = down.encodes
    else:
        down_bytes = down_raw = uploads * download_nbytes
        down_count = uploads
    return {"upload_bytes": up_bytes,
            "upload_raw_bytes": sum(c.raw_bytes for c in comps),
            "download_bytes": down_bytes,
            "download_raw_bytes": down_raw,
            "total_bytes": up_bytes + down_bytes,
            "upload_count": uploads, "download_count": down_count,
            "compression": codec.name, "down_compression": down_name,
            "simulated": True}


def _set_param_sites(fl_state, sites: List[int], global_tree):
    """Overwrite the given site rows of the stacked params with the
    (unstacked) global model."""
    idx = jnp.asarray(sites)
    new_params = jax.tree.map(
        lambda x, g: x.at[idx].set(jnp.asarray(np.asarray(g)).astype(x.dtype)),
        fl_state["params"], global_tree)
    return {**fl_state, "params": new_params}


def _set_param_rows(fl_state, sites: List[int], trees: List[Any]):
    """Overwrite the given site rows of the stacked params with per-site
    (unstacked) model trees — the bidirectional-compression install,
    where every site decodes a different model."""
    if not sites:
        return fl_state
    idx = jnp.asarray(sites)
    stacked = jax.tree.map(
        lambda *xs: np.stack([np.asarray(x, np.float32) for x in xs]), *trees)
    new_params = jax.tree.map(
        lambda x, g: x.at[idx].set(jnp.asarray(g).astype(x.dtype)),
        fl_state["params"], stacked)
    return {**fl_state, "params": new_params}


# -- socket transports (real Peer / AggregationServer / CoordinationServer) --


def _site_host_tree(params_stacked):
    """Site 0 of a [1, …]-stacked tree as host numpy (the wire payload)."""
    return jax.tree.map(lambda x: np.asarray(x[0]), params_stacked)


def _run_site(job: FederatedJob, site_id: int, agg_addr, coord_addr,
              rounds: int, start_round: int = 0) -> Dict[str, Any]:
    """One site's FL script — identical whether driven by a thread or an
    OS process (paper Algorithm 1, site side), and identical under a
    pods topology: the site just talks to its pod's aggregation server
    (``agg_addr`` arrives as a site→address map) and counts its barrier
    against its pod's active members.

    With a ``checkpoint_dir`` the site keeps its own sub-store
    (``checkpoint_dir/site{id}``: fl_state + delta reference + EF
    residual every ``ckpt_every`` rounds) and, when the driver resumes
    it at ``start_round > 0``, reloads round ``start_round - 1`` and
    re-enters mid-job.  With a ``lease_ttl`` it holds a lease at its
    aggregation point via a heartbeat thread; if admitted after the job
    advanced (a late joiner), it bootstraps from the join reply's dense
    global and skips the completed rounds."""
    from repro.comms.peer import Peer
    bundle = job.task.build()
    if isinstance(agg_addr, dict):          # pods: my pod server's address
        agg_addr = tuple(agg_addr[site_id])
    # the scheduler a site experiences is its aggregation point's — the
    # intra-pod tier under a pods topology (= the job scheduler when flat)
    buffered = isinstance(job.tier_schedulers()[0], BufferedScheduler)
    local_strategy = ("fedprox-local" if job.strategy == "fedprox"
                      else "individual")
    ctx = job.context(bundle, num_sites=1, strategy=local_strategy,
                      dp_site_base=site_id)
    dp_on = job.dp is not None
    state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
    local_round = jax.jit(F.build_fl_round(ctx))
    # every site replays the same Algorithm-2 chain (site + pod tiers) —
    # no status traffic needed for the schedule itself
    masks = job.masks(rounds)
    pod_members = job.topo.pod_of(job.task.sites) == \
        job.topo.pod_of(job.task.sites)[site_id]     # my barrier's peers
    strategy = strat_base.get_strategy(job.strategy)
    dcml_step = None
    peer = Peer(site_id, wire=job.wire)
    ri1 = {"active": np.ones(1, bool), "partner": np.zeros(1, np.int64),
           "is_receiver": np.zeros(1, bool)}
    losses: List[float] = []
    base_round = start_round  # server round of the global this site holds
    stale_uploads = 0
    rejected_uploads = 0
    # deterministic Byzantine harness: whether THIS worker is in the
    # plan's seeded malicious set is a pure function of (seed, S), so
    # every transport replays the same adversary without negotiation
    plan = job.adversary_plan
    malicious = plan is not None and plan.is_malicious(site_id,
                                                       job.task.sites)
    # upload compression: one compressor per outgoing stream, so the
    # error-feedback residuals compensate the right channel
    codec = resolve_codec(job.compression)
    comp = (UploadCompressor(codec, job.error_feedback)
            if codec.name != "none" else None)
    peer_comp = (UploadCompressor(codec, job.error_feedback)
                 if codec.name != "none" and strategy.needs_pairing else None)
    # download compression: the server streams per-site quantized deltas
    # against the global this site last acknowledged (meta carries the
    # ack); the decoded install doubles as the upload/prox anchor, which
    # is bit-equal to the server's held copy by construction
    down = resolve_codec(job.down_compression).name != "none"
    down_ref = None         # last decoded install (the delta base)
    down_acked: Optional[int] = None
    reference = None        # last pulled global (fp32) — the delta anchor
    sa = None               # secure aggregation: pairwise upload masker
    sa_bytes = sa_raw = sa_count = 0
    if job.secure_agg:
        from repro.privacy import SecureAggClient
        sa = SecureAggClient(job.mask_secret, "site", site_id)
        case_w = np.asarray(job.federation().case_weights())
        sa_weight = (1.0 if job.topo.intra == "uniform"
                     else float(case_w[site_id]))
    site_store = None
    if job.checkpoint_dir:
        from repro.checkpoint import CheckpointStore
        site_store = CheckpointStore(
            Path(job.checkpoint_dir) / f"site{site_id}")
    # the reference/residual checkpoint like: this site's zero model tree
    site_zero = jax.tree.map(lambda x: np.zeros(x.shape[1:], np.float32),
                             state["params"])
    hb = None
    try:
        if start_round > 0 and site_store is not None:
            like = {"fl_state": state}
            if comp is not None:
                like["reference"] = site_zero
                like["residual"] = site_zero
            if down:
                like["down_ref"] = site_zero
            loaded, lmeta = site_store.load("state", start_round - 1, like)
            state = jax.tree.map(jnp.asarray, loaded["fl_state"])
            base_round = int(lmeta.get("base_round", start_round))
            if comp is not None:
                if lmeta.get("has_reference"):
                    reference = jax.tree.map(np.asarray, loaded["reference"])
                if lmeta.get("has_residual"):
                    comp.residual = jax.tree.map(np.asarray,
                                                 loaded["residual"])
            if down and lmeta.get("has_down_ref"):
                # re-enter the server's residual stream exactly where the
                # killed site left it (the server restored the matching
                # held copy from its own checkpoint)
                down_ref = jax.tree.map(np.asarray, loaded["down_ref"])
                acked = lmeta.get("down_acked")
                down_acked = int(acked) if acked is not None else None
        if job.lease_ttl and agg_addr is not None:
            from repro.comms.membership import HeartbeatClient
            hb = HeartbeatClient(
                site_id, lambda k, m: peer.request(agg_addr, k, m),
                job.lease_ttl).start()
            join_round = int(hb.join_meta.get("round", 0))
            if join_round > start_round and hb.bootstrap is not None:
                # late joiner: the job is join_round rounds in — adopt the
                # dense bootstrap global and skip the completed rounds
                g = hb.bootstrap
                state = {**state, "params": jax.tree.map(
                    lambda x, gg: jnp.broadcast_to(
                        jnp.asarray(gg).astype(x.dtype)[None], x.shape),
                    state["params"], g)}
                if local_strategy == "fedprox-local":
                    state = {**state, "strategy": {"global": jax.tree.map(
                        lambda gg: jnp.asarray(gg, jnp.float32), g)}}
                base_round = join_round
                if comp is not None:
                    reference = jax.tree.map(
                        lambda x: np.asarray(x, np.float32), g)
                losses.extend([float("nan")] * (join_round - start_round))
                start_round = join_round
        if strategy.needs_pairing:
            from repro.core.strategies.gcml import make_site_dcml
            dcml_step = jax.jit(make_site_dcml(job.context(bundle)))
            peer.register(coord_addr)
        for r in range(start_round, rounds):
            me_active = bool(masks[r, site_id])
            b = bundle.site_batches(site_id, r, job.local_steps)
            if malicious and plan.flips_labels:
                b = plan.perturb_batch(b)
            # -- decentralized pre-exchange: gossip + regional DCML ------
            if dcml_step is not None and me_active:
                asg = peer.get_assignment(coord_addr, r + 1)
                recv_of = {int(asg["partner"][j]): j
                           for j in range(len(asg["partner"]))
                           if asg["is_receiver"][j]}
                if asg["is_sender"][site_id]:
                    target = recv_of[site_id]
                    wire_tree = _site_host_tree(state["params"])
                    if malicious and plan.flips_params:
                        # P2P: the pushed model is this site's "upload"
                        wire_tree = plan.perturb_tree(wire_tree, site_id, r)
                    smeta = None
                    if peer_comp is not None:   # quantize the P2P push too
                        wire_tree, smeta = peer_comp.encode(wire_tree)
                    peer.send_model(tuple(asg["addresses"][str(target)]),
                                    wire_tree, r + 1, meta_extra=smeta)
                if asg["is_receiver"][site_id]:
                    imeta, incoming = peer.recv_model(timeout=job.io_timeout)
                    incoming = decode_upload(incoming, imeta)
                    merged, _ = dcml_step(
                        stacking.site_slice(state["params"], 0),
                        jax.tree.map(jnp.asarray, incoming),
                        jax.tree.map(lambda x: x[0, 0], b),
                        jax.tree.map(lambda x: x[0, -1], b))
                    state = {**state,
                             "params": stacking.broadcast_to_sites(merged, 1)}
            # -- local training ------------------------------------------
            if me_active or job.dropout_scenario == "disconnect":
                if dp_on:
                    # pin the carried round counter to the loop round: a
                    # shut-down or late-joining site skips rounds, and its
                    # DP noise stream must skip with it to match the
                    # stacked twin
                    state = {**state, "round": jnp.asarray(r, jnp.int32)}
                state, metrics = local_round(state, b, ri1)
                losses.append(float(np.asarray(metrics["loss"])[0]))
            else:                                    # workstation off
                losses.append(float("nan"))
            # -- centralized exchange: upload → aggregate → download -----
            if agg_addr is not None and me_active:
                # sync barrier rounds are tagged with the loop round; under
                # a buffered scheduler the server finalizes ~S/K times per
                # loop round, so the upload carries the round of the global
                # this site last pulled — the FedBuff staleness anchor
                upload_round = base_round + 1 if buffered else r + 1
                payload = _site_host_tree(state["params"])
                if malicious and plan.flips_params:
                    # same seam as the stacked engines: only the WIRE
                    # payload at round r is perturbed — the site's own
                    # state stays honest, matching the traced round body
                    # where post_exchange overwrites the poisoned rows
                    payload = plan.perturb_tree(payload, site_id, r)
                cmeta = None
                if sa is not None:
                    # mask against the round's *scheduled* barrier peers
                    # (every participant replays masks, so the set needs
                    # no negotiation); the server recovers the pair seeds
                    # of anyone scheduled who never arrives
                    sa_raw += tree_payload_nbytes(payload)
                    participants = np.flatnonzero(masks[r] & pod_members)
                    payload, cmeta = sa.encode(payload, sa_weight,
                                               participants, r)
                    sa_bytes += tree_payload_nbytes(payload)
                    sa_count += 1
                elif comp is not None:
                    # a site that sat out long enough for its reference
                    # global to leave the server's keep_globals window
                    # must re-send dense: under the sync barrier a
                    # stale-acked (unfoldable) delta would leave the
                    # round one upload short of `expected` forever
                    if (reference is not None
                            and upload_round - base_round
                            >= KEEP_GLOBALS_DEFAULT):
                        reference = None
                    payload, cmeta = comp.encode(payload, reference)
                    cmeta["base_round"] = base_round if reference is not None \
                        else 0
                ack = peer.upload(agg_addr, payload, upload_round,
                                  active_sites=int(masks[r][pod_members].sum()),
                                  meta_extra=cmeta)
                if ack.get("rejected"):
                    # server-side sanitation refused the fold.  Drop any
                    # error-feedback residual: compensating next round
                    # for an upload the server never folded would
                    # re-inject the rejected content
                    rejected_uploads += 1
                    if comp is not None:
                        comp.residual = None
                elif ack.get("stale"):
                    # rejected as too stale: the resync below restores a
                    # small staleness for the next upload
                    stale_uploads += 1
                # buffered async has no barrier at all: pull whatever global
                # is current (want=0) rather than waiting for a window that
                # sites which already finished their rounds may never fill;
                # sync keeps the round-(r+1) barrier
                want = 0 if buffered else r + 1
                g, dmeta = peer.download(agg_addr, want, with_meta=True,
                                         down=down, acked_round=down_acked)
                if g is not None:        # None only if no buffer finalized yet
                    if down:
                        # delta broadcast: decode against the held install;
                        # dense (bootstrap / ack mismatch) decodes are
                        # reference-free and restart the stream
                        g = decode_download(g, dmeta, down_ref)
                        down_ref = jax.tree.map(
                            lambda x: np.asarray(x, np.float32), g)
                        down_acked = int(dmeta["round"])
                    base_round = int(dmeta["round"])
                    if comp is not None:     # next delta anchors to this pull
                        reference = jax.tree.map(
                            lambda x: np.asarray(x, np.float32), g)
                    new_params = jax.tree.map(
                        lambda x, gg: jnp.broadcast_to(
                            jnp.asarray(gg).astype(x.dtype)[None], x.shape),
                        state["params"], g)
                    state = {**state, "params": new_params}
                    if local_strategy == "fedprox-local":  # Eq. 2 anchor
                        state = {**state, "strategy": {
                            "global": jax.tree.map(
                                lambda gg: jnp.asarray(gg, jnp.float32), g)}}
            # -- crash-resume checkpoint (end-of-round state) ------------
            if site_store is not None and r % job.ckpt_every == 0:
                tree = {"fl_state": jax.tree.map(np.asarray, state)}
                if comp is not None:
                    tree["reference"] = (reference if reference is not None
                                         else site_zero)
                    tree["residual"] = (comp.residual
                                        if comp.residual is not None
                                        else site_zero)
                if down:
                    tree["down_ref"] = (down_ref if down_ref is not None
                                        else site_zero)
                site_store.save(
                    "state", r, tree,
                    meta={"base_round": base_round,
                          "has_reference": comp is not None
                          and reference is not None,
                          "has_residual": comp is not None
                          and comp.residual is not None,
                          "has_down_ref": down and down_ref is not None,
                          "down_acked": down_acked})
        streams = [c for c in (comp, peer_comp) if c is not None]
        return {"losses": losses, "stale_uploads": stale_uploads,
                "rejected_uploads": rejected_uploads,
                "params": _site_host_tree(state["params"]),
                "upload_payload_bytes":
                    sum(c.encoded_bytes for c in streams) + sa_bytes,
                "upload_raw_bytes":
                    sum(c.raw_bytes for c in streams) + sa_raw,
                "upload_count": sum(c.encodes for c in streams) + sa_count}
    finally:
        if hb is not None:
            hb.stop(leave=True)
        peer.close()


def _site_worker(job, site_id, agg_addr, coord_addr, result_q, rounds,
                 start_round=0):
    """Queue-reporting wrapper around :func:`_run_site` (thread/process)."""
    try:
        result_q.put((site_id, _run_site(job, site_id, agg_addr, coord_addr,
                                         rounds, start_round)))
    except Exception as e:  # noqa: BLE001 — surface worker death to the job
        result_q.put((site_id, {"error": f"{type(e).__name__}: {e}"}))


class _SocketTransport(Transport):
    """Shared round-trip machinery for thread- and process-backed sites.

    Round history is assembled from the workers' reports after the run:
    per-round ``wall_s`` is the run mean (the driver cannot observe
    individual remote rounds), and checkpointing saves the final global
    model only.
    """

    name = "socket"

    def execute(self, job: FederatedJob, rounds: int,
                resume: bool = False) -> JobResult:
        scheduler = resolve_scheduler(job.scheduler)
        strategy = strat_base.get_strategy(job.strategy)
        topo = job.topo
        if job.shard_sites:
            raise ValueError("shard_sites=True shards the stacked "
                             "simulator's [S, N] buffer; socket transports "
                             "distribute sites as processes already — use "
                             "transport='stacked'")
        if job.strategy == "pooled":
            raise ValueError("pooled is a single-process baseline; "
                             "run it on the stacked transport")
        if strategy.needs_pairing and job.max_dropout:
            raise ValueError("gossip under dropout needs coordinated status "
                             "updates; run it on the stacked transport")
        if topo.is_pods and job.strategy not in ("fedavg", "fedprox"):
            raise ValueError(
                "a pods topology needs a centrally-aggregated strategy "
                f"(fedavg/fedprox), not {job.strategy!r}")
        if job.secure_agg:
            intra_s, inter_s = job.tier_schedulers()
            if (isinstance(intra_s, BufferedScheduler)
                    or isinstance(inter_s, BufferedScheduler)):
                raise ValueError(
                    "secure aggregation cancels pairwise masks at a sync "
                    "barrier over the round's scheduled participants; "
                    "buffered-async folds partial subsets, so the masks "
                    "would never cancel")
            if resolve_codec(job.compression).name != "none":
                raise ValueError(
                    "secure aggregation uploads fixed-point masked "
                    "integers; quantizing that ciphertext would corrupt "
                    "the modular sum — use compression='none'")
            if job.strategy not in ("fedavg", "fedprox"):
                raise ValueError(
                    "secure aggregation protects centrally-aggregated "
                    f"uploads (fedavg/fedprox), not {job.strategy!r}")
        _validate_robustness(job)
        _validate_down(job)
        if job.round_deadline_s is not None:
            if topo.is_pods:
                raise ValueError(
                    "round_deadline_s bounds the flat star's sync "
                    "barrier; per-tier pod deadlines are not wired — "
                    "use topology='flat'")
            # the deadline rides the scheduler so the server's watcher
            # thread can read it off its own round policy
            scheduler = SyncScheduler(round_deadline_s=job.round_deadline_s)
        fed = job.federation()
        num_sites = fed.num_sites
        start_round = 0
        resumed_from = None
        initial_global = None
        if resume:
            if not job.checkpoint_dir:
                raise ValueError("run(resume=True) needs checkpoint_dir set")
            resumed_from, initial_global = _socket_resume_point(job,
                                                                num_sites)
            if resumed_from is not None:
                start_round = resumed_from + 1
        down_codec = resolve_codec(job.down_compression)
        down = down_codec.name != "none"
        initial_down = None
        if down and resumed_from is not None:
            # the resumed server must encode deltas against exactly what
            # each resumed site holds, or trajectories diverge
            initial_down = _socket_down_refs(job, resumed_from, num_sites)
        # construct before the workers run so wall_s spans the actual run
        recorder = job.recorder(rounds, num_sites)
        from repro.comms.coordinator import (AggregationServer,
                                             CoordinationServer)
        servers = []
        agg = None
        pod_stack = None
        agg_addr = coord_addr = None
        try:
            if topo.is_pods:
                from repro.comms.pods import PodTransport
                intra_s, inter_s = job.tier_schedulers()
                pod_stack = PodTransport(
                    topo, num_sites, list(fed.case_weights()),
                    job.masks(rounds), intra_s, inter_s,
                    io_timeout=job.io_timeout, wire=job.wire,
                    lease_ttl=job.lease_ttl, start_round=start_round,
                    initial_global=initial_global,
                    ckpt_store=recorder.store,
                    ckpt_every=job.ckpt_every,
                    codec=resolve_codec(job.compression),
                    error_feedback=job.error_feedback,
                    aggregator=job.aggregator,
                    max_upload_norm=job.max_upload_norm,
                    down_codec=down_codec if down else None,
                    initial_down=initial_down,
                    mask_secret=(job.mask_secret if job.secure_agg
                                 else None)).start()
                servers.append(pod_stack)
                agg_addr = pod_stack.site_addrs()
            elif not strategy.needs_pairing and job.strategy != "individual":
                sa_state = None
                if job.secure_agg:
                    from repro.privacy import SecureAggState
                    sa_state = SecureAggState(job.mask_secret, "site",
                                              job.masks(rounds))
                agg = AggregationServer(
                    "127.0.0.1", 0, num_sites=num_sites,
                    case_weights=list(fed.case_weights()),
                    download_timeout=job.io_timeout / 2,
                    scheduler=scheduler, wire=job.wire,
                    lease_ttl=job.lease_ttl, initial_round=start_round,
                    initial_global=initial_global,
                    ckpt_store=recorder.store, ckpt_every=job.ckpt_every,
                    secure_agg=sa_state, aggregator=job.aggregator,
                    max_upload_norm=job.max_upload_norm,
                    down_compression=down_codec if down else None,
                    initial_down=initial_down)
                servers.append(agg)
                agg_addr = agg.addr
            if strategy.needs_pairing:
                coord = CoordinationServer("127.0.0.1", 0,
                                           num_sites=num_sites, seed=job.seed,
                                           wire=job.wire)
                servers.append(coord)
                coord_addr = coord.addr
            results = self._run_workers(job, num_sites, agg_addr, coord_addr,
                                        rounds, start_round)
        finally:
            for s in servers:
                s.stop()
        per_site = dict(results)
        dead = {i: p["error"] for i, p in per_site.items() if "error" in p}
        if pod_stack is not None and pod_stack.leader_errors:
            dead = {**dead, **{f"pod-leader-{p}": e
                               for p, e in pod_stack.leader_errors.items()}}
        if dead:
            # elastic federation (lease_ttl set): a dead SITE already fell
            # out of the barriers via lease expiry — finish without it.
            # Dead infrastructure (a pod-leader relay) still aborts.
            elastic = (job.lease_ttl is not None
                       and all(isinstance(k, int) for k in dead))
            if not elastic:
                raise RuntimeError(f"site workers failed: {dead}")
            if job.verbose:
                print(f"elastic: finishing without failed sites "
                      f"{sorted(dead)}")
        # bytes-on-the-wire accounting: server-side counters are the real
        # framed bytes; site counters are the encoded payload (covers the
        # serverless gossip P2P pushes too)
        codec = resolve_codec(job.compression)
        site_payload = sum(p.get("upload_payload_bytes", 0)
                           for p in per_site.values())
        site_raw = sum(p.get("upload_raw_bytes", 0) for p in per_site.values())
        site_count = sum(p.get("upload_count", 0) for p in per_site.values())
        comm = None
        if pod_stack is not None:            # two-tier: per-tier byte split
            comm = {**pod_stack.comm(codec.name, down_codec.name),
                    "site_payload_bytes": site_payload,
                    "upload_raw_bytes": site_raw}
        elif agg is not None:
            snap = agg.stats.snapshot()
            up_b = snap.get("upload", {}).get("in_bytes", 0)
            down_b = snap.get("download", {}).get("out_bytes", 0)
            comm = {"upload_bytes": up_b,
                    "download_bytes": down_b,
                    "total_bytes": up_b + down_b,
                    "upload_count": snap.get("upload", {}).get("count", 0),
                    "download_count":
                        snap.get("download", {}).get("count", 0),
                    "site_payload_bytes": site_payload,
                    "upload_raw_bytes": site_raw,
                    "compression": codec.name,
                    "down_compression": down_codec.name, "simulated": False}
            down_counters = agg.down_counters
            if down_counters is not None:
                # payload-level split for the ratio math (out_bytes above
                # additionally includes wire framing)
                comm["download_payload_bytes"] = down_counters["encoded"]
                comm["download_raw_bytes"] = down_counters["raw"]
        elif site_count:                     # gossip P2P, compressed
            comm = {"upload_bytes": site_payload,
                    "upload_raw_bytes": site_raw, "download_bytes": 0,
                    "total_bytes": site_payload, "upload_count": site_count,
                    "download_count": 0, "compression": codec.name,
                    "down_compression": "none", "simulated": False}
        exec_rounds = rounds - start_round
        nan_row = [float("nan")] * exec_rounds
        losses = np.stack([per_site[i].get("losses", nan_row)
                           for i in range(num_sites)])
        masks = job.masks(rounds)
        stale = [per_site[i].get("stale_uploads", 0) for i in range(num_sites)]
        # server-authoritative sanitation count (covers decode failures a
        # site never learned the reason for); sites report their own view
        # in the per-site dicts for tests
        rejected = 0
        if pod_stack is not None:
            rejected = pod_stack.rejected_uploads
        elif agg is not None:
            rejected = agg.rejected_uploads
        round_wall = recorder.elapsed / max(exec_rounds, 1)
        for ri, r in enumerate(range(start_round, rounds)):
            extra = {"wall_s": round_wall}
            if r == rounds - 1:
                extra["stale_uploads"] = stale
            recorder.record(r, losses[:, ri], masks[r], extra=extra)
        # the served global: case-weighted mean of the final site models
        # (for FedAvg the sites already hold the last broadcast global);
        # an elastic run folds the survivors only
        acc = StreamingAccumulator()
        cw = fed.case_weights()
        for i in range(num_sites):
            if "params" in per_site[i]:
                acc.fold(per_site[i]["params"], float(cw[i]))
        if not acc.count:
            raise RuntimeError(f"no site produced a final model: {dead}")
        global_params = acc.finalize()
        if recorder.store is not None:       # --checkpoint: final global
            recorder.store.save("global", rounds - 1, global_params)
        return recorder.result(global_params, transport=self.name,
                               scheduler=scheduler.name, comm=comm,
                               resumed_from=resumed_from,
                               rejected_uploads=rejected,
                               privacy=job.privacy_report(rounds))

    def _run_workers(self, job, num_sites, agg_addr, coord_addr, rounds,
                     start_round=0):
        raise NotImplementedError


class ThreadTransport(_SocketTransport):
    """Real TCP round trips, sites driven by in-process threads."""

    name = "thread"

    def _run_workers(self, job, num_sites, agg_addr, coord_addr, rounds,
                     start_round=0):
        q: "queue.Queue" = queue.Queue()
        threads = [threading.Thread(
            target=_site_worker,
            args=(job, i, agg_addr, coord_addr, q, rounds, start_round),
            daemon=True)
            for i in range(num_sites)]
        for t in threads:
            t.start()
        results = [q.get(timeout=job.io_timeout * max(rounds, 1))
                   for _ in range(num_sites)]
        for t in threads:
            t.join(timeout=5)
        return results


class TcpTransport(_SocketTransport):
    """Real TCP round trips, one OS process per site (paper §III.A.3:
    sites identified by IP:port, colocated or spread across machines)."""

    name = "tcp"

    def _run_workers(self, job, num_sites, agg_addr, coord_addr, rounds,
                     start_round=0):
        import multiprocessing as mp
        import queue as queue_mod
        import time as time_mod
        mpctx = mp.get_context("spawn")
        q = mpctx.Queue()
        procs = [mpctx.Process(
            target=_site_worker,
            args=(job, i, agg_addr, coord_addr, q, rounds, start_round),
            daemon=True)
            for i in range(num_sites)]
        for p in procs:
            p.start()
        results: List[Tuple[int, Dict[str, Any]]] = []
        deadline = time_mod.time() + job.io_timeout * max(rounds, 1)
        try:
            while len(results) < num_sites:
                try:
                    results.append(q.get(timeout=2.0))
                except queue_mod.Empty:
                    # a worker that died before reporting would stall the
                    # collection until the deadline — fail fast instead
                    reported = {i for i, _ in results}
                    dead = [i for i, p in enumerate(procs)
                            if not p.is_alive()
                            and p.exitcode not in (0, None)
                            and i not in reported]
                    if dead and q.empty():
                        if job.lease_ttl is not None:
                            # elastic: a killed site never reports — its
                            # lease expiry already unblocked the
                            # survivors, so stand in an error record and
                            # keep collecting the rest
                            for i in dead:
                                results.append((i, {
                                    "error": f"process exited "
                                             f"{procs[i].exitcode}"}))
                            continue
                        raise RuntimeError(
                            f"{len(dead)} site process(es) exited with "
                            f"{[procs[i].exitcode for i in dead]} before "
                            f"reporting")
                    if time_mod.time() > deadline:
                        raise TimeoutError(
                            f"collected {len(results)}/{num_sites} site "
                            f"results before timeout")
        finally:
            for p in procs:
                p.join(timeout=30)
                if p.is_alive():
                    p.terminate()
        return results


_TRANSPORTS = {"stacked": StackedTransport, "thread": ThreadTransport,
               "tcp": TcpTransport}


def resolve_transport(spec: Union[str, Transport, None]) -> Transport:
    if spec is None:
        return StackedTransport()
    if isinstance(spec, Transport):
        return spec
    try:
        return _TRANSPORTS[spec]()
    except KeyError:
        raise KeyError(f"unknown transport {spec!r}; known: "
                       f"{sorted(_TRANSPORTS)}")
