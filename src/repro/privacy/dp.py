"""Traced DP-SGD: per-site / per-example clipping + Gaussian noise.

The noise stream is a *pure function* of ``(dp seed, round, site,
step)`` — each key is derived by folding the round counter carried in
``fl_state["round"]`` (the same carry element every engine threads
through its ``lax.scan``), the site's **global** index and the local
step index into one base key.  That makes the stream identical across
the stacked scan engine, the retired per-round loop and the socket
site workers, and it makes crash-resume replay automatic: a resumed
carry restores the round counter, so the noise picks up exactly where
the dead run stopped — no stream state is checkpointed.

Two clipping granularities (``mode``):

  * ``per-site``    — the site's whole-batch gradient is clipped to
                      ``clip`` and noised with ``N(0, (σ·clip)²)``:
                      site-level DP (one site's data is the unit of
                      privacy — the cross-silo setting of the paper).
  * ``per-example`` — classic Abadi et al. DP-SGD: every example's
                      gradient is clipped to ``clip`` individually, the
                      clipped sum is noised with ``N(0, (σ·clip)²)``
                      and averaged over the batch: example-level DP.

Both run traced (vmap/scan-compatible, no host callbacks), so DP-SGD
compiles into the donated multi-round scan chunks unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

#: Stream-domain tag folded into the base key so the DP noise stream
#: never collides with the round engine's on-device data stream
#: (which folds tag 7 — see ``round_engine._run_sync_scan``).
DP_STREAM_TAG = 13

_MODES = ("per-site", "per-example")


@dataclasses.dataclass(frozen=True)
class DPConfig:
    """DP-SGD knobs.  The mechanism is ON iff ``clip > 0``; σ = 0 then
    means clip-only (no formal guarantee, ε = ∞)."""

    clip: float
    noise_multiplier: float = 0.0
    delta: float = 1e-5
    mode: str = "per-site"
    seed: int = 0                      # noise-stream seed (the job seed)

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"dp mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if self.noise_multiplier < 0:
            raise ValueError("dp noise multiplier must be >= 0")
        if self.noise_multiplier > 0 and self.clip <= 0:
            raise ValueError("DP noise needs a finite sensitivity: set "
                             "dp_clip > 0 alongside dp_noise_multiplier")


def round_key(cfg: DPConfig, round_index) -> jax.Array:
    """Base noise key for one round; ``round_index`` may be traced (it
    is ``fl_state["round"]``, the scan-carried counter)."""
    base = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), DP_STREAM_TAG)
    return jax.random.fold_in(base, round_index)


def site_step_key(rkey: jax.Array, site_index, step_index) -> jax.Array:
    """One (site, local step) slot of the round's noise stream.
    ``site_index`` is the site's GLOBAL id (a 1-site socket worker
    passes its real id via ``FLContext.dp_site_base``), so every
    transport draws the same noise for the same logical site."""
    return jax.random.fold_in(jax.random.fold_in(rkey, site_index),
                              step_index)


def gaussian_noise_like(key: jax.Array, tree: Any, stddev) -> Any:
    """A tree of ``N(0, stddev²)`` fp32 noise, one subkey per leaf."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, x.shape, jnp.float32) * stddev
        for k, x in zip(keys, leaves)])


def _clip_per_example(grads: Any, clip: float) -> Tuple[Any, jax.Array]:
    """Clip each example's gradient (leading axis) to L2 norm ``clip``;
    returns (clipped grads, per-example pre-clip norms)."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)),
                     axis=tuple(range(1, g.ndim)))
             for g in jax.tree.leaves(grads))
    norms = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, clip / (norms + 1e-9))
    clipped = jax.tree.map(
        lambda g: (g.astype(jnp.float32)
                   * scale.reshape((-1,) + (1,) * (g.ndim - 1))).astype(g.dtype),
        grads)
    return clipped, norms


def dp_gradients(loss_fn: Callable, params: Any, batch: Any,
                 key: jax.Array, cfg: DPConfig
                 ) -> Tuple[Any, jax.Array, Any, jax.Array]:
    """DP-SGD gradient of ``loss_fn(params, batch) -> (loss, metrics)``.

    Returns ``(grads, loss, metrics, grad_norm)`` where ``grads`` is the
    clipped (+noised when σ > 0) gradient and ``grad_norm`` reports the
    pre-clip norm (per-site) or the mean per-example norm (per-example).
    """
    from repro.optim import clip_by_global_norm
    if cfg.mode == "per-site":
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, cfg.clip)
        stddev = cfg.noise_multiplier * cfg.clip
    else:
        # metrics/loss from one plain forward (the vmapped per-example
        # grads below would otherwise only yield per-example losses)
        loss, metrics = loss_fn(params, batch)

        def one(ex):
            exb = jax.tree.map(lambda x: x[None], ex)
            return jax.grad(lambda p: loss_fn(p, exb)[0])(params)

        per_ex = jax.vmap(one)(batch)
        clipped, norms = _clip_per_example(per_ex, cfg.clip)
        bsz = norms.shape[0]
        grads = jax.tree.map(lambda g: jnp.sum(g, axis=0) / bsz, clipped)
        gnorm = jnp.mean(norms)
        # noise calibrated to the clipped SUM's sensitivity, then the
        # same 1/B averaging the sum received
        stddev = cfg.noise_multiplier * cfg.clip / bsz
    if cfg.noise_multiplier > 0:
        noise = gaussian_noise_like(key, grads, stddev)
        grads = jax.tree.map(
            lambda g, n: (g.astype(jnp.float32) + n).astype(g.dtype),
            grads, noise)
    return grads, loss, metrics, gnorm
