"""Rényi (moments) accountant for the Gaussian mechanism.

The DP-SGD noise added in :mod:`repro.privacy.dp` is the Gaussian
mechanism on an L2-clipped gradient: sensitivity ``C`` (the clip norm),
noise ``N(0, (σ·C)²)`` per step.  Its Rényi divergence at order α is
the closed form (Mironov 2017, Prop. 7)

    RDP(α) = α / (2 σ²)

and RDP composes additively over the ``T = rounds × local_steps``
mechanism invocations each site performs, so the whole run costs
``T·α/(2σ²)`` at every order.  The (ε, δ) guarantee is the standard
RDP→DP conversion minimized over a grid of orders:

    ε(δ) = min_α  T·α/(2σ²) + log(1/δ)/(α − 1)

That minimum has an analytic optimum (∂/∂α = 0 at
``α* = 1 + sqrt(2σ²·log(1/δ)/T)``):

    ε* = T/(2σ²) + sqrt(2·T·log(1/δ))/σ

kept here as :func:`analytic_gaussian_epsilon` — the independent
reference the tests check the grid accountant against.

Scope: this accounts the *full-batch* Gaussian mechanism (sampling rate
q = 1 — every site uses its whole round batch every step, there is no
Poisson subsampling in the data pipeline), which upper-bounds any
subsampled variant.  ε is **per site**: each site's data participates
in at most T noisy steps regardless of dropout schedule.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

#: Default RDP orders: dense near 1 (where the optimum lands for small
#: T/σ² budgets), then a geometric tail for very private runs.
DEFAULT_ORDERS = np.concatenate([
    np.linspace(1.01, 12.0, 441),
    np.linspace(12.5, 63.5, 103),
    np.array([128.0, 256.0, 512.0, 1024.0]),
])


def rdp_gaussian(noise_multiplier: float, steps: int,
                 orders: np.ndarray) -> np.ndarray:
    """RDP ε(α) of ``steps`` composed Gaussian mechanisms at σ=noise_multiplier."""
    if noise_multiplier <= 0:
        raise ValueError("RDP of the Gaussian mechanism needs σ > 0")
    orders = np.asarray(orders, np.float64)
    return steps * orders / (2.0 * noise_multiplier ** 2)


def gaussian_epsilon(noise_multiplier: float, steps: int, delta: float,
                     orders: Optional[Sequence[float]] = None) -> float:
    """(ε at the given δ) for ``steps`` Gaussian-mechanism invocations,
    via grid-minimized RDP→DP conversion.  Returns ``inf`` for σ = 0
    (no noise, no guarantee) and 0.0 for steps = 0."""
    if steps <= 0:
        return 0.0
    if noise_multiplier <= 0:
        return float("inf")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    alphas = np.asarray(DEFAULT_ORDERS if orders is None else orders,
                        np.float64)
    alphas = alphas[alphas > 1.0]
    eps = rdp_gaussian(noise_multiplier, steps, alphas) \
        + math.log(1.0 / delta) / (alphas - 1.0)
    return float(np.min(eps))


def analytic_gaussian_epsilon(noise_multiplier: float, steps: int,
                              delta: float) -> float:
    """Closed-form optimum of the RDP→DP objective over continuous α —
    the analytic reference the grid accountant must match."""
    if steps <= 0:
        return 0.0
    if noise_multiplier <= 0:
        return float("inf")
    return (steps / (2.0 * noise_multiplier ** 2)
            + math.sqrt(2.0 * steps * math.log(1.0 / delta))
            / noise_multiplier)
