"""Rényi (moments) accountant for the Gaussian mechanism.

The DP-SGD noise added in :mod:`repro.privacy.dp` is the Gaussian
mechanism on an L2-clipped gradient: sensitivity ``C`` (the clip norm),
noise ``N(0, (σ·C)²)`` per step.  Its Rényi divergence at order α is
the closed form (Mironov 2017, Prop. 7)

    RDP(α) = α / (2 σ²)

and RDP composes additively over the ``T = rounds × local_steps``
mechanism invocations each site performs, so the whole run costs
``T·α/(2σ²)`` at every order.  The (ε, δ) guarantee is the standard
RDP→DP conversion minimized over a grid of orders:

    ε(δ) = min_α  T·α/(2σ²) + log(1/δ)/(α − 1)

That minimum has an analytic optimum (∂/∂α = 0 at
``α* = 1 + sqrt(2σ²·log(1/δ)/T)``):

    ε* = T/(2σ²) + sqrt(2·T·log(1/δ))/σ

kept here as :func:`analytic_gaussian_epsilon` — the independent
reference the tests check the grid accountant against.

Poisson client sampling (``FederatedJob(sample="poisson:q")`` — each
site independently scheduled with probability q per round, the model
:mod:`repro.core.sampling` implements) composes with per-site DP as the
*subsampled* Gaussian mechanism: a site's data only enters rounds the
sampler schedules it for, and privacy amplification by subsampling
tightens each invocation's RDP from ``α/(2σ²)`` to the
Mironov–Talwar–Zhang integer-order bound

    RDP_q(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k
                                         · e^{(k²−k)/(2σ²)}

(:func:`rdp_subsampled_gaussian`; at q = 1 only the k = α term
survives and the bound reduces to the dense ``α/(2σ²)`` exactly).
``gaussian_epsilon(..., sampling_rate=q)`` minimizes over integer
orders in that regime, and is never larger than the unsampled ε —
the property ``tests/test_privacy.py`` pins.  ``uniform:K`` sampling
is NOT Poisson (inclusions anti-correlate); the accountant
conservatively charges it at q = 1.

Without client sampling this accounts the *full-batch* Gaussian
mechanism (sampling rate q = 1 — every scheduled site uses its whole
round batch every step, there is no Poisson subsampling in the data
pipeline), which upper-bounds any subsampled variant.  ε is **per
site**: each site's data participates in at most T noisy steps
regardless of dropout schedule.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

#: Default RDP orders: dense near 1 (where the optimum lands for small
#: T/σ² budgets), then a geometric tail for very private runs.
DEFAULT_ORDERS = np.concatenate([
    np.linspace(1.01, 12.0, 441),
    np.linspace(12.5, 63.5, 103),
    np.array([128.0, 256.0, 512.0, 1024.0]),
])


def rdp_gaussian(noise_multiplier: float, steps: int,
                 orders: np.ndarray) -> np.ndarray:
    """RDP ε(α) of ``steps`` composed Gaussian mechanisms at σ=noise_multiplier."""
    if noise_multiplier <= 0:
        raise ValueError("RDP of the Gaussian mechanism needs σ > 0")
    orders = np.asarray(orders, np.float64)
    return steps * orders / (2.0 * noise_multiplier ** 2)


#: Integer RDP orders for the subsampled regime (the closed-form bound
#: above holds at integer α; fractional orders need the continued-
#: fraction machinery we deliberately avoid).
SUBSAMPLED_ORDERS = np.arange(2, 257)


def rdp_subsampled_gaussian(sampling_rate: float, noise_multiplier: float,
                            steps: int, orders: np.ndarray) -> np.ndarray:
    """RDP ε(α) of ``steps`` composed *Poisson-subsampled* Gaussian
    mechanisms at integer orders — the Mironov–Talwar–Zhang bound.

    Per invocation, with q = sampling_rate and σ = noise_multiplier:

        RDP_q(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k) (1−q)^{α−k} q^k
                                             · e^{(k²−k)/(2σ²)}

    evaluated in log space (``lgamma`` binomials + logsumexp), so large
    orders and tiny rates stay finite.  q = 1 collapses to the dense
    ``α/(2σ²)`` exactly; q = 0 gives 0 (the site never participates).
    """
    if noise_multiplier <= 0:
        raise ValueError("RDP of the Gaussian mechanism needs σ > 0")
    if not 0.0 <= sampling_rate <= 1.0:
        raise ValueError(f"sampling_rate must be in [0, 1], got "
                         f"{sampling_rate}")
    orders = np.asarray(orders)
    if not np.all(orders == orders.astype(np.int64)) or np.any(orders < 2):
        raise ValueError("the subsampled bound needs integer orders >= 2")
    q, sigma = float(sampling_rate), float(noise_multiplier)
    if q == 1.0:
        return rdp_gaussian(sigma, steps, orders)
    out = np.empty(len(orders), np.float64)
    log_q = math.log(q) if q > 0 else -math.inf
    log_1mq = math.log1p(-q)
    for i, a in enumerate(orders.astype(np.int64)):
        terms = [math.lgamma(a + 1) - math.lgamma(k + 1)
                 - math.lgamma(a - k + 1)
                 + k * log_q + (a - k) * log_1mq
                 + (k * k - k) / (2.0 * sigma * sigma)
                 for k in range(a + 1)]
        m = max(terms)
        log_a = m + math.log(sum(math.exp(t - m) for t in terms))
        out[i] = steps * max(log_a, 0.0) / (a - 1)
    return out


def gaussian_epsilon(noise_multiplier: float, steps: int, delta: float,
                     orders: Optional[Sequence[float]] = None,
                     sampling_rate: float = 1.0) -> float:
    """(ε at the given δ) for ``steps`` Gaussian-mechanism invocations,
    via grid-minimized RDP→DP conversion.  Returns ``inf`` for σ = 0
    (no noise, no guarantee) and 0.0 for steps = 0.

    ``sampling_rate < 1`` switches to the Poisson-subsampled bound
    (:func:`rdp_subsampled_gaussian`) over the integer-order grid —
    privacy amplification from per-round client sampling."""
    if steps <= 0:
        return 0.0
    if noise_multiplier <= 0:
        return float("inf")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    dense = None
    if sampling_rate >= 1.0 or orders is None:
        alphas = np.asarray(DEFAULT_ORDERS if orders is None else orders,
                            np.float64)
        alphas = alphas[alphas > 1.0]
        eps = rdp_gaussian(noise_multiplier, steps, alphas) \
            + math.log(1.0 / delta) / (alphas - 1.0)
        dense = float(np.min(eps))
        if sampling_rate >= 1.0:
            return dense
    # subsampled regime: the closed-form amplification bound holds at
    # integer orders only, whose grid can miss the fractional-order
    # optimum near q = 1 — but the dense (q = 1) accounting always
    # upper-bounds the subsampled mechanism, so take the tighter of the
    # two valid bounds (this keeps ε monotone: sampled ≤ unsampled)
    alphas = np.asarray(SUBSAMPLED_ORDERS if orders is None else orders,
                        np.float64)
    alphas = alphas[alphas >= 2.0]
    eps = rdp_subsampled_gaussian(sampling_rate, noise_multiplier, steps,
                                  alphas) \
        + math.log(1.0 / delta) / (alphas - 1.0)
    sub = float(np.min(eps))
    return sub if dense is None else min(sub, dense)


def analytic_gaussian_epsilon(noise_multiplier: float, steps: int,
                              delta: float) -> float:
    """Closed-form optimum of the RDP→DP objective over continuous α —
    the analytic reference the grid accountant must match."""
    if steps <= 0:
        return 0.0
    if noise_multiplier <= 0:
        return float("inf")
    return (steps / (2.0 * noise_multiplier ** 2)
            + math.sqrt(2.0 * steps * math.log(1.0 / delta))
            / noise_multiplier)
