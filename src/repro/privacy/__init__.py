"""Privacy tier: traced DP-SGD + dropout-robust secure aggregation.

Two composable mechanisms (threat model in ``docs/architecture.md``):

  * :mod:`repro.privacy.dp` — per-site / per-example gradient clipping
    + Gaussian noise inside the site update, traced so it compiles into
    the multi-round scan engine; noise keys are a pure function of
    (seed, round, site, step), so every transport and every resume
    replays the same stream.
  * :mod:`repro.privacy.accountant` — Rényi (moments) accounting of the
    composed Gaussian mechanism, surfaced as ``JobResult.privacy``.
  * :mod:`repro.privacy.secure_agg` — pairwise additive masks in
    fixed-point int64 over the ``Peer`` wire (``__masked__`` payloads),
    cancelling exactly in the server's integer fold, with seed-escrow
    recovery for dropped/lease-expired sites at both tiers.
"""
from repro.privacy.accountant import (analytic_gaussian_epsilon,
                                      gaussian_epsilon,
                                      rdp_subsampled_gaussian)
from repro.privacy.dp import (DPConfig, dp_gradients, gaussian_noise_like,
                              round_key, site_step_key)
from repro.privacy.secure_agg import (FRAC_BITS, SecureAggClient,
                                      SecureAggState, is_masked,
                                      masked_values)

__all__ = [
    "DPConfig", "dp_gradients", "gaussian_noise_like", "round_key",
    "site_step_key", "gaussian_epsilon", "analytic_gaussian_epsilon",
    "rdp_subsampled_gaussian",
    "FRAC_BITS", "SecureAggClient", "SecureAggState", "is_masked",
    "masked_values",
]
