"""Additive-mask secure aggregation in fixed-point integer arithmetic.

Bonawitz-style pairwise masking over the existing ``Peer`` wire: every
scheduled site ``i`` encodes its weighted upload in fixed point,

    y_i = round(w_i · x_i · 2^F)            (int64, F = 32 frac bits)

and adds, for every *other* scheduled participant ``j`` of the round,
a pairwise mask stream ``m_ij`` (derived from a shared per-pair seed +
the round index) with antisymmetric sign:

    u_i = y_i + Σ_{j>i} m_ij − Σ_{j<i} m_ij      (mod 2^64)

The server folds the ``u_i`` integers at weight 1 — an exact wraparound
sum, so every mask cancels pairwise and the total equals
``Σ w_i x_i · 2^F`` exactly; dividing by ``2^F · Σ w_i`` (the per-site
weights ride the *metadata*, which is public) recovers the FedAvg
global to fixed-point precision (~2⁻³² relative).  No individual
``u_i`` is distinguishable from uniform without the pair seeds, so the
server learns only the sum.

**Dropout recovery** (the Algorithm-2 / lease-expiry path): masks only
cancel if every scheduled site's upload arrives.  When the barrier
closes with sites missing — churned out by the availability schedule's
replay mismatch, crashed mid-upload, or lease-expired — the server
reconstructs, per missing site ``d``, the net mask the *folded* sites
applied against ``d`` and subtracts it:

    Σ_folded u_i  −  Σ_{i folded} sign(i, d) · m_id   =   Σ_folded y_i

This stands in for Bonawitz et al.'s threshold secret-sharing
reconstruction: the per-pair seeds here are derived from the job's
shared wire secret (seed escrow at the aggregation point) rather than
Shamir shares — same recovery semantics, simpler key management, and
the honest-but-curious server still never sees a plaintext model
(it reconstructs mask *sums* for dropped pairs, not per-site models;
a server colluding with the seed escrow can unmask, which is the
documented trust boundary — see docs/architecture.md).

The same construction runs at two tiers: flat / intra-pod (ids = site
ids, participants = the round's scheduled sites in the pod) and
cross-pod (ids = pod ids, participants = the round's active pods, the
leaders masking their partials against the root).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Iterable, List, Sequence, Set, Tuple

import jax
import numpy as np

from repro.comms.codec import MaskedTensor

#: Fixed-point fractional bits.  Headroom: |Σ w·x·2^32| stays far under
#: 2^63 for normalized weights and O(1) parameters, and the round-trip
#: quantization error (~2⁻³² relative) is well inside the fp32 noise of
#: an unmasked fold.
FRAC_BITS = 32

_SCHEME = "pairwise-v1"


def _pair_rng(secret: str, tier: str, a: int, b: int,
              round_index: int) -> np.random.Generator:
    """The (i, j) pair's per-round mask stream, derived from the shared
    job secret.  Both endpoints (and the recovery path) regenerate it
    bit-identically; the 128-bit Philox key comes from a hash over the
    unordered pair + the ABSOLUTE round index, so no stream is ever
    reused across rounds or pairs."""
    lo, hi = (a, b) if a <= b else (b, a)
    h = hashlib.sha256(
        f"{_SCHEME}|{secret}|{tier}|{lo}|{hi}|{round_index}".encode()
    ).digest()
    return np.random.Generator(
        np.random.Philox(key=int.from_bytes(h[:16], "little")))


def _pair_stream(secret: str, tier: str, a: int, b: int, round_index: int,
                 n: int) -> np.ndarray:
    """``n`` uniform uint64 mask words for the pair (order-insensitive)."""
    return _pair_rng(secret, tier, a, b, round_index).integers(
        0, 2 ** 64 - 1, size=n, dtype=np.uint64, endpoint=True)


def _net_mask(secret: str, tier: str, me: int, others: Iterable[int],
              round_index: int, n: int) -> np.ndarray:
    """The total mask site ``me`` adds: +m(me,j) for j > me, −m for j < me."""
    total = np.zeros(n, np.uint64)
    for j in others:
        j = int(j)
        if j == me:
            continue
        s = _pair_stream(secret, tier, me, j, round_index, n)
        if me < j:
            total += s
        else:
            total -= s
    return total


def _fixed_point(x: np.ndarray, weight: float) -> np.ndarray:
    """``round(w · x · 2^F)`` as a flat uint64 word array (two's
    complement: negatives wrap, the modular sum is still exact)."""
    y = np.round(np.asarray(x, np.float64).reshape(-1)
                 * (weight * float(2 ** FRAC_BITS)))
    return y.astype(np.int64).astype(np.uint64)


class SecureAggClient:
    """Client-side masker for one participant at one tier."""

    def __init__(self, secret: str, tier: str, my_id: int):
        self.secret = str(secret)
        self.tier = str(tier)
        self.my_id = int(my_id)

    def encode(self, tree: Any, weight: float,
               participants: Sequence[int], round_index: int
               ) -> Tuple[Any, Dict[str, Any]]:
        """Masked fixed-point encoding of ``weight · tree`` against the
        round's scheduled ``participants`` (which include ``my_id``).
        Returns (tree of :class:`MaskedTensor`, upload meta)."""
        leaves, treedef = jax.tree.flatten(tree)
        words = [_fixed_point(x, weight) for x in leaves]
        mask = _net_mask(self.secret, self.tier, self.my_id, participants,
                         int(round_index), sum(w.size for w in words))
        out, off = [], 0
        for x, w in zip(leaves, words):
            w += mask[off:off + w.size]
            off += w.size
            out.append(MaskedTensor(
                shape=tuple(np.shape(x)),
                data={"v": w.view(np.int64).reshape(np.shape(x))}))
        meta = {"masked": True, "scheme": _SCHEME, "tier": self.tier,
                "weight": float(weight), "mask_round": int(round_index),
                "frac_bits": FRAC_BITS}
        return jax.tree.unflatten(treedef, out), meta


def is_masked(meta: Dict[str, Any]) -> bool:
    return bool(meta and meta.get("masked"))


def masked_values(tree: Any) -> Any:
    """A decoded ``__masked__`` upload as a tree of uint64 word arrays —
    what the integer-exact :class:`StreamingAccumulator` fold consumes."""
    def conv(mt: MaskedTensor) -> np.ndarray:
        v = np.ascontiguousarray(mt.data["v"])
        return v.view(np.uint64).reshape(mt.shape)
    return jax.tree.map(conv, tree,
                        is_leaf=lambda x: isinstance(x, MaskedTensor))


@dataclasses.dataclass
class SecureAggState:
    """Server-side unmasking state for one aggregation point.

    ``participant_masks`` is the [rounds, N] bool schedule of this
    tier's participants (the Algorithm-2 replay restricted to this
    pod's members, or the active-pod schedule at the root) — the same
    schedule the clients mask against, so scheduled-but-missing ids are
    exactly the pairs whose masks failed to cancel.
    """

    secret: str
    tier: str
    participant_masks: np.ndarray

    def __post_init__(self):
        self.participant_masks = np.asarray(self.participant_masks, bool)
        self.recovered: List[Tuple[int, int]] = []   # (round, missing id)

    def scheduled(self, round_index: int) -> Set[int]:
        return set(np.flatnonzero(
            self.participant_masks[int(round_index)]).tolist())

    def unmask(self, int_tree: Any, round_index: int, folded: Set[int],
               weight_total: float) -> Any:
        """Recover the fp32 weighted mean from the integer fold.

        ``folded`` is the set of participant ids actually summed; for
        every scheduled-but-missing id the pairwise streams are
        regenerated (seed escrow) and the net mask the folded sites
        applied against it is subtracted — a crashed or lease-expired
        site never corrupts the round."""
        leaves, treedef = jax.tree.flatten(int_tree)
        n = sum(int(x.size) for x in leaves)
        folded = {int(i) for i in folded}
        missing = sorted(self.scheduled(round_index) - folded)
        if missing:
            resid = np.zeros(n, np.uint64)
            for d in missing:
                for i in sorted(folded):
                    s = _pair_stream(self.secret, self.tier, i, d,
                                     int(round_index), n)
                    if i < d:
                        resid += s
                    else:
                        resid -= s
                self.recovered.append((int(round_index), d))
            off = 0
            fixed = []
            for x in leaves:
                x = np.asarray(x, np.uint64).reshape(-1)
                fixed.append(x - resid[off:off + x.size])
                off += x.size
            leaves = [f.reshape(o.shape) for f, o in zip(fixed, leaves)]
        if weight_total <= 0:
            raise ValueError("secure-agg finalize with zero folded weight")
        inv = 1.0 / (float(2 ** FRAC_BITS) * float(weight_total))
        out = [(np.asarray(x, np.uint64).view(np.int64).astype(np.float64)
                * inv).astype(np.float32) for x in leaves]
        return jax.tree.unflatten(treedef, out)
