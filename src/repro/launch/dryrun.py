import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder devices.

For each combination this script:
  1. builds the step (federated train round / serve prefill / serve decode)
  2. ``jax.jit(...).lower(*abstract)`` on the single-pod 16x16 mesh AND the
     2x16x16 multi-pod mesh
  3. ``.compile()`` — sharding mismatches / OOM / unsupported collectives
     fail HERE, which is the point
  4. records memory_analysis(), cost_analysis() and the collective-bytes
     breakdown parsed from the compiled HLO into a JSON artifact consumed
     by EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ALIASES, ARCH_IDS, is_skipped
from repro.launch.hlo_analysis import analyze
from repro.launch.roofline import roofline_report
from repro.launch.steps import build

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"


def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            save: bool = True, verbose: bool = True, **kw) -> dict:
    t0 = time.time()
    art = build(arch_id, shape_name, multi_pod=multi_pod, **kw)
    with art.mesh:
        jitted = jax.jit(art.step_fn, in_shardings=art.in_shardings,
                         out_shardings=art.out_shardings)
        lowered = jitted.lower(*art.abstract_inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = analyze(compiled.as_text())
    n_dev = art.mesh.devices.size
    rec = {
        "name": art.name,
        "arch": arch_id,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "devices": int(n_dev),
        "notes": art.notes,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # while-aware HLO analysis (trip-count-corrected; see hlo_analysis.py)
        "flops": hlo.flops,
        "bytes_accessed": hlo.bytes,
        "collective_bytes": {**hlo.collective_bytes, "count": hlo.collective_count},
        # XLA's own (per-body-once) numbers kept as a cross-check
        "xla_cost_flops_once": float(cost.get("flops", 0.0)),
        "xla_cost_bytes_once": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }
    rec["roofline"] = roofline_report(rec)
    if verbose:
        print(f"[dryrun] {art.name}: lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory/device: args {mem.argument_size_in_bytes/2**30:.2f} GiB "
              f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB "
              f"out {mem.output_size_in_bytes/2**30:.2f} GiB")
        print(f"  HLO flops {rec['flops']:.3e}  bytes {rec['bytes_accessed']:.3e}"
              f"  (xla-once: {rec['xla_cost_flops_once']:.2e})")
        print(f"  collectives: { {k: f'{v:.3e}' for k, v in rec['collective_bytes'].items() if v} }")
        r = rec["roofline"]
        print(f"  roofline: compute {r['compute_s']:.4f}s memory {r['memory_s']:.4f}s "
              f"collective {r['collective_s']:.4f}s -> bound: {r['bound']}")
    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        fn = ARTIFACT_DIR / f"dryrun_{arch_id}_{shape_name}_{'2pod' if multi_pod else '1pod'}.json"
        fn.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or alias (default: all)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES), help="default: all")
    ap.add_argument("--multi-pod", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    archs = ([ALIASES.get(args.arch, args.arch)] if args.arch
             else [a for a in ARCH_IDS if a != "sanet_openkbp"])
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    failures, skips = [], []
    for arch_id in archs:
        for shape_name in shapes:
            reason = is_skipped(arch_id, shape_name)
            if reason:
                skips.append((arch_id, shape_name, reason))
                print(f"[skip] {arch_id}:{shape_name} — {reason}")
                continue
            for mp in pods:
                try:
                    run_one(arch_id, shape_name, mp, save=not args.no_save)
                except Exception as e:  # noqa: BLE001 — report all failures at end
                    failures.append((arch_id, shape_name, mp, repr(e)))
                    traceback.print_exc()
    print(f"\n[dryrun] done. {len(failures)} failures, {len(skips)} documented skips.")
    for f in failures:
        print("  FAIL:", f)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
