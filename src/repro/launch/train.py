"""Federated training driver (CPU-runnable end-to-end).

A thin CLI over :class:`repro.api.FederatedJob` — task construction,
strategy, dropout, checkpointing and the round loop all live in the job;
this module only maps arguments onto it.  ``--transport`` switches the
same run between the vmapped single-process simulator and the real TCP
stack (threaded or one-process-per-site), ``--scheduler buffered`` turns
on FedBuff-style buffered-async rounds, and ``--compression int8`` (or
``fp8``/``topk-sparse``) quantizes every upload as an error-feedback
delta (~4× fewer bytes on the wire).  ``--dry-run`` resolves the full
job and prints it without training — the hook the docs check uses to
keep README snippets honest.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --strategy fedavg --sites 8 --rounds 30
  PYTHONPATH=src python -m repro.launch.train --task dose --strategy gcml \
      --sites 5 --rounds 20 --max-dropout 2
  PYTHONPATH=src python -m repro.launch.train --sites 4 --rounds 8 \
      --transport tcp                      # real multi-process FedAvg
  PYTHONPATH=src python -m repro.launch.train --sites 8 --rounds 20 \
      --scheduler buffered --buffer-k 4    # async: aggregate after 4 of 8
  PYTHONPATH=src python -m repro.launch.train --sites 4 --rounds 10 \
      --transport tcp --compression int8   # quantized delta uploads
  PYTHONPATH=src python -m repro.launch.train --sites 4 --rounds 10 \
      --compression int8 --down-compression int8
                                           # quantize BOTH directions
  PYTHONPATH=src python -m repro.launch.train --sites 8 --rounds 40 \
      --chunk-rounds 20 --device-data      # compiled scan chunks with
                                           # on-device batch generation
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.api import FederatedJob, TaskConfig
from repro.comms.transport import WireConfig
from repro.core.session import BufferedScheduler


def run(args) -> dict:
    task = TaskConfig(
        kind=args.task, arch=args.arch, reduced=args.reduced,
        sites=args.sites, batch=args.batch, seq=args.seq,
        volume=(args.volume,) * 3, base_filters=args.base_filters,
        num_levels=args.num_levels,
        heterogeneity=args.het, seed=args.seed)
    # tests may force-quiet a parsed namespace by setting args.verbose
    verbose = getattr(args, "verbose", None)
    if verbose is None:
        verbose = not args.quiet
    scheduler = (BufferedScheduler(buffer_k=args.buffer_k)
                 if args.scheduler == "buffered" else args.scheduler)
    wire = WireConfig(secret=args.auth_secret, tls_cert=args.tls_cert,
                      tls_key=args.tls_key,
                      max_message_size=args.max_message_size)
    job = FederatedJob(
        task=task, strategy=args.strategy, rounds=args.rounds,
        local_steps=args.local_steps, lr=args.lr, prox_mu=args.prox_mu,
        max_dropout=args.max_dropout, dropout_scenario=args.dropout_scenario,
        sample=args.sample, shard_sites=args.shard_sites,
        transport=args.transport, scheduler=scheduler,
        topology=args.topology, pod_dropout=args.pod_dropout,
        compression=args.compression,
        down_compression=args.down_compression,
        error_feedback=not args.no_error_feedback,
        dp_clip=args.dp_clip, dp_noise_multiplier=args.dp_noise_multiplier,
        dp_delta=args.dp_delta, dp_mode=args.dp_mode,
        secure_agg=args.secure_agg, seed=args.seed,
        aggregator=args.aggregator, adversary=args.adversary,
        round_deadline_s=args.round_deadline_s,
        max_upload_norm=args.max_upload_norm,
        wire=wire, lease_ttl=args.lease_ttl,
        round_engine=args.round_engine, chunk_rounds=args.chunk_rounds,
        device_data=args.device_data,
        checkpoint_dir=str(Path(args.out) / "ckpt") if args.checkpoint else None,
        ckpt_every=args.ckpt_every, verbose=verbose)
    if getattr(args, "dry_run", False):
        # resolve everything that could drift (transport/scheduler/codec
        # names, task construction) but skip the training itself
        from repro.api import resolve_transport
        from repro.comms.compression import resolve_codec
        from repro.core.session import resolve_scheduler
        topo = job.topo
        resolved = {
            "dry_run": True, "strategy": job.strategy,
            "task": job.task.kind, "sites": job.task.sites,
            "rounds": job.rounds,
            "transport": resolve_transport(job.transport).name,
            "scheduler": resolve_scheduler(job.scheduler).name,
            "topology": (f"pods:{topo.num_pods}" if topo.is_pods else "flat"),
            "pod_dropout": job.pod_dropout,
            "sample": job.sampler.spec,
            "shard_sites": job.shard_sites,
            "compression": resolve_codec(job.compression).name,
            "down_compression": resolve_codec(job.down_compression).name,
            "error_feedback": job.error_feedback,
            "round_engine": job.round_engine,
            "chunk_rounds": job.chunk_rounds,
            "device_data": job.device_data,
            "dp_clip": job.dp_clip,
            "dp_noise_multiplier": job.dp_noise_multiplier,
            "dp_delta": job.dp_delta, "dp_mode": job.dp_mode,
            "secure_agg": job.secure_agg,
            "aggregator": job.aggregator_spec.spec,
            "adversary": job.adversary,
            "round_deadline_s": job.round_deadline_s,
            "max_upload_norm": job.max_upload_norm,
            "auth": job.wire.secret is not None,
            "tls": job.wire.tls,
            "max_message_size": job.wire.max_message_size,
            "lease_ttl": job.lease_ttl,
            "resume": bool(getattr(args, "resume", False)),
        }
        print(json.dumps(resolved))
        return resolved
    res = job.run(resume=args.resume)
    result = {**res.to_dict(), "strategy": args.strategy}
    if args.out:
        out = Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        (out / f"train_{args.strategy}.json").write_text(
            json.dumps(result, indent=2))
    return result


def make_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--task", default="tokens", choices=["tokens", "dose", "seg"])
    ap.add_argument("--strategy", default="fedavg",
                    choices=["fedavg", "fedprox", "gcml", "individual", "pooled"])
    ap.add_argument("--sites", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--local-steps", type=int, default=1, dest="local_steps")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--volume", type=int, default=16, metavar="D",
                    help="volume tasks (dose/seg): cubic volume edge "
                         "(D, D, D)")
    ap.add_argument("--base-filters", type=int, default=8,
                    dest="base_filters",
                    help="volume tasks: SA-Net channel width (shrink for "
                         "cross-device site counts)")
    ap.add_argument("--num-levels", type=int, default=2, dest="num_levels",
                    help="volume tasks: SA-Net encoder depth")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--prox-mu", type=float, default=0.01, dest="prox_mu")
    ap.add_argument("--het", type=float, default=0.0, help="non-IID heterogeneity")
    ap.add_argument("--max-dropout", type=int, default=0, dest="max_dropout")
    ap.add_argument("--dropout-scenario", default="disconnect",
                    choices=["disconnect", "shutdown"], dest="dropout_scenario")
    ap.add_argument("--sample", default="none", metavar="none|uniform:K|poisson:q",
                    help="cross-device client sampling: schedule only K "
                         "sites (uniform:K) or each site with probability "
                         "q (poisson:q) per round, Eq. 1 reweighted by "
                         "inclusion probability; composes with "
                         "--max-dropout by intersection")
    ap.add_argument("--shard-sites", action="store_true", dest="shard_sites",
                    help="stacked transport: shard the [S, N] site buffer "
                         "across the device mesh and train only the "
                         "sampled rows per round (cross-device scale; "
                         "fedavg/fedprox, sync, compression none/int8)")
    ap.add_argument("--transport", default="stacked",
                    choices=["stacked", "thread", "tcp"])
    ap.add_argument("--scheduler", default="sync", choices=["sync", "buffered"])
    ap.add_argument("--buffer-k", type=int, default=2, dest="buffer_k",
                    help="buffered scheduler: aggregate after K uploads")
    ap.add_argument("--topology", default="flat", metavar="flat|pods:K",
                    help="federation topology: flat star (default) or "
                         "pods:K — two-tier aggregation through K pod "
                         "servers and a root combiner")
    ap.add_argument("--pod-dropout", type=int, default=0, dest="pod_dropout",
                    metavar="N",
                    help="Algorithm-2 churn at the pod tier: up to N whole "
                         "pods offline at once (requires --topology pods:K)")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "fp8", "topk", "topk-sparse",
                             "topk-fixed"],
                    help="quantize uploads (error-feedback deltas); "
                         "topk-fixed = constant-shape top-k that compiles "
                         "under the scan engine")
    ap.add_argument("--down-compression", default="none",
                    dest="down_compression",
                    choices=["none", "int8", "fp8", "topk-fixed"],
                    help="quantize downloads too: the server keeps per-site "
                         "error-feedback references and broadcasts each "
                         "global as a delta against what that site last "
                         "acknowledged (dense bootstrap on join/evict); "
                         "fedavg/fedprox, sync scheduler")
    ap.add_argument("--dp-clip", type=float, default=0.0, dest="dp_clip",
                    metavar="C",
                    help="DP-SGD: clip gradients to L2 norm C inside every "
                         "site update (0 = off)")
    ap.add_argument("--dp-noise-multiplier", type=float, default=0.0,
                    dest="dp_noise_multiplier", metavar="SIGMA",
                    help="DP-SGD: Gaussian noise stddev as a multiple of "
                         "the clip norm (needs --dp-clip > 0)")
    ap.add_argument("--dp-delta", type=float, default=1e-5, dest="dp_delta",
                    help="DP-SGD: the delta the accountant reports "
                         "epsilon at")
    ap.add_argument("--dp-mode", default="per-site", dest="dp_mode",
                    choices=["per-site", "per-example"],
                    help="DP-SGD clipping unit (per-site protects a whole "
                         "site's round contribution)")
    ap.add_argument("--secure-agg", action="store_true", dest="secure_agg",
                    help="mask uploads pairwise (fixed-point int64) so the "
                         "aggregation server only sees their sum; "
                         "thread/tcp transports, sync schedulers, "
                         "compression=none")
    ap.add_argument("--aggregator", default="fedavg",
                    metavar="fedavg|trimmed:f|median|krum:f|normclip:c",
                    help="robust site→global combine rule: coordinate-wise "
                         "trimmed mean / median, krum selection, or "
                         "per-upload L2 norm clipping (fedavg = Eq. 1 "
                         "weighted mean)")
    ap.add_argument("--adversary", default=None,
                    metavar="sign_flip:f|label_flip:f|scale:c:f|noise:s:f",
                    help="deterministic Byzantine harness: f seeded "
                         "malicious sites perturb what they expose to "
                         "aggregation (same sites and perturbations on "
                         "every transport)")
    ap.add_argument("--round-deadline-s", type=float, default=None,
                    dest="round_deadline_s", metavar="SECONDS",
                    help="socket transports: after this long with at least "
                         "one upload folded, close the sync barrier with "
                         "whoever arrived (stragglers are acked stale)")
    ap.add_argument("--max-upload-norm", type=float, default=None,
                    dest="max_upload_norm", metavar="C",
                    help="socket transports: reject uploads with L2 norm "
                         "above C (non-finite uploads are always rejected)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    dest="no_error_feedback",
                    help="disable the client-side quantization residual")
    ap.add_argument("--round-engine", default="auto", dest="round_engine",
                    choices=["auto", "scan", "loop"],
                    help="stacked transport: compiled multi-round lax.scan "
                         "(auto/scan) vs the retired per-round loop")
    ap.add_argument("--chunk-rounds", type=int, default=None,
                    dest="chunk_rounds", metavar="N",
                    help="rounds fused per compiled scan chunk "
                         "(default: auto)")
    ap.add_argument("--device-data", action="store_true", dest="device_data",
                    help="generate synthetic batches on-device inside the "
                         "compiled scan (token tasks)")
    ap.add_argument("--dry-run", action="store_true", dest="dry_run",
                    help="resolve and print the job, skip training")
    ap.add_argument("--auth-secret", default=None, dest="auth_secret",
                    metavar="SECRET",
                    help="socket transports: require an HMAC hello token "
                         "over this shared job secret on every connection")
    ap.add_argument("--tls-cert", default=None, dest="tls_cert",
                    metavar="PEM", help="serve TLS with this certificate "
                                        "(clients pin it)")
    ap.add_argument("--tls-key", default=None, dest="tls_key", metavar="PEM",
                    help="private key for --tls-cert")
    ap.add_argument("--max-message-size", type=int, default=None,
                    dest="max_message_size", metavar="BYTES",
                    help="stream uploads larger than this in chunks "
                         "instead of one frame")
    ap.add_argument("--lease-ttl", type=float, default=None, dest="lease_ttl",
                    metavar="SECONDS",
                    help="elastic membership: expire sites silent for this "
                         "long into the round's dropout accounting")
    ap.add_argument("--resume", action="store_true",
                    help="re-enter a killed job from the newest usable "
                         "checkpoint under --out/ckpt (needs --checkpoint)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--checkpoint", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=10, dest="ckpt_every")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-round progress output")
    return ap


if __name__ == "__main__":
    run(make_parser().parse_args())
