"""Federated training driver (CPU-runnable end-to-end).

Runs real federated rounds — local training, strategy exchange, site
dropout (Algorithm 2) — on synthetic data with controllable non-IID
heterogeneity.  Works for every assigned architecture (``--arch``, full
or ``--reduced``) and for SA-Net tasks via ``--task dose|seg``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --strategy fedavg --sites 8 --rounds 30
  PYTHONPATH=src python -m repro.launch.train --task dose --strategy gcml \
      --sites 5 --rounds 20 --max-dropout 2
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs.base import FederationConfig, MeshConfig
from repro.configs.registry import get_arch
from repro.core import federation as F
from repro.core.dropout import SiteAvailability
from repro.data.synthetic import (DoseTaskGenerator, SegTaskGenerator,
                                  TokenTaskGenerator)
from repro.models import sanet as sanet_mod
from repro.models import transformer as T
from repro.optim import adamw


def build_token_task(args, cfg):
    gen = TokenTaskGenerator(vocab_size=cfg.vocab_size, num_sites=args.sites,
                             heterogeneity=args.het,
                             num_codebooks=cfg.num_codebooks, seed=args.seed)

    def loss_fn(params, batch):
        return T.next_token_loss(params, batch, cfg)

    def logits_fn(params, batch):
        logits, _ = T.forward(params, batch["tokens"], cfg)
        labels = batch["tokens"][:, 1:]
        return logits[:, :-1], labels

    def init_fn(key):
        return T.init(key, cfg)

    def batches(rnd):
        return jax.tree.map(jnp.asarray, gen.stacked_batches(
            rnd, args.local_steps, args.batch, args.seq))

    return loss_fn, logits_fn, init_fn, batches


def build_volume_task(args, kind: str):
    scfg = (sanet_mod.SANetConfig(in_channels=4, out_channels=1, base_filters=8,
                                  num_levels=2, task="dose") if kind == "dose"
            else sanet_mod.SANetConfig(in_channels=2, out_channels=3, base_filters=8,
                                       num_levels=2, task="segmentation"))
    vol = (16, 16, 16)
    if kind == "dose":
        gen = DoseTaskGenerator(volume=vol, num_oars=2, num_sites=args.sites,
                                heterogeneity=args.het, seed=args.seed)
        loss = lambda p, b: sanet_mod.dose_loss(p, b, scfg)
        logits_fn = None
    else:
        gen = SegTaskGenerator(volume=vol, in_channels=2, num_classes=3,
                               num_sites=args.sites, heterogeneity=args.het,
                               seed=args.seed)
        loss = lambda p, b: sanet_mod.segmentation_loss(p, b, scfg)

        def logits_fn(params, batch):
            pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
            return pred, batch["labels"]

    def init_fn(key):
        return sanet_mod.sanet_init(key, scfg)

    def batches(rnd):
        return jax.tree.map(jnp.asarray, gen.stacked_batches(
            rnd, args.local_steps, args.batch))

    return loss, logits_fn, init_fn, batches, scfg


def run(args) -> dict:
    if args.task == "tokens":
        arch = get_arch(args.arch)
        cfg = arch.reduced() if args.reduced else arch.CONFIG
        loss_fn, logits_fn, init_fn, batches = build_token_task(args, cfg)
    else:
        loss_fn, logits_fn, init_fn, batches, _ = build_volume_task(args, args.task)

    fed = FederationConfig(
        num_sites=args.sites, strategy=args.strategy,
        local_steps=args.local_steps, rounds=args.rounds,
        prox_mu=args.prox_mu, max_dropout_sites=args.max_dropout,
        dropout_scenario=args.dropout_scenario)
    mesh_cfg = MeshConfig(sites_per_pod=args.sites, fsdp=16 // args.sites
                          if 16 % args.sites == 0 else 1,
                          data_axis_size=args.sites * (16 // args.sites
                          if 16 % args.sites == 0 else 1))
    ctx = F.FLContext(
        fed=fed, mesh=mesh_cfg, case_weights=jnp.asarray(fed.case_weights()),
        loss_fn=loss_fn, logits_fn=logits_fn,
        optimizer=adamw(args.lr, weight_decay=0.01),
        grad_clip=1.0, dcml_lr=args.lr, hierarchical=False)

    state = F.init_fl_state(ctx, init_fn, jax.random.PRNGKey(args.seed))
    fl_round = jax.jit(F.build_fl_round(ctx))
    avail = SiteAvailability(args.sites, args.max_dropout, seed=args.seed)
    rng = np.random.default_rng(args.seed)

    store = CheckpointStore(Path(args.out) / "ckpt") if args.checkpoint else None
    history = []
    t0 = time.time()
    for rnd in range(args.rounds):
        b = batches(rnd)
        ri = F.make_round_inputs(ctx, avail, rng, rnd)
        if ctx.fed.strategy == "gcml":
            ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
            ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
        state, metrics = fl_round(state, b, ri)
        mean_loss = float(jnp.mean(metrics["loss"]))
        history.append({"round": rnd, "loss": mean_loss,
                        "active": int(np.sum(ri["active"])),
                        "per_site_loss": np.asarray(metrics["loss"]).tolist()})
        if args.verbose and (rnd % max(args.rounds // 10, 1) == 0 or rnd == args.rounds - 1):
            print(f"round {rnd:4d} loss {mean_loss:.4f} active {int(np.sum(ri['active']))}/{args.sites}")
        if store and rnd % args.ckpt_every == 0:
            store.save("global", rnd, F.global_model(state, ctx))
    result = {"history": history, "wall_s": time.time() - t0,
              "final_loss": history[-1]["loss"], "strategy": args.strategy}
    if args.out:
        out = Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        (out / f"train_{args.strategy}.json").write_text(json.dumps(result, indent=2))
    return result


def make_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--task", default="tokens", choices=["tokens", "dose", "seg"])
    ap.add_argument("--strategy", default="fedavg",
                    choices=["fedavg", "fedprox", "gcml", "individual", "pooled"])
    ap.add_argument("--sites", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--local-steps", type=int, default=1, dest="local_steps")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--prox-mu", type=float, default=0.01, dest="prox_mu")
    ap.add_argument("--het", type=float, default=0.0, help="non-IID heterogeneity")
    ap.add_argument("--max-dropout", type=int, default=0, dest="max_dropout")
    ap.add_argument("--dropout-scenario", default="disconnect",
                    choices=["disconnect", "shutdown"], dest="dropout_scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--checkpoint", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=10, dest="ckpt_every")
    ap.add_argument("--verbose", action="store_true", default=True)
    return ap


if __name__ == "__main__":
    run(make_parser().parse_args())
