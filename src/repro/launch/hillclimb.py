import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: lower+compile named variants of the three chosen
(arch × shape) pairs and record the roofline deltas.

Each variant is a (description, build-kwargs) pair; results append to
``benchmarks/artifacts/hillclimb.json`` with hypothesis / before / after
for EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.hillclimb --pair deepseek
"""
import argparse
import json
from pathlib import Path

from repro.launch.dryrun import run_one

ART = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

# variant grids per hillclimb pair; "hypothesis" is written before measuring
PAIRS = {
    "deepseek": {
        "arch": "deepseek_v2_236b", "shape": "train_4k",
        "variants": [
            ("baseline", "paper-faithful FedAvg round (local_steps=1, micro=4, "
             "no activation-sharding hints) — the reproduction reference",
             {"kw": {"hints": False}}),
            ("qkv_hints", "H: 32 TB/round of f32 score all-reduces (measured "
             "via HLO triage: [mb,8h,512,4096] x30208) come from the MLA "
             "nope/rope concat losing head sharding; constraining q/k/v to "
             "head-sharded makes score contractions device-local -> "
             "collective ~6x down", {}),
            ("micro8", "H(prior iteration, refuted): collective was per-"
             "microbatch grad syncs; micro 4->8 should halve it. Re-test on "
             "top of hints.", {"kw": {"microbatch": 8}}),
            ("local4", "H: with collectives fixed, FedAvg full-param exchange "
             "amortizes over local_steps=4; per-STEP terms (divide by 4) "
             "should drop only in the exchange share",
             {"kw": {"local_steps": 4}}),
            ("gather_moe", "H(refuted decisively in iteration 1): token-"
             "gather MoE gathers [T,k,D,F] weight copies -> 2 TiB/device. "
             "Not re-run; recorded for the log.", None),
        ],
    },
    "rwkv6": {
        "arch": "rwkv6_7b", "shape": "train_4k",
        "variants": [
            ("baseline", "paper-faithful FedAvg round, 16 sites, TP=16, "
             "micro=8, no hints", {"kw": {"hints": False}}),
            ("qkv_hints", "H: same score-resharding class as deepseek does "
             "not apply (attention-free) -> expect no change from hints",
             {}),
            ("micro16", "H: grad reductions per microbatch dominate "
             "collectives; micro 8->16 (single sync per site step)",
             {"kw": {"microbatch": 16}}),
            ("fsdp2", "H(refuted): sites=8 x fsdp=2 halves sites but "
             "doubles per-site tokens -> per-device collective GREW 2x "
             "(26->54 s). Lesson: collective here scales with tokens/device, "
             "not site count.", None),
            ("tp4", "H(from HLO triage: [mb,4096,14336] activation "
             "all-reduce/gathers x64 = row-parallel TP traffic): TP=16 is "
             "overkill for 7.6B; refactor the FL view to (site=16, fsdp=4, "
             "model=4) -> per-device activation shards (batch/4) and psum "
             "group (4 vs 16) both shrink -> collective ~3-4x down",
             {"mesh": {"sites_per_pod": 16, "fsdp": 4, "model_parallel": 4}}),
        ],
    },
    "qwen3": {
        "arch": "qwen3_8b", "shape": "train_4k",
        "variants": [
            ("baseline", "paper-faithful FedAvg round, 16 sites, micro=4, "
             "no hints", {"kw": {"hints": False}}),
            ("qkv_hints", "H: qwen3 GQA (32q/8kv heads, head concat-free) "
             "already head-shards cleanly; hints should be ~neutral", {}),
            ("micro8", "H: memory term ~ params re-read per microbatch "
             "(8.2B bf16 x fwd+bwd x n_micro); micro 4->8 cuts param "
             "traffic share ~2x", {"kw": {"microbatch": 8}}),
            ("micro16", "H: continues 8->16 until activation carries "
             "dominate", {"kw": {"microbatch": 16}}),
        ],
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=list(PAIRS) + ["all"], default="all")
    args = ap.parse_args()
    pairs = list(PAIRS) if args.pair == "all" else [args.pair]
    log_path = ART / "hillclimb.json"
    log = json.loads(log_path.read_text()) if log_path.exists() else {}
    for pname in pairs:
        spec = PAIRS[pname]
        entries = log.setdefault(pname, [])
        for vname, hypothesis, opts in spec["variants"]:
            if opts is None:
                entries.append({"variant": vname, "hypothesis": hypothesis,
                                "skipped": "recorded from iteration 1"})
                continue
            kw = dict(opts.get("kw", {}))
            if "mesh" in opts:
                from repro.configs.base import MeshConfig
                kw["override_mesh"] = MeshConfig(**opts["mesh"])
            print(f"\n=== {pname}:{vname} ===\n  {hypothesis}")
            rec = run_one(spec["arch"], spec["shape"], multi_pod=False,
                          save=False, **kw)
            entries.append({
                "variant": vname, "hypothesis": hypothesis,
                "roofline": rec["roofline"],
                "collectives": rec["collective_bytes"],
                "memory_gib": (rec["memory"]["argument_bytes"]
                               + rec["memory"]["temp_bytes"]
                               + rec["memory"]["output_bytes"]) / 2 ** 30,
                "flops": rec["flops"], "bytes": rec["bytes_accessed"],
            })
            log_path.write_text(json.dumps(log, indent=2))
    print("\nhillclimb log written to", log_path)


if __name__ == "__main__":
    main()
