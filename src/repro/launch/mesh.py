"""Mesh construction.

``make_production_mesh`` is the assignment-prescribed mesh (verbatim).
``make_fl_mesh`` derives the federated view of the SAME devices by
factorizing the 16-wide "data" axis into ("site", "fsdp"): FL sites are
contiguous device blocks; cross-site traffic (the paper's gRPC layer)
rides the mesh axes that separate blocks.  See DESIGN.md §3.

Everything is a function — importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import MeshConfig


def make_site_mesh(num_devices: int | None = None) -> Mesh:
    """One-axis ``("site",)`` mesh over the process's devices — the
    cross-device simulator's mesh (``FederatedJob(shard_sites=True)``).

    Unlike :func:`make_production_mesh` this adapts to whatever devices
    exist (1 CPU in tests, N chips in production): the sharded round
    engine partitions its ``[S, …]`` per-site state over this axis, so
    site capacity scales with device count instead of device memory.
    ``num_devices`` takes a prefix of ``jax.devices()`` (tests pin 1).
    """
    devs = jax.devices()
    if num_devices is not None:
        if not 1 <= num_devices <= len(devs):
            raise ValueError(f"num_devices={num_devices} outside "
                             f"[1, {len(devs)}] available devices")
        devs = devs[:num_devices]
    return Mesh(np.array(devs), ("site",))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_fl_mesh(cfg: MeshConfig) -> Mesh:
    """FL view of the production mesh's devices.

    single-pod:  (site, fsdp, model)          site*fsdp == 16
    multi-pod :  (pod, site, fsdp, model)     total sites = pods*site
    """
    base = make_production_mesh(multi_pod=cfg.multi_pod)
    cfg.validate_for_pod(base.devices.size // (cfg.num_pods if cfg.multi_pod else 1))
    s, f, m = cfg.sites_per_pod, cfg.fsdp, cfg.model_parallel
    if cfg.multi_pod:
        devs = base.devices.reshape(cfg.num_pods, s, f, m)
        return Mesh(devs, ("pod", "site", "fsdp", "model"))
    devs = base.devices.reshape(s, f, m)
    return Mesh(devs, ("site", "fsdp", "model"))


def site_axes(cfg: MeshConfig):
    """Mesh axes the stacked-site param axis is sharded over."""
    return ("pod", "site") if cfg.multi_pod else ("site",)


def batch_axes(cfg: MeshConfig):
    """Mesh axes a *serving* batch dim is sharded over (no site axis in
    serving: the aggregated global model serves)."""
    return ("pod", "site", "fsdp") if cfg.multi_pod else ("site", "fsdp")
