"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e targets):

    compute    = HLO_FLOPs            / (chips · 197e12 FLOP/s bf16)
    memory     = HLO_bytes_accessed   / (chips · 819e9 B/s HBM)
    collective = Σ collective bytes   / (chips · 50e9 B/s ICI per link)

``cost_analysis()`` supplies FLOPs and bytes; collective bytes are parsed
from the compiled HLO text by summing operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Also reports MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy).
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[16,1024,512] all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<ty>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _elem_bytes(ty: str, shape: str) -> float:
    n = 1
    if shape:
        for d in shape.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-operand bytes per collective kind from HLO text.

    Bytes are per-device program bytes (the HLO is the per-device SPMD
    program), i.e. what each chip moves through its links.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # `-start` variants appear as e.g. all-gather-start; regex matches stem
        if m.group("ty"):
            b = _elem_bytes(m.group("ty"), m.group("shape"))
        else:
            # tuple-shaped result: sum elements (take first half for start ops
            # which carry (operand, result) pairs — conservative upper bound)
            lhs = line.split("=", 1)[1]
            paren = lhs[: lhs.find(op)]
            b = sum(_elem_bytes(t, s) for t, s in _TUPLE_ELEM_RE.findall(paren))
        out[op] += b
        out["count"] += 1
    return out


def roofline_report(rec: dict) -> dict:
    """Compute the three terms from a dry-run record (see dryrun.py)."""
    chips = rec["devices"]
    flops = rec["flops"]
    byts = rec["bytes_accessed"]
    coll = rec["collective_bytes"]
    coll_total = sum(v for k, v in coll.items() if k != "count")
    # cost_analysis() analyzes the per-device SPMD module (verified against
    # a hand-counted sharded matmul), as does the HLO text — so every term
    # is already per-chip: divide by per-chip peak rates only.
    del chips
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms, key=terms.get).replace("_s", "")
    return {**terms, "bound": bound, "collective_total_bytes": coll_total}


def model_flops(n_params: int, n_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N_active·D for serving."""
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens
