"""Partition-spec rule engine.

Maps every parameter / batch / cache leaf to a ``PartitionSpec`` on the
FL mesh, with divisibility-aware fallbacks (e.g. granite's vocab 49155
is indivisible by 16, so the embedding falls back to sharding d_model).

Scheme (megatron/MaxText-style tensor parallel + FSDP + expert parallel):

  * column-parallel matrices  [in, out]  -> (fsdp, model)
  * row-parallel matrices     [in, out]  -> (model, fsdp)
  * expert-parallel tensors   [E, in, out] -> (model, fsdp, None)
  * embeddings                [V, D]     -> (model, fsdp)  (vocab parallel)
  * vectors / small LoRA factors          -> replicated
  * the federated site axis (stacked leading dim) -> ("pod","site")

XLA's SPMD partitioner propagates activation shardings from these seeds.
"""
from __future__ import annotations

from math import prod
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig

Axis = Union[str, Tuple[str, ...], None]

# column-parallel (shard output dim over "model")
_COL = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_b", "w_gate", "w_up",
        "w_r", "w_k", "w_v", "w_g", "w_in", "w_bcdt", "w_dt", "lm_head",
        "ts_w1", "decay_w1", "w1"}
# row-parallel (shard input dim over "model")
_ROW = {"wo", "w_down", "w_out", "w2"}
# replicated small factors
_REPL = {"router", "wkv_a", "decay_w2", "ts_w2", "mu_base", "mu_x",
         "decay_w0", "u", "gn_scale", "q_norm", "k_norm", "kv_norm",
         "mu_k", "mu_r", "scale", "bias", "conv_b", "dt_bias", "d_skip"}


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    return prod(mesh.shape[a] for a in axes)


def pick(mesh: Mesh, shape: Sequence[int], prefs: Sequence[Sequence[Axis]]) -> P:
    """Choose one axis per dim from a priority list, honoring divisibility
    and never reusing a mesh axis."""
    used = set()
    out = []
    for dim, cands in zip(shape, prefs):
        chosen = None
        for c in cands:
            if c is None:
                break
            axes = c if isinstance(c, tuple) else (c,)
            if any(a in used for a in axes):
                continue
            if dim % _axis_size(mesh, c) == 0:
                chosen = c
                used.update(axes)
                break
        out.append(chosen)
    return P(*out)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _path_names(path):
    return [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]


def param_spec(mesh: Mesh, path, leaf, n_leading: int) -> P:
    """Spec for one parameter leaf. ``n_leading`` extra leading axes
    (site stacking and/or scan-repeat) precede the base parameter dims."""
    name = _leaf_name(path)
    names = _path_names(path)
    shape = leaf.shape
    base_shape = shape[n_leading:]
    nd = len(base_shape)
    F, M = "fsdp", "model"

    if name == "embed":
        if nd == 3:    # musicgen: [K, V, D]
            prefs = [[None], [M, None], [F, None]]
        else:          # [V, D]
            prefs = [[M, None], [F, M, None]]
    elif name in _COL and nd == 3 and name in ("w_gate", "w_up") and "ffn" in names \
            and "shared" not in names:
        # routed experts [E, D, Fh]: expert parallel
        prefs = [[M, None], [F, None], [None]]
    elif name == "w_down" and nd == 3:
        prefs = [[M, None], [None], [F, None]]
    elif name in _COL and nd == 2:
        prefs = [[F, None], [M, F, None]]
    elif name in _ROW and nd == 2:
        prefs = [[M, F, None], [F, None]]
    elif name == "conv_w":        # [K, d_inner]
        prefs = [[None], [M, None]]
    elif name == "log_a":         # [d_inner, d_state]
        prefs = [[M, None], [None]]
    elif name in _REPL or nd <= 1:
        prefs = [[None]] * nd
    elif nd == 2:
        prefs = [[F, None], [M, None]]
    else:
        prefs = [[None]] * nd

    base = pick(mesh, base_shape, prefs)
    lead = _leading_axes(mesh, shape, n_leading)
    return P(*(tuple(lead) + tuple(base)))


def _leading_axes(mesh: Mesh, shape, n_leading: int):
    """Site axis (sharded over pod+site) then scan-repeat axes (replicated)."""
    lead = []
    for i in range(n_leading):
        if i == 0 and _has_site(mesh):
            ax = ("pod", "site") if "pod" in mesh.shape else ("site",)
            ax = ax if len(ax) > 1 else ax[0]
            if shape[0] % _axis_size(mesh, ax) == 0:
                lead.append(ax)
            else:
                lead.append(None)
        else:
            lead.append(None)
    return lead


def _has_site(mesh: Mesh) -> bool:
    return "site" in mesh.shape


def param_shardings(mesh: Mesh, params, stacked_site: bool):
    """NamedSharding pytree for a (possibly site-stacked) param tree.

    Leading-axis accounting: site stacking adds one axis; scan_layers
    adds one repeat axis (detected from the path).
    """
    def spec(path, leaf):
        names = _path_names(path)
        n_lead = (1 if stacked_site else 0) + (1 if "scan_layers" in names else 0)
        return NamedSharding(mesh, param_spec(mesh, path, leaf, n_lead))
    return jax.tree_util.tree_map_with_path(spec, params)


def batch_spec_train(mesh: Mesh, leaf_ndim: int) -> P:
    """[S, K, B, ...]: site axis over (pod,site), per-site batch over fsdp."""
    site_ax = ("pod", "site") if "pod" in mesh.shape else "site"
    dims = [site_ax, None, "fsdp"] + [None] * (leaf_ndim - 3)
    return P(*dims)


def batch_spec_serve(mesh: Mesh, shape) -> P:
    """Serving batch [B, L, ...]: batch over every non-model axis that divides."""
    axes = [a for a in ("pod", "site", "fsdp") if a in mesh.shape]
    cand = tuple(axes)
    if shape[0] % _axis_size(mesh, cand) == 0:
        return P(cand, *([None] * (len(shape) - 1)))
    # batch=1 (long_500k): shard the sequence/cache-length dim instead
    if len(shape) > 1 and shape[1] % _axis_size(mesh, cand) == 0 and shape[1] > 1:
        return P(None, cand, *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_spec(mesh: Mesh, path, leaf, batch: int) -> P:
    """KV/state cache sharding for serving.

    Priority: batch dim over (pod,site,fsdp); heads/hidden over "model";
    long_500k (batch 1) shards the cache length dim over the batch axes.
    """
    name = _leaf_name(path)
    names = _path_names(path)
    shape = leaf.shape
    n_lead = 1 if "scan" in names else 0
    base = shape[n_lead:]
    axes = tuple(a for a in ("pod", "site", "fsdp") if a in mesh.shape)
    F, M = axes, "model"
    if name == "index" or len(base) == 0:
        return P(*([None] * len(shape)))
    prefs = None
    if name in ("k", "v"):            # [B, cap, Hkv, hd]
        # sequence-sharded cache (flash-decode): none of the assigned archs
        # has kv_heads divisible by model=16, so shard the length dim over
        # "model" — decode attention reduces over it with a tiny psum.
        prefs = [[F, None], [M, None], [None], [None]]
        if base[0] == 1:
            prefs = [[None], [(tuple(list(axes) + ["model"])), M, F, None], [None], [None]]
    elif name in ("c_kv", "k_rope"):  # [B, cap, r]
        prefs = [[F, None], [M, None], [None]]
        if base[0] == 1:
            prefs = [[None], [(tuple(list(axes) + ["model"])), M, F, None], [None]]
    elif name == "state" and len(base) == 4:   # rwkv [B, H, hd, hd]
        prefs = [[F, None], [M, None], [None], [None]]
        if base[0] == 1:
            prefs = [[None], [(tuple(list(axes) + ["model"])), M, F, None], [None], [None]]
    elif name == "state" and len(base) == 3:   # mamba [B, d_inner, d_state]
        prefs = [[F, None], [M, None], [None]]
        if base[0] == 1:
            prefs = [[None], [(tuple(list(axes) + ["model"])), M, None], [None]]
    elif name == "conv_window":       # [B, K-1, d_inner]
        prefs = [[F, None], [None], [M, None]]
        if base[0] == 1:
            prefs = [[None], [None], [M, None]]
    elif name == "last_x":            # [B, D]
        prefs = [[F, None], [None]]
        if base[0] == 1:
            prefs = [[None], [M, None]]
    if prefs is None:
        prefs = [[None]] * len(base)
    spec = pick(mesh, base, prefs)
    if n_lead:
        return P(*((None,) + tuple(spec)))
    return spec


def cache_shardings(mesh: Mesh, caches, batch: int):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(mesh, p, l, batch)), caches)
