"""While-loop-aware analysis of compiled (SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 59 layers contributes a single layer of FLOPs
(verified empirically: scan vs unrolled differ by exactly the trip
count).  Since the layer stack, microbatch accumulation, KV-chunked
attention and the recurrent mixers are all scans, naive cost analysis
underestimates compute/traffic by 1–2 orders of magnitude.

This module parses the optimized HLO text instead:

  * splits the module into named computations,
  * extracts ``known_trip_count`` from every ``while`` instruction and
    propagates execution-count multipliers through the call graph
    (while bodies/conditions, fusions, and other ``calls=``),
  * counts per-instruction costs × execution count:
      - FLOPs: ``dot`` (2·prod(result)·prod(contracting)) and
        ``convolution`` (2·prod(result)·prod(kernel window)·Cin/groups)
      - bytes: result + operand bytes of top-level (non-fused-interior)
        instructions — fusion interiors stay in registers/VMEM
      - collective bytes by kind (all-gather / all-reduce /
        reduce-scatter / all-to-all / collective-permute)

The result feeds EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\\?":\s*\{\\?"n\\?":\\?"(\d+)')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ops whose "operands+result" don't represent real HBM traffic
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "custom-call"}


def _shape_bytes(type_str: str) -> float:
    """Bytes of a (possibly tuple) shape string like 'f32[8,16]' or
    '(s32[], f32[4])'."""
    total = 0.0
    for ty, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(ty, 4)
    return total


def _shape_dims(type_str: str) -> Tuple[List[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], "f32"
    ty, dims = m.group(1), m.group(2)
    return [int(d) for d in dims.split(",") if d], ty


@dataclass
class Instruction:
    name: str
    type_str: str          # result type/shape portion
    op: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)


_OP_TOKEN_RE = re.compile(r"^\s*([\w\-]+)\(")


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
        if (header and s.endswith("{") and "->" in s and " = " not in s
                and not s.startswith("ROOT")):
            cur = Computation(header.group(1))
            comps[cur.name] = cur
            continue
        if s == "}" or cur is None:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # rest: "<type> <op>(...), attrs"  (type may be a tuple "(..)")
        if rest.startswith("("):
            depth = 0
            for i, ch in enumerate(rest):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            type_str, tail = rest[: i + 1], rest[i + 1:].strip()
        else:
            sp = rest.find(" ")
            type_str, tail = rest[:sp], rest[sp + 1:]
        om = _OP_TOKEN_RE.match(tail)
        op = om.group(1) if om else tail.split("(")[0].strip()
        # operands: inside the first (...) of tail
        lp = tail.find("(")
        depth, rp = 0, len(tail)
        for i in range(lp, len(tail)):
            depth += tail[i] == "("
            depth -= tail[i] == ")"
            if depth == 0:
                rp = i
                break
        operand_str = tail[lp + 1: rp] if lp >= 0 else ""
        operands = _OPERAND_RE.findall(operand_str)
        cur.instructions.append(Instruction(name, type_str, op, s, operands))
    return comps


def execution_counts(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Multiplier per computation: product of enclosing while trip counts."""
    counts: Dict[str, float] = {}

    def visit(cname: str, mult: float):
        if cname not in comps:
            return
        counts[cname] = counts.get(cname, 0.0) + mult
        for ins in comps[cname].instructions:
            trip = 1.0
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = float(tm.group(1)) if tm else 1.0
            callees = _CALLS_RE.findall(ins.line)
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                callees += _OPERAND_RE.findall(bm.group(1)) or [
                    c.strip().lstrip("%") for c in bm.group(1).split(",")]
            for callee in callees:
                child_mult = mult * (trip if ins.op == "while" else 1.0)
                visit(callee, child_mult)

    visit(entry, 1.0)
    return counts


def _dot_flops(ins: Instruction, shapes: Dict[str, str]) -> float:
    res_dims, _ = _shape_dims(ins.type_str)
    n_res = 1
    for d in res_dims:
        n_res *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not cm or not ins.operands:
        return 2.0 * n_res          # degenerate
    lhs_shape = shapes.get(ins.operands[0], "")
    lhs_dims, _ = _shape_dims(lhs_shape)
    contract = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * n_res * contract


def _conv_flops(ins: Instruction, shapes: Dict[str, str]) -> float:
    res_dims, _ = _shape_dims(ins.type_str)
    n_res = 1
    for d in res_dims:
        n_res *= d
    wm = re.search(r"window=\{size=([\dx]+)", ins.line)
    win = 1
    if wm:
        for d in wm.group(1).split("x"):
            win *= int(d)
    # input feature count from rhs (kernel) shape: last-but-one conventional
    cin = 1
    if len(ins.operands) >= 2:
        k_dims, _ = _shape_dims(shapes.get(ins.operands[1], ""))
        if k_dims:
            cin = max(k_dims[-2] if len(k_dims) >= 2 else 1, 1)
    fm = re.search(r"feature_group_count=(\d+)", ins.line)
    groups = int(fm.group(1)) if fm else 1
    return 2.0 * n_res * win * cin / max(groups, 1)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: float = 0.0
    dot_count: float = 0.0

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str) -> HloCosts:
    comps = parse_module(hlo)
    entry = None
    em = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if em:
        entry = em.group(1)
    else:  # fall back to last computation
        entry = list(comps)[-1]
    counts = execution_counts(comps, entry)

    # global symbol table name -> type_str (names unique per module in
    # practice; collisions only risk tiny flop misattribution)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for ins in comp.instructions:
            shapes[ins.name] = ins.type_str

    # fusion-interior computations: bytes counted at the fusion call site
    interior = set()
    slicing_fusions = set()          # fusions that read a slice of operands
    inplace_fusions = set()          # fusions that update a slice in place
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.op == "fusion":
                for callee in _CALLS_RE.findall(ins.line):
                    interior.add(callee)
                    callee_ops = {i.op for i in comps.get(callee, Computation("")).instructions}
                    if callee_ops & {"dynamic-slice", "gather", "slice"}:
                        slicing_fusions.add(ins.name)
                    if "dynamic-update-slice" in callee_ops or "scatter" in callee_ops:
                        inplace_fusions.add(ins.name)

    out = HloCosts(collective_bytes={k: 0.0 for k in COLLECTIVE_KINDS})
    for cname, comp in comps.items():
        mult = counts.get(cname, 0.0)
        if mult == 0.0:
            continue
        for ins in comp.instructions:
            if ins.op == "dot":
                out.flops += mult * _dot_flops(ins, shapes)
                out.dot_count += mult
            elif ins.op == "convolution":
                out.flops += mult * _conv_flops(ins, shapes)
            for kind in COLLECTIVE_KINDS:
                if ins.op == kind or ins.op.startswith(kind + "-start"):
                    out.collective_bytes[kind] += mult * _shape_bytes(ins.type_str)
                    out.collective_count += mult
            if cname not in interior and ins.op not in _FREE_OPS \
                    and not ins.op.endswith("-done"):
                res_b = _shape_bytes(ins.type_str)
                if ins.op == "dynamic-update-slice" or ins.name in inplace_fusions:
                    # aliased in-place update: traffic = the small update
                    # operands (write + read-modify), not the whole buffer
                    small = sum(_shape_bytes(shapes[o]) for o in ins.operands[1:]
                                if o in shapes and _shape_bytes(shapes[o]) < res_b)
                    out.bytes += mult * 2 * small
                    continue
                b = res_b
                for opnd in ins.operands:
                    if opnd in shapes:
                        ob = _shape_bytes(shapes[opnd])
                        # slicing fusions (scan reading one layer's params)
                        # touch ~result-sized slices, not the whole operand
                        if ins.name in slicing_fusions:
                            ob = min(ob, res_b)
                        b += ob
                out.bytes += mult * b
    return out
