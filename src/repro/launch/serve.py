"""Serving driver: batched prefill + autoregressive decode.

Serves the *aggregated global model* (what FedKBP+ deploys after
federated training).  CPU-runnable with ``--reduced``; the full-scale
sharded path is exercised via the dry-run (launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 64 --decode-steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import transformer as T


def run(args):
    arch = get_arch(args.arch)
    cfg = arch.reduced() if args.reduced else arch.CONFIG
    key = jax.random.PRNGKey(args.seed)
    params = T.init(key, cfg)
    b, lp = args.batch, args.prompt_len
    capacity = lp + args.decode_steps
    shape = (b, lp) if cfg.num_codebooks == 1 else (b, lp, cfg.num_codebooks)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg, cache_capacity=capacity,
                                             moe_impl="dense"))
    decode = jax.jit(lambda p, t, c: T.decode_step(p, t, c, cfg, moe_impl="dense"))

    t0 = time.time()
    logits, caches = prefill(params, prompts)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(lg):
        tok = jnp.argmax(lg[:, -1:], axis=-1)
        return tok.astype(jnp.int32)

    toks = sample(logits)
    out_tokens = [toks]
    t0 = time.time()
    for _ in range(args.decode_steps - 1):
        logits, caches = decode(params, toks, caches)
        toks = sample(logits)
        out_tokens.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    total_new = b * args.decode_steps
    print(f"[serve] {cfg.name}: prefill {b}x{lp} in {t_prefill:.2f}s; "
          f"decode {args.decode_steps} steps in {t_decode:.2f}s "
          f"({total_new / max(t_decode, 1e-9):.1f} tok/s)")
    seq = jnp.concatenate(out_tokens, axis=1)
    print("[serve] sample continuation ids:", jax.device_get(seq[0])[:16].tolist())
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tok_per_s": total_new / max(t_decode, 1e-9)}


def make_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64, dest="prompt_len")
    ap.add_argument("--decode-steps", type=int, default=32, dest="decode_steps")
    ap.add_argument("--seed", type=int, default=0)
    return ap


if __name__ == "__main__":
    run(make_parser().parse_args())
