"""Per-(architecture × input-shape) step builders for dry-run / launch.

Produces, for any assigned arch and workload shape:

  * ``abstract_state()``  — ShapeDtypeStruct pytrees for every input
    (params, optimizer state, batches, caches) — no allocation
  * ``step_fn``           — the jit-able function:
        train_4k              -> one federated round (local step + exchange)
        prefill_32k           -> serve_prefill (batched logits + caches)
        decode_32k/long_500k  -> serve_decode (ONE token against the cache)
  * ``in_shardings`` / ``out_shardings`` on the FL mesh

The federated train step is the *paper-faithful* path: site-stacked
params, per-site local training, strategy exchange (FedAvg default).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (FederationConfig, InputShape, JobConfig,
                                MeshConfig, ModelConfig, PrecisionConfig,
                                INPUT_SHAPES)
from repro.configs.registry import get_arch
from repro.core import federation as F
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh
from repro.models import shardhints
from repro.models import transformer as T
from repro.optim import adamw


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


@dataclasses.dataclass
class StepArtifacts:
    name: str
    mesh: Any                      # jax Mesh (FL view)
    step_fn: Callable
    abstract_inputs: tuple         # positional ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    notes: str = ""


# ---------------------------------------------------------------------------
# Train (federated round)
# ---------------------------------------------------------------------------

# per-arch microbatch (per site) chosen so remat activations fit 16 GiB
# HBM (v5e); derivation + iterations recorded in EXPERIMENTS.md §Perf
TRAIN_MICROBATCH = {
    "deepseek-v2-236b": 4,
    "jamba-1.5-large-398b": 2,
    "chameleon-34b": 4,
    "qwen3-moe-30b-a3b": 4,
    "qwen3-8b": 4,
    "rwkv6-7b": 8,
    "granite-3-2b": 8,
    "gemma3-1b": 8,
    "smollm-135m": 8,
    "musicgen-medium": 8,
}


def build_train(arch_id: str, shape_name: str = "train_4k",
                multi_pod: bool = False, strategy: str = "fedavg",
                local_steps: int = 1, moe_impl: str = "dispatch",
                fsdp_params: bool = True, override_mesh: Optional[MeshConfig] = None,
                hierarchical: bool = True,
                microbatch: Optional[int] = None,
                hints: bool = True) -> StepArtifacts:
    arch = get_arch(arch_id)
    cfg: ModelConfig = arch.CONFIG
    shape: InputShape = INPUT_SHAPES[shape_name]
    mesh_cfg: MeshConfig = override_mesh or arch.mesh_for(shape, multi_pod)
    prec: PrecisionConfig = arch.precision_for(shape)
    mesh = mesh_lib.make_fl_mesh(mesh_cfg)

    s_total = mesh_cfg.total_sites
    per_site_batch = max(shape.global_batch // s_total, 1)
    if microbatch is None:
        microbatch = TRAIN_MICROBATCH.get(cfg.name)
    pdt = _dtype(prec.param_dtype)
    sdt = _dtype(prec.opt_state_dtype)

    fed = FederationConfig(num_sites=s_total, strategy=strategy,
                           local_steps=local_steps)
    opt = adamw(1e-4, weight_decay=0.01, state_dtype=sdt)

    def loss_fn(params, batch):
        return T.next_token_loss(params, batch, cfg, remat=True, moe_impl=moe_impl)

    # on a multi-pod mesh the mesh pod IS the aggregation pod: contiguous
    # sites_per_pod blocks, per-pod partials over ICI then cross-pod over
    # DCN (``hierarchical=False`` forces a flat all-reduce for A/B runs)
    from repro.core.topology import FLAT, Topology
    topo = (Topology.pods(mesh_cfg.num_pods)
            if (mesh_cfg.multi_pod and hierarchical) else FLAT)
    ctx = F.FLContext(
        fed=fed, mesh=mesh_cfg, case_weights=jnp.asarray(fed.case_weights()),
        loss_fn=loss_fn, logits_fn=None, optimizer=opt, grad_clip=1.0,
        dcml_lr=1e-4, topology=topo, microbatch=microbatch,
        accum_dtype=(jnp.bfloat16 if prec.opt_state_dtype == "bfloat16"
                     else jnp.float32))

    fl_round = F.build_fl_round(ctx)

    def init_params(key):
        return T.init(key, cfg, dtype=pdt)

    def abstract_state():
        params = jax.eval_shape(
            lambda k: F.init_fl_state(ctx, init_params, k), jax.random.PRNGKey(0))
        return params

    fl_state_abs = abstract_state()
    tok_shape = (s_total, local_steps, per_site_batch, shape.seq_len)
    if cfg.num_codebooks > 1:
        tok_shape = tok_shape + (cfg.num_codebooks,)
    batches_abs = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    round_inputs_abs = {
        "active": jax.ShapeDtypeStruct((s_total,), jnp.bool_),
        "partner": jax.ShapeDtypeStruct((s_total,), jnp.int32),
        "is_receiver": jax.ShapeDtypeStruct((s_total,), jnp.bool_),
    }

    # shardings
    def state_shardings(state_abs):
        out = {}
        out["params"] = sh.param_shardings(mesh, state_abs["params"], stacked_site=True)
        out["opt"] = {
            "step": NamedSharding(mesh, P(mesh_lib.site_axes(mesh_cfg)
                                          if s_total > 1 else None)),
            "mu": sh.param_shardings(mesh, state_abs["opt"]["mu"], stacked_site=True),
            "nu": sh.param_shardings(mesh, state_abs["opt"]["nu"], stacked_site=True),
        }
        # strategy state entries are unstacked model-shaped pytrees (e.g.
        # fedprox's global model) — shard like params sans the site axis
        out["strategy"] = {k: sh.param_shardings(mesh, v, stacked_site=False)
                           for k, v in state_abs["strategy"].items()}
        out["round"] = NamedSharding(mesh, P())
        return out

    st_sh = state_shardings(fl_state_abs)
    site_ax = mesh_lib.site_axes(mesh_cfg)
    site_ax = site_ax if len(site_ax) > 1 else site_ax[0]
    bt_sh = {"tokens": NamedSharding(
        mesh, sh.batch_spec_train(mesh, len(tok_shape)))}
    ri_sh = {k: NamedSharding(mesh, P()) for k in round_inputs_abs}

    def step_fn(fl_state, batches, round_inputs):
        import contextlib
        hctx = (shardhints.enable(model_axis=mesh_cfg.model_parallel)
                if hints else contextlib.nullcontext())
        with hctx:
            new_state, metrics = fl_round(fl_state, batches, round_inputs)
        return new_state, jax.tree.map(jnp.mean, metrics)

    return StepArtifacts(
        name=f"{arch_id}:{shape_name}:{'2pod' if multi_pod else '1pod'}",
        mesh=mesh, step_fn=step_fn,
        abstract_inputs=(fl_state_abs, batches_abs, round_inputs_abs),
        in_shardings=(st_sh, bt_sh, ri_sh),
        out_shardings=(st_sh, None),
        notes=f"sites={s_total} per_site_batch={per_site_batch} "
              f"micro={microbatch} strategy={strategy}")


# ---------------------------------------------------------------------------
# Serve (prefill / decode)
# ---------------------------------------------------------------------------


def build_serve(arch_id: str, shape_name: str, multi_pod: bool = False,
                moe_impl: str = "dispatch") -> StepArtifacts:
    arch = get_arch(arch_id)
    cfg: ModelConfig = arch.CONFIG
    shape: InputShape = INPUT_SHAPES[shape_name]
    mesh_cfg: MeshConfig = arch.mesh_for(shape, multi_pod)
    prec: PrecisionConfig = arch.precision_for(shape)
    mesh = mesh_lib.make_fl_mesh(mesh_cfg)
    pdt = _dtype(prec.param_dtype)

    params_abs = jax.eval_shape(lambda k: T.init(k, cfg, dtype=pdt),
                                jax.random.PRNGKey(0))
    p_sh = sh.param_shardings(mesh, params_abs, stacked_site=False)
    b = shape.global_batch

    if shape.kind == "prefill":
        tok_shape = (b, shape.seq_len) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
        toks_abs = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
        t_sh = NamedSharding(mesh, sh.batch_spec_serve(mesh, tok_shape))

        def step_fn(params, tokens):
            with shardhints.enable(model_axis=mesh_cfg.model_parallel):
                logits, caches = T.prefill(params, tokens, cfg,
                                           cache_capacity=shape.seq_len,
                                           moe_impl=moe_impl)
            return logits, caches

        caches_abs = jax.eval_shape(
            lambda: T.init_caches(b, shape.seq_len, cfg, dtype=jnp.bfloat16))
        c_sh = sh.cache_shardings(mesh, caches_abs, b)
        return StepArtifacts(
            name=f"{arch_id}:{shape_name}:{'2pod' if multi_pod else '1pod'}",
            mesh=mesh, step_fn=step_fn,
            abstract_inputs=(params_abs, toks_abs),
            in_shardings=(p_sh, t_sh),
            out_shardings=(None, c_sh),
            notes=f"prefill batch={b} seq={shape.seq_len}")

    # decode: ONE new token against a seq_len cache
    tok_shape = (b, 1) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    toks_abs = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
    caches_abs = jax.eval_shape(
        lambda: T.init_caches(b, shape.seq_len, cfg, dtype=jnp.bfloat16))
    c_sh = sh.cache_shardings(mesh, caches_abs, b)
    t_sh = NamedSharding(mesh, sh.batch_spec_serve(mesh, tok_shape))

    def step_fn(params, tokens, caches):
        with shardhints.enable(model_axis=mesh_cfg.model_parallel):
            return T.decode_step(params, tokens, caches, cfg, moe_impl=moe_impl)

    return StepArtifacts(
        name=f"{arch_id}:{shape_name}:{'2pod' if multi_pod else '1pod'}",
        mesh=mesh, step_fn=step_fn,
        abstract_inputs=(params_abs, toks_abs, caches_abs),
        in_shardings=(p_sh, t_sh, c_sh),
        out_shardings=(None, c_sh),
        notes=f"decode batch={b} cache={shape.seq_len}")


def build(arch_id: str, shape_name: str, multi_pod: bool = False, **kw) -> StepArtifacts:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return build_train(arch_id, shape_name, multi_pod, **kw)
    return build_serve(arch_id, shape_name, multi_pod)
