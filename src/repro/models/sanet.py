"""SA-Net (Scale Attention Network) — the paper's predictive backbone.

Faithful to Figure 5: a ResNet-style encoder whose residual blocks carry
squeeze-and-excitation (ResSE), a mirrored decoder with a single ResSE
block per level, and a *scale attention* block per decoder level that
resizes all encoder scales to a common resolution, sums them, squeezes
(GAP + SE), and softmax-normalizes per-channel weights **across scales**
— the decoder fuses the attention output by element-wise summation
(not concatenation).  Deep supervision heads at every decoder level.

Used for all three KBP+ tasks with task-specific losses:
  * dose prediction — voxel-wise MAE (paper §III.A.3)
  * tumor segmentation — Jaccard distance + focal loss (§III.B.3)
  * OAR segmentation — cross-entropy + Jaccard distance (§III.C.3)

Layout: channels-last volumes [B, D, H, W, C].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

DIMNUMS = ("NDHWC", "DHWIO", "NDHWC")


@dataclass(frozen=True)
class SANetConfig:
    in_channels: int = 11              # OpenKBP: CT + PTVs + OAR masks
    out_channels: int = 1              # dose (1) or segmentation classes
    base_filters: int = 24
    num_levels: int = 4
    se_ratio: int = 4
    task: str = "dose"                 # dose | segmentation
    deep_supervision: bool = True

    def filters(self, level: int) -> int:
        return self.base_filters * (2 ** level)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def conv_init(key, k: Tuple[int, int, int], cin: int, cout: int, dtype=jnp.float32):
    fan_in = cin * k[0] * k[1] * k[2]
    w = jax.random.truncated_normal(key, -2, 2, k + (cin, cout)) * (2.0 / fan_in) ** 0.5
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def conv_apply(p, x, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride,) * 3, padding="SAME",
        dimension_numbers=DIMNUMS) + p["b"]


def groupnorm_init(c: int, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def groupnorm_apply(p, x, groups: int = 8, eps: float = 1e-5):
    b = x.shape[0]
    c = x.shape[-1]
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, -1, g, c // g).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 3), keepdims=True)
    var = jnp.var(xg, axis=(1, 3), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(x.shape) * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def se_init(key, c: int, ratio: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    hidden = max(c // ratio, 4)
    return {"w1": (jax.random.normal(k1, (c, hidden)) * (c ** -0.5)).astype(dtype),
            "w2": (jax.random.normal(k2, (hidden, c)) * (hidden ** -0.5)).astype(dtype)}


def se_apply(p, x):
    """Squeeze-and-excitation on [B, D, H, W, C]."""
    s = jnp.mean(x, axis=(1, 2, 3))                    # [B, C]
    s = jax.nn.relu(s @ p["w1"]) @ p["w2"]
    return x * jax.nn.sigmoid(s)[:, None, None, None, :]


def resse_init(key, cin: int, cout: int, ratio: int, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "norm1": groupnorm_init(cin, dtype),
        "conv1": conv_init(ks[0], (3, 3, 3), cin, cout, dtype),
        "norm2": groupnorm_init(cout, dtype),
        "conv2": conv_init(ks[1], (3, 3, 3), cout, cout, dtype),
        "se": se_init(ks[2], cout, ratio, dtype),
    }
    if cin != cout:
        p["proj"] = conv_init(ks[3], (1, 1, 1), cin, cout, dtype)
    return p


def resse_apply(p, x):
    """Pre-activation residual block with SE (Figure 5(b))."""
    h = conv_apply(p["conv1"], jax.nn.relu(groupnorm_apply(p["norm1"], x)))
    h = conv_apply(p["conv2"], jax.nn.relu(groupnorm_apply(p["norm2"], h)))
    h = se_apply(p["se"], h)
    skip = conv_apply(p["proj"], x) if "proj" in p else x
    return skip + h


def resize_volume(x, target_shape: Tuple[int, int, int]):
    """Nearest-neighbour spatial resize of [B, D, H, W, C]."""
    b, d, h, w, c = x.shape
    return jax.image.resize(x, (b,) + tuple(target_shape) + (c,), method="nearest")


# ---------------------------------------------------------------------------
# Scale attention block (Figure 5(c))
# ---------------------------------------------------------------------------


def scale_attn_init(key, cfg: SANetConfig, level: int, dtype=jnp.float32):
    c = cfg.filters(level)
    ks = jax.random.split(key, cfg.num_levels + 1)
    # 1x1 convs mapping each encoder scale's channels to this level's width
    proj = [conv_init(ks[i], (1, 1, 1), cfg.filters(i), c, dtype)
            for i in range(cfg.num_levels)]
    return {"proj": proj, "se": se_init(ks[-1], c * cfg.num_levels, cfg.se_ratio, dtype)}


def scale_attn_apply(p, enc_feats, cfg: SANetConfig, level: int):
    """Fuse all encoder scales into one map at ``level`` resolution."""
    target = enc_feats[level].shape[1:4]
    c = cfg.filters(level)
    maps = [conv_apply(p["proj"][i], resize_volume(f, target))
            for i, f in enumerate(enc_feats)]           # each [B,*,*,*,C]
    summed = sum(maps)
    # squeeze: GAP of the sum, then SE producing per-(scale, channel) logits
    s = jnp.mean(summed, axis=(1, 2, 3))                # [B, C]
    s_all = jnp.tile(s, (1, cfg.num_levels))            # [B, S*C]
    e = jax.nn.relu(s_all @ p["se"]["w1"]) @ p["se"]["w2"]   # [B, S*C]
    logits = e.reshape(s.shape[0], cfg.num_levels, c)
    weights = jax.nn.softmax(logits, axis=1)            # softmax over scales
    out = sum(weights[:, i][:, None, None, None, :] * maps[i]
              for i in range(cfg.num_levels))
    return out


# ---------------------------------------------------------------------------
# Full network
# ---------------------------------------------------------------------------


def sanet_init(key, cfg: SANetConfig, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 64))
    p = {"stem": conv_init(next(ks), (3, 3, 3), cfg.in_channels, cfg.filters(0), dtype)}
    # encoder: 2 ResSE blocks per level, stride-2 downsample conv between levels
    p["enc"] = []
    for lvl in range(cfg.num_levels):
        c = cfg.filters(lvl)
        blocks = {"b1": resse_init(next(ks), c, c, cfg.se_ratio, dtype),
                  "b2": resse_init(next(ks), c, c, cfg.se_ratio, dtype)}
        if lvl < cfg.num_levels - 1:
            blocks["down"] = conv_init(next(ks), (3, 3, 3), c, cfg.filters(lvl + 1), dtype)
        p["enc"].append(blocks)
    # scale attention + decoder (single ResSE per level) + deep supervision
    p["scale_attn"] = [scale_attn_init(next(ks), cfg, lvl, dtype)
                       for lvl in range(cfg.num_levels - 1)]
    p["dec"] = []
    p["ds_heads"] = []
    for lvl in range(cfg.num_levels - 2, -1, -1):
        cin, cout = cfg.filters(lvl + 1), cfg.filters(lvl)
        p["dec"].append({
            "up": conv_init(next(ks), (1, 1, 1), cin, cout, dtype),
            "block": resse_init(next(ks), cout, cout, cfg.se_ratio, dtype),
        })
        p["ds_heads"].append(conv_init(next(ks), (1, 1, 1), cout, cfg.out_channels, dtype))
    return p


def sanet_apply(params, x, cfg: SANetConfig):
    """x: [B, D, H, W, in_channels] -> (output, deep-supervision list).

    ``output`` is [B, D, H, W, out_channels]; deep-supervision outputs are
    produced at every decoder level and resized to full resolution.
    """
    h = conv_apply(params["stem"], x)
    enc_feats = []
    for lvl in range(cfg.num_levels):
        b = params["enc"][lvl]
        h = resse_apply(b["b2"], resse_apply(b["b1"], h))
        enc_feats.append(h)
        if lvl < cfg.num_levels - 1:
            h = conv_apply(b["down"], h, stride=2)
    # decoder
    ds_outs = []
    d = enc_feats[-1]
    for i, lvl in enumerate(range(cfg.num_levels - 2, -1, -1)):
        target = enc_feats[lvl].shape[1:4]
        up = conv_apply(params["dec"][i]["up"], resize_volume(d, target))
        fused = up + scale_attn_apply(params["scale_attn"][lvl], enc_feats, cfg, lvl)
        d = resse_apply(params["dec"][i]["block"], fused)
        ds = conv_apply(params["ds_heads"][i], d)
        ds_outs.append(resize_volume(ds, x.shape[1:4]))
    return ds_outs[-1], ds_outs


# ---------------------------------------------------------------------------
# Task losses (paper §III)
# ---------------------------------------------------------------------------


def dose_loss(params, batch, cfg: SANetConfig, ds_weight: float = 0.5):
    """Voxel-wise MAE with deep supervision (dose prediction, §III.A.3).

    ``batch["mask"]`` restricts the loss to the patient volume (possible
    dose region), matching OpenKBP's evaluation protocol.
    """
    pred, ds_outs = sanet_apply(params, batch["volume"], cfg)
    mask = batch.get("mask")
    def mae(p):
        err = jnp.abs(p - batch["dose"])
        if mask is not None:
            return jnp.sum(err * mask) / (jnp.sum(mask) + 1e-6)
        return jnp.mean(err)
    loss = mae(pred)
    if cfg.deep_supervision and len(ds_outs) > 1:
        aux = sum(mae(o) for o in ds_outs[:-1]) / max(len(ds_outs) - 1, 1)
        loss = loss + ds_weight * aux
    return loss, {"mae": loss}


def _soft_jaccard(probs, onehot, eps=1e-6):
    inter = jnp.sum(probs * onehot, axis=(1, 2, 3))
    union = jnp.sum(probs + onehot, axis=(1, 2, 3)) - inter
    return 1.0 - (inter + eps) / (union + eps)          # [B, C]


def segmentation_loss(params, batch, cfg: SANetConfig, focal_gamma: float = 2.0,
                      use_focal: bool = False, ds_weight: float = 0.5):
    """Jaccard distance + (focal or plain) CE (paper §III.B.3 / §III.C.3)."""
    pred, ds_outs = sanet_apply(params, batch["volume"], cfg)
    labels = batch["labels"]                             # [B, D, H, W] int
    onehot = jax.nn.one_hot(labels, cfg.out_channels)

    def term(logits):
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        if use_focal:
            pt = jnp.exp(-ce)
            ce = ce * (1.0 - pt) ** focal_gamma
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.mean(ce) + jnp.mean(_soft_jaccard(probs, onehot))

    loss = term(pred)
    if cfg.deep_supervision and len(ds_outs) > 1:
        loss = loss + ds_weight * sum(term(o) for o in ds_outs[:-1]) / max(len(ds_outs) - 1, 1)
    return loss, {"seg_loss": loss}
