"""Mixture-of-Experts FFN (DeepSeek-V2 / Qwen3-MoE / Jamba style).

Dense-einsum formulation: every token computes a routing distribution, the
top-k experts are selected, and expert FFNs are evaluated as a single
[E, d_model, d_expert] batched einsum with a [tokens, E] dispatch/combine
weight matrix.  On TPU this lowers to MXU-friendly batched matmuls and —
when the expert dimension is sharded over the "model" axis — to the
all-to-all-free expert-parallel pattern (each device computes all tokens for
its expert shard and the combine is a reduce over the expert axis).

The router's load-balance auxiliary loss (Switch-style, as used by all three
assigned MoE archs) is returned for the trainer to add; it is computed
per-site in federated training (see DESIGN.md §5: under non-IID data each
site balances its *own* token distribution — the global balance emerges via
FedAvg on router weights).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    e, de = cfg.num_experts, cfg.d_expert
    p = {
        "router": dense_init(ks[0], d_model, e, dtype=jnp.float32),  # router in fp32
        "w_gate": (jax.random.normal(ks[1], (e, d_model, de)) * (d_model ** -0.5)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, de)) * (d_model ** -0.5)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, de, d_model)) * (de ** -0.5)).astype(dtype),
    }
    if cfg.num_shared_experts:
        ds = cfg.d_shared_total
        p["shared"] = {
            "w_gate": dense_init(ks[4], d_model, ds, dtype),
            "w_up": dense_init(ks[5], d_model, ds, dtype),
            "w_down": dense_init(ks[6], ds, d_model, dtype),
        }
    return p


def router_probs(params, x, cfg: MoEConfig) -> jnp.ndarray:
    """[.., L, E] softmax routing probabilities (fp32)."""
    logits = x.astype(jnp.float32) @ params["router"]
    return jax.nn.softmax(logits, axis=-1)


def topk_dispatch(probs: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k combine weights as a dense [.., E] matrix plus the aux loss.

    Returns (combine[.., E], aux_loss scalar).
    """
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)          # [.., k]
    if cfg.normalize_router_weights:
        top_vals = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)
    onehot = jax.nn.one_hot(top_idx, cfg.num_experts, dtype=probs.dtype)  # [..,k,E]
    combine = jnp.einsum("...k,...ke->...e", top_vals, onehot)
    # Switch-style load balance: E * sum_e( mean_frac_tokens_e * mean_prob_e )
    tokens_per_expert = jnp.mean(jnp.sum(onehot, axis=-2), axis=tuple(range(onehot.ndim - 2)))
    mean_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = cfg.num_experts * jnp.sum(tokens_per_expert * mean_prob)
    return combine, aux


def moe_apply(params, x, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, L, D] -> (y: [B, L, D], aux_loss scalar).

    Dense dispatch: compute all experts' contributions weighted by the
    combine matrix.  FLOP-exact for dry-run cost analysis of the *dense
    compute* formulation; the Pallas/production path can swap in gathered
    dispatch without changing semantics (combine weights are identical).
    """
    combine, aux = topk_dispatch(router_probs(params, x, cfg), cfg)   # [B,L,E]
    h = jax.nn.silu(jnp.einsum("bld,edf->belf", x, params["w_gate"]))
    h = h * jnp.einsum("bld,edf->belf", x, params["w_up"])
    y = jnp.einsum("belf,efd,ble->bld", h, params["w_down"],
                   combine.astype(x.dtype))
    if cfg.num_shared_experts:
        s = params["shared"]
        y = y + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
    return y, aux


def moe_apply_dispatch(params, x, cfg: MoEConfig, capacity_factor: float = 1.25,
                       group_size: int = 2048) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard/Switch-style grouped capacity dispatch — the production path.

    Tokens are split into groups of ``group_size``; within each group a
    token is routed to per-expert buffers of capacity
    ``C = ceil(group_size * top_k / E * capacity_factor)`` (overflow
    drops, standard semantics).  Expert FFNs run as [G, E, C, D] x
    [E, D, F] batched matmuls.  Grouping bounds the dispatch/combine
    tensors at ~tokens * top_k * capacity_factor elements regardless of
    sequence length — without it a 32k-prefill's dispatch matrix is
    petabyte-scale.  With the expert axis sharded over "model" the
    group-to-expert resharding lowers to the expert-parallel all-to-all.
    Active-expert FLOPs only.
    """
    b, l, d = x.shape
    tokens = b * l
    s = min(group_size, tokens)
    if tokens % s:
        s = tokens                      # ragged: fall back to one group
    g = tokens // s
    xt = x.reshape(g, s, d)
    probs = router_probs(params, xt, cfg)                              # [G,S,E]
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)                # [G,S,k]
    if cfg.normalize_router_weights:
        top_vals = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)
    onehot = jax.nn.one_hot(top_idx, cfg.num_experts, dtype=jnp.float32)  # [G,S,k,E]
    # aux loss (Switch): E * sum_e mean_tokens_e * mean_prob_e
    tokens_per_expert = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(tokens_per_expert * mean_prob)

    cap = int(max(4, s * cfg.top_k / cfg.num_experts * capacity_factor))
    cap = min(cap, s)
    # accumulate dispatch/combine one top-k slot at a time so the peak
    # temporary is a single [G, S, E, C] buffer (sharded over E)
    dispatch = jnp.zeros((g, s, cfg.num_experts, cap), x.dtype)
    combine = jnp.zeros((g, s, cfg.num_experts, cap), x.dtype)
    count = jnp.zeros((g, cfg.num_experts), jnp.float32)
    for j in range(cfg.top_k):
        assign = onehot[:, :, j, :]                                    # [G,S,E]
        pos = jnp.cumsum(assign, axis=1) * assign - 1.0 + count[:, None, :] * assign
        keep = (pos >= 0) & (pos < cap) & (assign > 0)
        d_j = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype) \
            * keep.astype(x.dtype)[..., None]                          # [G,S,E,C]
        dispatch = dispatch + d_j
        combine = combine + top_vals[:, :, j, None, None].astype(x.dtype) * d_j
        count = count + jnp.sum(assign, axis=1)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt)                    # [G,E,C,D]
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])             # [G,E,C,D]
    y = jnp.einsum("gsec,gecd->gsd", combine, ye).reshape(b, l, d)
    if cfg.num_shared_experts:
        sh = params["shared"]
        y = y + (jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]
    return y, aux


def moe_apply_sparse(params, x, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-gather (active-expert-only) formulation.

    Evaluates only the k selected experts per token via gathered parameter
    matmuls — O(k/E) of the dense-einsum FLOPs.  This is the
    *beyond-paper* optimized path used after the faithful baseline is
    recorded (see EXPERIMENTS.md §Perf): XLA lowers the gather over the
    expert-sharded weights to an all-to-all on the "model" axis.
    """
    probs = router_probs(params, x, cfg)
    top_vals, top_idx = jax.lax.top_k(probs, cfg.top_k)               # [B,L,k]
    if cfg.normalize_router_weights:
        top_vals = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)
    onehot = jax.nn.one_hot(top_idx, cfg.num_experts, dtype=probs.dtype)
    tokens_per_expert = jnp.mean(jnp.sum(onehot, axis=-2), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(tokens_per_expert * mean_prob)

    wg = params["w_gate"][top_idx]                                    # [B,L,k,D,F]
    wu = params["w_up"][top_idx]
    wd = params["w_down"][top_idx]                                    # [B,L,k,F,D]
    h = jax.nn.silu(jnp.einsum("bld,blkdf->blkf", x, wg))
    h = h * jnp.einsum("bld,blkdf->blkf", x, wu)
    y = jnp.einsum("blkf,blkfd,blk->bld", h, wd, top_vals.astype(x.dtype))
    if cfg.num_shared_experts:
        s = params["shared"]
        y = y + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
    return y, aux
