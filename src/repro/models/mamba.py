"""Mamba (S6) selective-scan mixer, used by Jamba's non-attention layers.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t        (per channel)
    y_t = C_t . h_t + D * x_t

State: [B, d_inner, d_state].  Same nested-chunked-scan memory strategy as
rwkv6 (outer scan over chunks with checkpointing, exact inner scan).
Decode is a single recurrence step with a rolling conv window.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from repro.models.layers import dense_init


def _dims(cfg: ModelConfig):
    m: MambaConfig = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank if m.dt_rank is not None else math.ceil(cfg.d_model / 16)
    return m, d_inner, dt_rank


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m, d_inner, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    a_init = jnp.broadcast_to(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (d_inner, m.d_state))
    return {
        "w_in": dense_init(ks[0], cfg.d_model, 2 * d_inner, dtype),      # x and gate z
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, d_inner)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_bcdt": dense_init(ks[2], d_inner, 2 * m.d_state + dt_rank, dtype),
        "w_dt": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": (jax.random.uniform(ks[4], (d_inner,), minval=-4.6, maxval=-2.3)).astype(dtype),
        "log_a": jnp.log(a_init),                                        # fp32
        "d_skip": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[5], d_inner, cfg.d_model, dtype),
    }


def _conv1d(x, w, b, last_window: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [B,L,C]; w: [K,C]. ``last_window`` is the
    trailing K-1 inputs of the previous segment for stateful decode."""
    k = w.shape[0]
    if last_window is None:
        pad = jnp.zeros_like(x[:, : k - 1])
    else:
        pad = last_window
    xp = jnp.concatenate([pad, x], axis=1)                               # [B, L+K-1, C]
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(k))
    return out + b


def _ssm_inputs(params, x, cfg: ModelConfig):
    """Project to per-token SSM inputs (dt, B, C). x: [B,L,d_inner].

    The discretized decay/drive tensors ([.., d_inner, d_state]) are NOT
    materialized here — they are 16x larger than the projections and are
    formed chunk-by-chunk inside ``selective_scan`` (peak transient one
    chunk instead of the whole sequence).
    """
    m, d_inner, dt_rank = _dims(cfg)
    bcdt = x @ params["w_bcdt"]
    b_mat = bcdt[..., : m.d_state]
    c_mat = bcdt[..., m.d_state: 2 * m.d_state]
    dt = jax.nn.softplus(bcdt[..., 2 * m.d_state:] @ params["w_dt"]
                         + params["dt_bias"].astype(x.dtype))            # [B,L,d_inner]
    return dt, b_mat, c_mat


def discretize(dt, b_mat, x, log_a):
    """(decay, drive) for a token block. dt/x: [..., di]; b_mat: [..., ds]."""
    a = -jnp.exp(log_a)                                                  # [di, ds]
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
    drive = (dt.astype(jnp.float32) * x.astype(jnp.float32))[..., None] \
        * b_mat.astype(jnp.float32)[..., None, :]
    return decay, drive


def selective_scan(dt, b_mat, c_mat, x, log_a, state=None, chunk: int = 128):
    """Exact selective scan with chunked checkpointing.

    dt/x: [B, L, d_inner]; b_mat/c_mat: [B, L, d_state].
    Returns (y [B, L, d_inner], final_state [B, d_inner, d_state]).
    """
    b, l, di = dt.shape
    ds = b_mat.shape[-1]
    if state is None:
        state = jnp.zeros((b, di, ds), jnp.float32)
    c = min(chunk, l)
    if l % c:
        c = l
    nchunks = l // c

    def chunk_body(st, xs):
        dtc, bc, cc, xc = xs                                            # [c, B, ...]
        def step(s, inp):
            dt_t, b_t, c_t, x_t = inp
            dec, drv = discretize(dt_t, b_t, x_t, log_a)
            s = dec * s + drv
            y = jnp.einsum("bis,bs->bi", s, c_t.astype(jnp.float32))
            return s, y
        st, ys = jax.lax.scan(step, st, (dtc, bc, cc, xc))
        return st, ys

    chunk_body = jax.checkpoint(chunk_body)
    swap = lambda t: jnp.moveaxis(t.reshape((b, nchunks, c) + t.shape[2:]), 0, 2)
    xs = (swap(dt), swap(b_mat), swap(c_mat), swap(x))                  # [nc, c, B, ...]
    state, ys = jax.lax.scan(chunk_body, state, xs)                     # [nc, c, B, di]
    y = jnp.moveaxis(ys, 2, 0).reshape(b, l, di)
    return y, state


def mamba_apply(params, x, cfg: ModelConfig, return_cache: bool = False):
    m, d_inner, _ = _dims(cfg)
    b, l, _ = x.shape
    xz = x @ params["w_in"]
    xin, z = xz[..., :d_inner], xz[..., d_inner:]
    xc = jax.nn.silu(_conv1d(xin, params["conv_w"], params["conv_b"]))
    dt, b_mat, c_mat = _ssm_inputs(params, xc, cfg)
    y, state = selective_scan(dt, b_mat, c_mat, xc, params["log_a"],
                              chunk=m.chunk_size)
    y = y.astype(x.dtype) + params["d_skip"].astype(x.dtype) * xc
    y = (y * jax.nn.silu(z)) @ params["w_out"]
    if not return_cache:
        return y, None
    window = xin[:, -(m.d_conv - 1):] if l >= m.d_conv - 1 else \
        jnp.concatenate([jnp.zeros((b, m.d_conv - 1 - l, d_inner), xin.dtype), xin], axis=1)
    return y, {"state": state, "conv_window": window, "index": jnp.full((), l, jnp.int32)}


def init_mamba_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    m, d_inner, _ = _dims(cfg)
    return {
        "state": jnp.zeros((batch, d_inner, m.d_state), jnp.float32),
        "conv_window": jnp.zeros((batch, m.d_conv - 1, d_inner), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def mamba_decode(params, x, cache, cfg: ModelConfig):
    """One-token decode: O(1) state + rolling conv window."""
    m, d_inner, _ = _dims(cfg)
    b = x.shape[0]
    xz = x @ params["w_in"]
    xin, z = xz[..., :d_inner], xz[..., d_inner:]
    xc = jax.nn.silu(_conv1d(xin, params["conv_w"], params["conv_b"],
                             last_window=cache["conv_window"]))
    dt, b_mat, c_mat = _ssm_inputs(params, xc, cfg)
    decay, drive = discretize(dt, b_mat, xc, params["log_a"])
    s = decay[:, 0] * cache["state"] + drive[:, 0]
    y = jnp.einsum("bis,bs->bi", s, c_mat[:, 0].astype(jnp.float32))[:, None, :]
    y = y.astype(x.dtype) + params["d_skip"].astype(x.dtype) * xc
    y = (y * jax.nn.silu(z)) @ params["w_out"]
    window = jnp.concatenate([cache["conv_window"][:, 1:], xin], axis=1)
    return y, {"state": s, "conv_window": window, "index": cache["index"] + 1}
