"""Activation-sharding hints for the model code.

Model modules are mesh-agnostic; the launcher enables hints with the mesh
axis sizes and the model drops ``with_sharding_constraint`` seeds at the
few places XLA's propagation goes wrong (measured, not speculative — see
EXPERIMENTS.md §Perf: without the q/k/v head constraint the MLA score
contraction partial-sums over the model axis, 32 TB of all-reduce per
deepseek round).

Usage (launcher):
    with shardhints.enable(model_axis=16):
        jax.jit(step).lower(...)

Model code:
    q = shardhints.constrain_heads(q)      # [B, L, H, D] — H over "model"
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _cfg():
    return getattr(_state, "cfg", None)


@contextlib.contextmanager
def enable(model_axis: int, axis_name: str = "model"):
    prev = _cfg()
    _state.cfg = {"model_axis": model_axis, "axis_name": axis_name}
    try:
        yield
    finally:
        _state.cfg = prev


def constrain_heads(x, head_axis: int = -2):
    """Constrain a [..., H, D] activation's head dim over the model axis
    (no-op when hints are disabled or H doesn't divide)."""
    cfg = _cfg()
    if cfg is None:
        return x
    h = x.shape[head_axis]
    if h % cfg["model_axis"]:
        return x
    spec = [None] * x.ndim
    spec[head_axis % x.ndim] = cfg["axis_name"]
    return jax.lax.with_sharding_constraint(x, P(*spec))
