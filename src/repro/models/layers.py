"""Shared neural-net building blocks (pure-function style: init + apply).

Every module is a pair of functions::

    params = <name>_init(key, ...)
    y      = <name>_apply(params, x, ...)

Parameters are plain dict pytrees so they stack cleanly along the federated
site axis (see ``repro.core.stacking``) and shard with simple
``PartitionSpec`` rules.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal fan-in init for a [d_in, d_out] kernel."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    """RMS layer norm; statistics in fp32 regardless of input dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def l2norm(x, eps: float = 1e-6):
    """Per-head L2 normalization used by qk-norm variants (Qwen3/Gemma3)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies for rotary embedding (half-dim)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """Apply rotary embedding.

    x: [..., L, H, D] (D even), positions: broadcastable to [..., L].
    Uses the interleaved-pairs convention in fp32 then casts back.
    """
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)                        # [D/2]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., L, D/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]                               # [..., L, 1, D/2]
    cos = cos[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d // 2], x32[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int, dtype=jnp.float32):
    """Classic transformer sinusoidal table (MusicGen-style)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    table = jnp.zeros((length, d_model), dtype=jnp.float32)
    table = table.at[:, 0::2].set(jnp.sin(ang))
    table = table.at[:, 1::2].set(jnp.cos(ang))
    return table.astype(dtype)


# ---------------------------------------------------------------------------
# Feed-forward networks
# ---------------------------------------------------------------------------


def _act(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu_sq": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def mlp_init(key, d_model: int, d_ff: int, activation: str = "swiglu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def mlp_apply(params, x, activation: str = "swiglu"):
    if activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = _act(activation)(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Token shift (RWKV)
# ---------------------------------------------------------------------------


def token_shift(x, last: Optional[jnp.ndarray] = None):
    """Shift the sequence right by one: y[t] = x[t-1]; y[0] = last or 0.

    x: [B, L, D]. ``last`` is the final token of the previous chunk
    ([B, D]) when running chunked/stateful decode.
    """
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)
