"""RWKV-6 "Finch" mixer (arXiv:2404.05892): data-dependent decay linear
recurrence, plus the RWKV channel-mix FFN.

State per head: S in R^[hd, hd] with per-channel (k-dim) decay

    out_t[j] = sum_i r_t[i] * ( S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j] )
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]

Training/prefill runs a memory-bounded *nested* scan: an outer
``lax.scan`` over chunks carrying only the [B,H,hd,hd] state (with
``jax.checkpoint`` on the chunk body so the backward pass recomputes
intra-chunk activations instead of storing L copies of S), and an exact
inner scan over the chunk.  Decode is the single-step recurrence.  The
Pallas kernel (``repro.kernels.rwkv6_scan``) implements the chunked
matmul formulation for the MXU; this module is the semantic reference the
kernel is validated against.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, Rwkv6Config
from repro.models.layers import dense_init, token_shift

MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv6_init(key, cfg: ModelConfig, dtype=jnp.float32):
    r: Rwkv6Config = cfg.rwkv
    d = cfg.d_model
    h = d // r.head_dim
    ks = jax.random.split(key, 12)
    p = {
        # static token-shift interpolators (per channel, per branch)
        "mu_base": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(dtype),
        "mu_x": (jax.random.uniform(ks[1], (d,)) * 0.5).astype(dtype),
        # data-dependent token-shift LoRA: d -> 5*rank -> 5*d
        "ts_w1": dense_init(ks[2], d, 5 * r.tokenshift_lora_rank, dtype),
        "ts_w2": (jax.random.normal(ks[3], (5, r.tokenshift_lora_rank, d)) * 0.01).astype(dtype),
        # projections
        "w_r": dense_init(ks[4], d, d, dtype),
        "w_k": dense_init(ks[5], d, d, dtype),
        "w_v": dense_init(ks[6], d, d, dtype),
        "w_g": dense_init(ks[7], d, d, dtype),
        "w_o": dense_init(ks[8], d, d, dtype),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x W1) W2))
        "decay_w0": jnp.full((d,), -5.0, dtype),
        "decay_w1": dense_init(ks[9], d, r.decay_lora_rank, dtype),
        "decay_w2": (jax.random.normal(ks[10], (r.decay_lora_rank, d)) * 0.01).astype(dtype),
        # per-(head,channel) bonus for the current token
        "u": (jax.random.normal(ks[11], (h, r.head_dim)) * 0.1).astype(dtype),
        # per-head output group-norm
        "gn_scale": jnp.ones((d,), dtype),
    }
    return p


def _branch_inputs(params, x, last: Optional[jnp.ndarray]):
    """Data-dependent token-shift mixing (the Finch innovation)."""
    xs = token_shift(x, last)
    dx = xs - x
    xxx = x + dx * params["mu_x"].astype(x.dtype)
    lora = jnp.tanh(xxx @ params["ts_w1"])
    b, l, _ = x.shape
    rank = params["ts_w2"].shape[1]
    lora = lora.reshape(b, l, 5, rank)
    mu_dyn = jnp.einsum("blfr,frd->fbld", lora, params["ts_w2"].astype(x.dtype))
    out = {}
    for i, name in enumerate(MIX_NAMES):
        mu = params["mu_base"][i].astype(x.dtype) + mu_dyn[i]
        out[name] = x + dx * mu
    return out


def _rkvwg(params, x, cfg: ModelConfig, last: Optional[jnp.ndarray] = None):
    rcfg: Rwkv6Config = cfg.rwkv
    hd = rcfg.head_dim
    h = cfg.d_model // hd
    b, l, _ = x.shape
    br = _branch_inputs(params, x, last)
    r = (br["r"] @ params["w_r"]).reshape(b, l, h, hd)
    k = (br["k"] @ params["w_k"]).reshape(b, l, h, hd)
    v = (br["v"] @ params["w_v"]).reshape(b, l, h, hd)
    g = jax.nn.silu(br["g"] @ params["w_g"])
    logw = -jnp.exp(
        params["decay_w0"].astype(jnp.float32)
        + (jnp.tanh(br["w"] @ params["decay_w1"]) @ params["decay_w2"]).astype(jnp.float32))
    w = jnp.exp(logw).reshape(b, l, h, hd)                    # in (0, 1)
    return r, k, v, w, g


def _wkv_step(state, rkvw, u):
    """Single recurrence step. state: [B,H,hd,hd]; r/k/v/w: [B,H,hd]."""
    r, k, v, w = rkvw
    kv = k[..., :, None] * v[..., None, :]                    # [B,H,hd,hd]
    att = state + u[None, :, :, None] * kv
    out = jnp.einsum("bhi,bhij->bhj", r, att)
    new_state = w[..., :, None] * state + kv
    return new_state, out


def wkv_scan(r, k, v, w, u, state=None, chunk: int = 128):
    """Exact WKV recurrence via nested (chunked) scan.

    r/k/v/w: [B, L, H, hd] (fp32 recommended); u: [H, hd].
    Returns (out [B, L, H, hd], final_state [B, H, hd, hd]).
    """
    b, l, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    c = min(chunk, l)
    if l % c:
        c = l  # fall back to a single chunk for ragged lengths
    nchunks = l // c

    def chunk_body(st, xs):
        rc, kc, vc, wc = xs                                   # [c, B, H, hd]
        def step(s, x):
            return _wkv_step(s, x, u)
        st, outs = jax.lax.scan(step, st, (rc, kc, vc, wc))
        return st, outs

    chunk_body = jax.checkpoint(chunk_body)
    swap = lambda t: jnp.moveaxis(t, 1, 0).reshape(nchunks, c, b, h, hd)
    xs = tuple(swap(t.astype(jnp.float32)) for t in (r, k, v, w))
    state, outs = jax.lax.scan(chunk_body, state, xs)         # outs: [nc, c, B, H, hd]
    out = jnp.moveaxis(outs.reshape(l, b, h, hd), 0, 1)
    return out, state


def _group_norm(x, scale, h, eps=1e-5):
    """Per-head layer norm on [B, L, D] reshaped to heads."""
    b, l, d = x.shape
    xh = x.reshape(b, l, h, d // h).astype(jnp.float32)
    mean = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, l, d) * scale.astype(jnp.float32)).astype(x.dtype)


def rwkv6_apply(params, x, cfg: ModelConfig, return_cache: bool = False):
    """Full-sequence time-mix. Cache = (last_token_x, wkv_state)."""
    rcfg: Rwkv6Config = cfg.rwkv
    h = cfg.d_model // rcfg.head_dim
    b, l, d = x.shape
    r, k, v, w, g = _rkvwg(params, x, cfg)
    out, state = wkv_scan(r, k, v, w, params["u"].astype(jnp.float32), chunk=rcfg.chunk_size)
    y = _group_norm(out.reshape(b, l, d).astype(x.dtype), params["gn_scale"], h) * g
    y = y @ params["w_o"]
    if not return_cache:
        return y, None
    return y, {"last_x": x[:, -1], "state": state, "index": jnp.full((), l, jnp.int32)}


def init_rwkv6_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    rcfg: Rwkv6Config = cfg.rwkv
    h = cfg.d_model // rcfg.head_dim
    return {
        "last_x": jnp.zeros((batch, cfg.d_model), dtype),
        "state": jnp.zeros((batch, h, rcfg.head_dim, rcfg.head_dim), jnp.float32),
        "index": jnp.zeros((), jnp.int32),
    }


def rwkv6_decode(params, x, cache, cfg: ModelConfig):
    """One-token decode: O(1) state update — why rwkv6 runs long_500k."""
    rcfg: Rwkv6Config = cfg.rwkv
    h = cfg.d_model // rcfg.head_dim
    b, _, d = x.shape
    r, k, v, w, g = _rkvwg(params, x, cfg, last=cache["last_x"])
    take = lambda t: t[:, 0].astype(jnp.float32)
    state, out = _wkv_step(cache["state"], (take(r), take(k), take(v), take(w)),
                           params["u"].astype(jnp.float32))
    y = _group_norm(out.reshape(b, 1, d).astype(x.dtype), params["gn_scale"], h) * g
    y = y @ params["w_o"]
    return y, {"last_x": x[:, -1], "state": state, "index": cache["index"] + 1}


# ---------------------------------------------------------------------------
# RWKV channel mix (the FFN used between time-mix layers)
# ---------------------------------------------------------------------------


def cmix_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "mu_r": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dtype),
        "w_k": dense_init(ks[1], d, f, dtype),
        "w_v": dense_init(ks[2], f, d, dtype),
        "w_r": dense_init(ks[0], d, d, dtype),
    }


def cmix_apply(params, x, last: Optional[jnp.ndarray] = None):
    xs = token_shift(x, last)
    dx = xs - x
    xk = x + dx * params["mu_k"].astype(x.dtype)
    xr = x + dx * params["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    return jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"])
