"""Decoder-only transformer stack covering all assigned architecture families.

Key design points (production-framework behaviour, not a toy):

* **Per-layer block dispatch** — each layer's mixer (GQA / MLA / RWKV6 /
  Mamba) and FFN (dense / MoE) comes from ``ModelConfig.layer_spec(i)``,
  so DeepSeek-V2 (dense first layer, MLA+MoE rest), Jamba (1:7
  attention:Mamba, MoE every other layer) and Gemma-3 (5:1
  local:global windows) are plain configs.

* **Scan-group compilation** — consecutive layers with identical parameter
  *shapes* are stacked along a leading repeat axis and executed with
  ``jax.lax.scan``; per-layer scalars that vary inside a group (sliding
  window size) are passed as scanned-over data.  This keeps HLO size and
  compile time O(unique-layer-shapes), which matters when lowering a 398B
  Jamba for a 512-chip mesh.  ``remat`` wraps the scan body for training.

* **Stateful serving** — ``prefill`` returns per-layer caches (KV, MLA
  latent, RWKV/Mamba states); ``decode_step`` advances one token. Sliding
  window layers allocate ring-buffer caches of window size only.

All functions are pure; parameters are dict pytrees that stack cleanly
along the federated site axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (dense_init, embed_init, mlp_apply, mlp_init,
                                 rmsnorm_init, rmsnorm_apply, sinusoidal_positions)

# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------


def _signature(cfg: ModelConfig, i: int):
    spec = cfg.layer_spec(i)
    return (spec.mixer, spec.ffn, cfg.dense_ff_for_layer(i), spec.sliding_window)


@dataclasses.dataclass(frozen=True)
class ScanGroup:
    """``n_repeats`` repetitions of a ``period``-layer block pattern.

    Sliding windows are part of the group signature, so every period
    position has a single static window (ring-buffer caches stack
    homogeneously — gemma3's 5 local + 1 global becomes period 6).
    """

    start: int
    period: int
    n_repeats: int
    specs: Tuple[LayerSpec, ...]            # one per period position


def plan_groups(cfg: ModelConfig, max_period: int = 8) -> Tuple[Tuple[int, ...], Optional[ScanGroup]]:
    """Split layers into an unrolled prefix + one periodic scan group.

    Returns (prefix_layer_indices, group-or-None).  The group covers the
    longest periodic suffix whose layers have identical parameter shapes
    and specs; remaining leading layers are unrolled (e.g. DeepSeek-V2's
    dense first layer).
    """
    n = cfg.num_layers
    sigs = [_signature(cfg, i) for i in range(n)]
    for start in range(n):
        remaining = n - start
        if remaining < 2:
            break
        for p in range(1, max_period + 1):
            if remaining % p or remaining // p < 2:
                continue
            if all(sigs[i] == sigs[start + ((i - start) % p)] for i in range(start, n)):
                specs = tuple(cfg.layer_spec(start + j) for j in range(p))
                return tuple(range(start)), ScanGroup(start, p, remaining // p, specs)
    return tuple(range(n)), None


# ---------------------------------------------------------------------------
# Single-layer init / apply
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, i: int, dtype):
    spec = cfg.layer_spec(i)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, dtype),
                         "norm2": rmsnorm_init(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = attn.gqa_init(ks[0], cfg, dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn.mla_init(ks[0], cfg, dtype)
    elif spec.mixer == "rwkv6":
        p["mixer"] = rwkv_mod.rwkv6_init(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.mamba_init(ks[0], cfg, dtype)
    if spec.ffn == "moe":
        p["ffn"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.moe, dtype)
    elif spec.mixer == "rwkv6":
        p["ffn"] = rwkv_mod.cmix_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.dense_ff_for_layer(i), cfg.ffn_activation, dtype)
    return p


def _layer_apply(params, x, cfg: ModelConfig, spec: LayerSpec, window,
                 cache=None, decode: bool = False, make_cache: bool = False,
                 cache_len: Optional[int] = None, moe_impl: str = "dense"):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps)
    new_cache = None
    if spec.mixer in ("attn",):
        if decode:
            y, new_cache = attn.gqa_decode(params["mixer"], h, cache, cfg, window=window)
        else:
            y, new_cache = attn.gqa_apply(params["mixer"], h, cfg, window=window,
                                          return_cache=make_cache, cache_len=cache_len)
    elif spec.mixer == "mla":
        if decode:
            y, new_cache = attn.mla_decode(params["mixer"], h, cache, cfg)
        else:
            y, new_cache = attn.mla_apply(params["mixer"], h, cfg,
                                          return_cache=make_cache, cache_len=cache_len)
    elif spec.mixer == "rwkv6":
        if decode:
            y, new_cache = rwkv_mod.rwkv6_decode(params["mixer"], h,
                                                 cache["mixer"], cfg)
        else:
            y, new_cache = rwkv_mod.rwkv6_apply(params["mixer"], h, cfg,
                                                return_cache=make_cache)
    elif spec.mixer == "mamba":
        if decode:
            y, new_cache = mamba_mod.mamba_decode(params["mixer"], h, cache, cfg)
        else:
            y, new_cache = mamba_mod.mamba_apply(params["mixer"], h, cfg, return_cache=make_cache)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    h2 = rmsnorm_apply(params["norm2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "moe":
        apply_fn = {"dense": moe_mod.moe_apply,
                    "gather": moe_mod.moe_apply_sparse,
                    "dispatch": moe_mod.moe_apply_dispatch}[moe_impl]
        y2, aux = apply_fn(params["ffn"], h2, cfg.moe)
    elif spec.mixer == "rwkv6":
        # channel-mix token shift is stateful across decode steps too
        last = cache["cmix_last"] if decode else None
        y2 = rwkv_mod.cmix_apply(params["ffn"], h2, last=last)
        if new_cache is not None:
            new_cache = {"mixer": new_cache, "cmix_last": h2[:, -1]}
    else:
        y2 = mlp_apply(params["ffn"], h2, cfg.ffn_activation)
    return x + y2, new_cache, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    """Initialize full model parameters (dict pytree)."""
    prefix, group = plan_groups(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: Dict[str, Any] = {}
    vpad = cfg.padded_vocab
    if cfg.num_codebooks > 1:
        params["embed"] = jnp.stack(
            [embed_init(k, vpad, cfg.d_model, dtype)
             for k in jax.random.split(keys[0], cfg.num_codebooks)])
    else:
        params["embed"] = embed_init(keys[0], vpad, cfg.d_model, dtype)
    params["prefix_layers"] = [_layer_init(keys[1 + i], cfg, i, dtype) for i in prefix]
    if group is not None:
        stacked = []
        for j in range(group.period):
            reps = [_layer_init(keys[1 + group.start + r * group.period + j], cfg,
                                group.start + r * group.period + j, dtype)
                    for r in range(group.n_repeats)]
            stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
        params["scan_layers"] = stacked
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["lm_head"] = jnp.stack(
                [dense_init(k, cfg.d_model, vpad, dtype)
                 for k in jax.random.split(keys[-1], cfg.num_codebooks)])
        else:
            params["lm_head"] = dense_init(keys[-1], cfg.d_model, vpad, dtype)
    return params


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via ``jax.eval_shape`` over ``init``.

    ``active_only`` subtracts inactive routed-expert parameters
    (MoE: only top_k of num_experts are live per token).
    """
    shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = sum(1 for s in cfg.layer_specs() if s.ffn == "moe")
        per_expert = 3 * cfg.d_model * m.d_expert
        total -= n_moe_layers * per_expert * (m.num_experts - m.top_k)
    return total


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg: ModelConfig, position_offset=0):
    """tokens: [B, L] or [B, L, K] (codebooks). Returns [B, L, D]."""
    if cfg.num_codebooks > 1:
        x = sum(jnp.take(params["embed"][k], tokens[..., k], axis=0)
                for k in range(cfg.num_codebooks))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.pos_embedding == "sinusoidal":
        l = x.shape[1]
        if isinstance(position_offset, int) and position_offset == 0:
            x = x + sinusoidal_positions(l, cfg.d_model, x.dtype)[None]
        else:
            # decode: compute the sinusoidal row at the dynamic offset
            pos = jnp.asarray(position_offset, jnp.float32)
            dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
            ang = pos / jnp.power(10000.0, dim / cfg.d_model)
            row = jnp.zeros((cfg.d_model,), jnp.float32)
            row = row.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
            x = x + row.astype(x.dtype)[None, None, :]
    return x


def _mask_pad(logits, cfg: ModelConfig):
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
    return jnp.where(pad, -1e30, logits)


def unembed(params, x, cfg: ModelConfig):
    """[B, L, D] -> logits over the PADDED vocab ([B,L,Vp] or [B,L,K,Vp]);
    padding rows are masked to -inf so the softmax ignores them."""
    x32 = x.astype(jnp.float32)
    if cfg.num_codebooks > 1:
        w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        if cfg.tie_embeddings:
            logits = jnp.einsum("bld,kvd->blkv", x32, w.astype(jnp.float32))
        else:
            logits = jnp.einsum("bld,kdv->blkv", x32, w.astype(jnp.float32))
        return _mask_pad(logits, cfg)
    if cfg.tie_embeddings:
        logits = x32 @ params["embed"].astype(jnp.float32).T
    else:
        logits = x32 @ params["lm_head"].astype(jnp.float32)
    return _mask_pad(logits, cfg)


def _scan_forward(params, x, cfg: ModelConfig, group: ScanGroup,
                  remat: bool, moe_impl: str):
    """Run the periodic scan group (training/eval path, no caches)."""

    def body(carry, layer_params):
        h, aux = carry
        for j in range(group.period):
            h, _, a = _layer_apply(layer_params[j], h, cfg, group.specs[j],
                                   group.specs[j].sliding_window,
                                   moe_impl=moe_impl)
            aux = aux + a
        return (h, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["scan_layers"])
    return x, aux


def forward(params, tokens, cfg: ModelConfig, remat: bool = False,
            moe_impl: str = "dense", inputs_embeds=None):
    """Training/eval forward pass. Returns (logits, aux_loss)."""
    prefix, group = plan_groups(cfg)
    x = embed_tokens(params, tokens, cfg) if inputs_embeds is None else inputs_embeds
    aux = jnp.zeros((), jnp.float32)
    for n, i in enumerate(prefix):
        spec = cfg.layer_spec(i)
        x, _, a = _layer_apply(params["prefix_layers"][n], x, cfg, spec,
                               spec.sliding_window, moe_impl=moe_impl)
        aux = aux + a
    if group is not None:
        x, a = _scan_forward(params, x, cfg, group, remat, moe_impl)
        aux = aux + a
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return unembed(params, x, cfg), aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def next_token_loss(params, batch, cfg: ModelConfig, remat: bool = False,
                    moe_impl: str = "dense", aux_coef: Optional[float] = None):
    """Mean next-token cross-entropy (+ MoE load-balance aux)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens, cfg, remat=remat, moe_impl=moe_impl)
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    if cfg.num_codebooks > 1:
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]  # [B,L-1,K]
    else:
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    coef = aux_coef if aux_coef is not None else (cfg.moe.router_aux_coef if cfg.moe else 0.0)
    return loss + coef * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def _cache_for_layer(batch: int, capacity: int, cfg: ModelConfig, spec: LayerSpec,
                     window: Optional[int], dtype):
    if spec.mixer == "attn":
        return attn.init_gqa_cache(batch, capacity, cfg, dtype, window=window)
    if spec.mixer == "mla":
        return attn.init_mla_cache(batch, capacity, cfg, dtype)
    if spec.mixer == "rwkv6":
        return {"mixer": rwkv_mod.init_rwkv6_cache(batch, cfg, dtype),
                "cmix_last": jnp.zeros((batch, cfg.d_model), dtype)}
    if spec.mixer == "mamba":
        return mamba_mod.init_mamba_cache(batch, cfg, dtype)
    raise ValueError(spec.mixer)


def init_caches(batch: int, capacity: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Empty per-layer caches: (prefix list, stacked scan-group caches)."""
    prefix, group = plan_groups(cfg)
    pre = [_cache_for_layer(batch, capacity, cfg, cfg.layer_spec(i),
                            cfg.layer_spec(i).sliding_window, dtype) for i in prefix]
    scan_caches = None
    if group is not None:
        scan_caches = []
        for j in range(group.period):
            reps = [_cache_for_layer(batch, capacity, cfg, group.specs[j],
                                     group.specs[j].sliding_window, dtype)
                    for _ in range(group.n_repeats)]
            scan_caches.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
    return {"prefix": pre, "scan": scan_caches}


def decode_step(params, tokens, caches, cfg: ModelConfig, moe_impl: str = "dispatch"):
    """One-token decode. tokens: [B, 1] (or [B, 1, K]). Returns (logits, caches)."""
    prefix, group = plan_groups(cfg)

    def _index_of(c):
        return c["index"] if "index" in c else c["mixer"]["index"]

    index0 = (_index_of(caches["prefix"][0]) if caches["prefix"]
              else _index_of(caches["scan"][0])[0])
    x = embed_tokens(params, tokens, cfg, position_offset=index0)
    new_prefix = []
    for n, i in enumerate(prefix):
        spec = cfg.layer_spec(i)
        x, c, _ = _layer_apply(params["prefix_layers"][n], x, cfg, spec,
                               spec.sliding_window, cache=caches["prefix"][n],
                               decode=True, moe_impl=moe_impl)
        new_prefix.append(c)
    new_scan = None
    if group is not None:
        def body(h, xs):
            layer_params, layer_caches = xs
            new_cs = []
            for j in range(group.period):
                h, c, _ = _layer_apply(layer_params[j], h, cfg, group.specs[j],
                                       group.specs[j].sliding_window,
                                       cache=layer_caches[j],
                                       decode=True, moe_impl=moe_impl)
                new_cs.append(c)
            return h, new_cs
        x, new_scan = jax.lax.scan(body, x, (params["scan_layers"], caches["scan"]))
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits, {"prefix": new_prefix, "scan": new_scan}


def prefill(params, tokens, cfg: ModelConfig, cache_capacity: int,
            moe_impl: str = "dispatch", cache_dtype=jnp.bfloat16):
    """Full-sequence prefill producing logits + decode-ready caches."""
    prefix, group = plan_groups(cfg)
    x = embed_tokens(params, tokens, cfg)
    new_prefix = []
    for n, i in enumerate(prefix):
        spec = cfg.layer_spec(i)
        x, c, _ = _layer_apply(params["prefix_layers"][n], x, cfg, spec,
                               spec.sliding_window, make_cache=True,
                               cache_len=cache_capacity, moe_impl=moe_impl)
        new_prefix.append(c)
    new_scan = None
    if group is not None:
        def body(h, layer_params):
            new_cs = []
            for j in range(group.period):
                h, c, _ = _layer_apply(layer_params[j], h, cfg, group.specs[j],
                                       group.specs[j].sliding_window, make_cache=True,
                                       cache_len=cache_capacity, moe_impl=moe_impl)
                new_cs.append(c)
            return h, new_cs
        x, new_scan = jax.lax.scan(body, x, params["scan_layers"])
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return unembed(params, x[:, -1:], cfg), {"prefix": new_prefix, "scan": new_scan}
