"""Attention mixers: GQA (with qk-norm / sliding window) and DeepSeek-V2 MLA.

Two entry modes per mixer:
  * ``*_apply``  — full-sequence (training / prefill). Returns output and,
    if ``return_cache``, the KV cache for subsequent decode.
  * ``*_decode`` — one new token against an existing cache (serve_decode).

The cache layout is a dict of arrays with a static ``length`` capacity and a
dynamic ``index`` scalar, so decode steps lower to in-place dynamic-update
slices (no reallocation) and shard cleanly over the mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import shardhints
from repro.models.layers import apply_rope, dense_init, l2norm

NEG_INF = -1e30

# full-sequence attention switches to the online-softmax blockwise path at
# this length (below it the L² reference is cheaper to compile and exact)
BLOCKWISE_MIN_LEN = 1024


# ---------------------------------------------------------------------------
# Masking / softmax core
# ---------------------------------------------------------------------------


def causal_mask(q_len: int, kv_len: int, window: Optional[int] = None):
    """[q_len, kv_len] additive mask. Queries are the *last* q_len positions."""
    q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    k_pos = jnp.arange(kv_len)[None, :]
    ok = k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def sdpa_blockwise(q, k, v, causal: bool = True, window: Optional[int] = None,
                   chunk: int = 512, scale: Optional[float] = None):
    """Memory-bounded attention: online-softmax scan over KV chunks.

    Exact (fp32 accumulators), never materializes the [Lq, Lk] score
    matrix — peak transient is [B, Lq, H, chunk].  This is the pure-jnp
    analogue of the Pallas flash kernel (kernels/flash_attention.py) and
    what the full-scale training/prefill paths use.

    q: [B, Lq, Hq, D]; k/v: [B, Lk, Hkv, D].
    """
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    nchunks = lk // chunk if lk % chunk == 0 else 1
    c = lk // nchunks
    q32 = q.reshape(b, lq, hkv, g, d).astype(jnp.float32) * scale
    q_pos = jnp.arange(lq) + (lk - lq)

    kc = jnp.moveaxis(k.reshape(b, nchunks, c, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nchunks, c, hkv, dv), 1, 0)

    def body(carry, xs):
        acc, m, l = carry
        kj, vj, j = xs
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q32, kj.astype(jnp.float32))
        k_pos = j * c + jnp.arange(c)
        ok = jnp.ones((lq, c), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, lq, hkv, g, dv), jnp.float32)
    m0 = jnp.full((b, lq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, lq, hkv, g), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, l0),
        (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, lq, hq, dv).astype(q.dtype)


def sdpa(q, k, v, mask=None, scale: Optional[float] = None):
    """Reference scaled-dot-product attention with GQA head broadcasting.

    q: [B, Lq, Hq, D]; k/v: [B, Lk, Hkv, D(v)]. fp32 softmax.
    """
    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, lq, hkv, groups, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if mask is not None:
        logits = logits + mask                     # mask broadcasts over [b,h,g]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, lq, hq, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.head_dim if cfg.head_dim is not None else d // hq
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype=dtype)
        p["k_norm"] = jnp.ones((hd,), dtype=dtype)
    return p


def _gqa_project(params, x, cfg: ModelConfig, positions):
    b, l, _ = x.shape
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    hd = cfg.head_dim if cfg.head_dim is not None else cfg.d_model // hq
    q = (x @ params["wq"]).reshape(b, l, hq, hd)
    k = (x @ params["wk"]).reshape(b, l, hkv, hd)
    v = (x @ params["wv"]).reshape(b, l, hkv, hd)
    if cfg.qk_norm:
        q = l2norm(q) * params["q_norm"].astype(q.dtype)
        k = l2norm(k) * params["k_norm"].astype(k.dtype)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # seed head sharding so score contractions stay device-local
    return (shardhints.constrain_heads(q), shardhints.constrain_heads(k),
            shardhints.constrain_heads(v))


def gqa_apply(params, x, cfg: ModelConfig, window: Optional[int] = None,
              return_cache: bool = False, cache_len: Optional[int] = None):
    """Full-sequence GQA attention (train / prefill)."""
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l), (b, l))
    q, k, v = _gqa_project(params, x, cfg, positions)
    if l >= BLOCKWISE_MIN_LEN:
        out = sdpa_blockwise(q, k, v, causal=True, window=window)
    else:
        out = sdpa(q, k, v, causal_mask(l, l, window))
    y = out.reshape(b, l, -1) @ params["wo"]
    if not return_cache:
        return y, None
    cap = cache_len if cache_len is not None else l
    cache = init_gqa_cache(b, cap, cfg, dtype=k.dtype, window=window)
    ring_cap = cache["k"].shape[1]               # == min(cap, window)
    if l >= ring_cap:
        # keep the trailing window, placed at each position's ring slot
        slots = jnp.mod(jnp.arange(l - ring_cap, l), ring_cap)
        kpad = jnp.zeros_like(cache["k"]).at[:, slots].set(k[:, -ring_cap:])
        vpad = jnp.zeros_like(cache["v"]).at[:, slots].set(v[:, -ring_cap:])
    else:
        kpad = jnp.zeros_like(cache["k"]).at[:, :l].set(k)
        vpad = jnp.zeros_like(cache["v"]).at[:, :l].set(v)
    cache = {**cache, "k": kpad, "v": vpad, "index": jnp.full((), l, jnp.int32)}
    return y, cache


def init_gqa_cache(batch: int, capacity: int, cfg: ModelConfig, dtype=jnp.bfloat16,
                   window: Optional[int] = None):
    """Allocate an empty KV cache. Sliding-window layers allocate only the
    window (ring buffer) — this is what makes gemma3 long_500k feasible."""
    hkv = cfg.num_kv_heads
    hd = cfg.head_dim if cfg.head_dim is not None else cfg.d_model // cfg.num_heads
    cap = min(capacity, window) if window is not None else capacity
    return {
        "k": jnp.zeros((batch, cap, hkv, hd), dtype),
        "v": jnp.zeros((batch, cap, hkv, hd), dtype),
        "index": jnp.zeros((), jnp.int32),        # absolute position count
    }


def gqa_decode(params, x, cache, cfg: ModelConfig, window: Optional[int] = None):
    """One-token decode. x: [B, 1, D]; cache from ``init_gqa_cache``."""
    b = x.shape[0]
    idx = cache["index"]
    positions = jnp.broadcast_to(idx[None, None], (b, 1))
    q, k_new, v_new = _gqa_project(params, x, cfg, positions)
    cap = cache["k"].shape[1]
    slot = jnp.mod(idx, cap) if window is not None else idx
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    # validity mask over cache slots
    pos = jnp.arange(cap)
    if window is not None:
        valid = (pos <= slot) | (idx >= cap)      # ring buffer: all valid once full
    else:
        valid = pos <= idx
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    out = sdpa(q, k, v, mask)
    y = out.reshape(b, 1, -1) @ params["wo"]
    new_cache = {"k": k, "v": v, "index": idx + 1}
    return y, new_cache


# ---------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------
#
# Projections (names follow the DeepSeek-V2 paper):
#   q:  x --(wq_a: d->q_lora)--> norm --(wq_b: q_lora -> H*(nope+rope))-->
#   kv: x --(wkv_a: d->(kv_lora + rope))-->  latent c_kv [kv_lora] + k_rope
#       c_kv --(wkv_b: kv_lora -> H*(nope + v))--> k_nope, v
# The decode cache stores ONLY (c_kv, k_rope): (kv_lora + rope) per position.


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype=dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, h * m.qk_head_dim, dtype),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype=dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[4], h * m.v_head_dim, d, dtype),
    }


def _mla_q(params, x, cfg: ModelConfig, positions):
    from repro.models.layers import rmsnorm_apply
    m: MLAConfig = cfg.mla
    b, l, _ = x.shape
    h = cfg.num_heads
    cq = rmsnorm_apply({"scale": params["q_norm"]}, x @ params["wq_a"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(b, l, h, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # the concat loses head sharding without an explicit seed (EXPERIMENTS
    # §Perf deepseek iteration 2: 32 TB/round of score all-reduces without it)
    return shardhints.constrain_heads(jnp.concatenate([q_nope, q_rope], axis=-1))


def _mla_kv_latent(params, x, cfg: ModelConfig, positions):
    from repro.models.layers import rmsnorm_apply
    m: MLAConfig = cfg.mla
    kv = x @ params["wkv_a"]                                 # [B,L,kv_lora+rope]
    c_kv = rmsnorm_apply({"scale": params["kv_norm"]}, kv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]         # [B,L,1,rope]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _mla_expand(params, c_kv, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    b, l, _ = c_kv.shape
    h = cfg.num_heads
    kv = (c_kv @ params["wkv_b"]).reshape(b, l, h, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]


def mla_apply(params, x, cfg: ModelConfig, return_cache: bool = False,
              cache_len: Optional[int] = None):
    m: MLAConfig = cfg.mla
    b, l, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(l), (b, l))
    q = _mla_q(params, x, cfg, positions)                    # [B,L,H,nope+rope]
    c_kv, k_rope = _mla_kv_latent(params, x, cfg, positions)
    k_nope, v = _mla_expand(params, c_kv, cfg)
    v = shardhints.constrain_heads(v)
    k = shardhints.constrain_heads(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, l, cfg.num_heads, m.qk_rope_head_dim))],
        axis=-1))
    if l >= BLOCKWISE_MIN_LEN:
        out = sdpa_blockwise(q, k, v, causal=True, scale=m.qk_head_dim ** -0.5)
    else:
        out = sdpa(q, k, v, causal_mask(l, l), scale=m.qk_head_dim ** -0.5)
    y = out.reshape(b, l, -1) @ params["wo"]
    if not return_cache:
        return y, None
    cap = cache_len if cache_len is not None else l
    cache = init_mla_cache(b, cap, cfg, dtype=c_kv.dtype)
    cache["c_kv"] = cache["c_kv"].at[:, :l].set(c_kv)
    cache["k_rope"] = cache["k_rope"].at[:, :l].set(k_rope)
    cache["index"] = jnp.full((), l, jnp.int32)
    return y, cache


def init_mla_cache(batch: int, capacity: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    m: MLAConfig = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def mla_decode(params, x, cache, cfg: ModelConfig):
    """One-token MLA decode against the compressed latent cache.

    Attention is computed in the *latent* space (the DeepSeek-V2 absorbed
    formulation): q_nope is absorbed through wkv_b's k-half so scores are
    dot-products against c_kv — the cache stays (kv_lora + rope) wide.
    """
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    idx = cache["index"]
    positions = jnp.broadcast_to(idx[None, None], (b, 1))
    q = _mla_q(params, x, cfg, positions)                    # [B,1,H,nope+rope]
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    c_new, r_new = _mla_kv_latent(params, x, cfg, positions)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, idx, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], r_new.astype(cache["k_rope"].dtype), (0, idx, 0))
    # absorb q_nope through the k-half of wkv_b: [kv_lora, H, nope]
    wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_k, w_v = wkv_b[..., : m.qk_nope_head_dim], wkv_b[..., m.qk_nope_head_dim:]
    q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32), w_k.astype(jnp.float32))
    scores = jnp.einsum("bqhc,bkc->bhqk", q_lat, c_kv.astype(jnp.float32))
    scores += jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    scores *= m.qk_head_dim ** -0.5
    cap = cache["c_kv"].shape[1]
    valid = jnp.arange(cap) <= idx
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqk,bkc->bqhc", probs, c_kv.astype(jnp.float32))   # latent values
    out = jnp.einsum("bqhc,chd->bqhd", out_lat, w_v.astype(jnp.float32))
    y = out.reshape(b, 1, -1).astype(x.dtype) @ params["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope, "index": idx + 1}
