"""Deterministic Byzantine adversary plans — the fault-injection half
of the robustness tier.

An :class:`AdversaryPlan` makes ``f`` of the job's sites malicious and
perturbs their contribution at the SITE-UPDATE SEAM — the one point
every transport shares: the params a site is about to expose to
aggregation.  On the stacked simulator the perturbation is traced into
the round body (malicious & active rows of the [S, N] state, between
local training and ``post_exchange``); on socket workers the same
perturbation is applied host-side to the upload payload in
``_run_site``.  Because ``post_exchange`` overwrites every active row
with the new global, a stacked perturbation never persists into the
next round — exactly matching the socket path, where only the wire
payload is perturbed and the site's local state is clean.

Determinism is the point: which sites are malicious is a pure function
of ``(seed, num_sites)`` (no RNG state threads through the round scan),
and the noise attack's randomness is a counter-derived key chain
``fold_in(fold_in(fold_in(key(seed), round), site), leaf)`` — so the
same plan replays bit-identically across scan/loop/thread/tcp engines
and across ``--resume`` restarts (tested in tests/test_robustness.py).

Spec grammar (``--adversary`` on the train CLI; last field = f sites)::

    sign_flip:f      f sites upload −params
    scale:c:f        f sites upload c·params
    noise:s:f        f sites upload params + s·N(0,1)
    label_flip:f     f sites train on corrupted targets (floats negated,
                     int targets reversed along the last axis — a pure
                     permutation of examples would be a mean-loss no-op)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stacking import where_site

# keys of a batch dict that count as training targets for label_flip
TARGET_KEYS = ("dose", "labels", "tokens")

_SELECT_SALT = 104729   # site-selection stream, disjoint from data/DP seeds
_NOISE_SALT = 60013     # noise-attack key chain


@dataclasses.dataclass(frozen=True)
class AdversaryPlan:
    """Seeded selection of f malicious sites + the perturbation they apply."""
    kind: str           # sign_flip | scale | noise | label_flip
    f: int              # number of malicious sites
    param: float = 0.0  # c for scale, s for noise
    seed: int = 0

    @property
    def flips_labels(self) -> bool:
        return self.kind == "label_flip"

    @property
    def flips_params(self) -> bool:
        return self.kind in ("sign_flip", "scale", "noise")

    # -- site selection (host, pure in (seed, num_sites)) -------------------

    def malicious_mask(self, num_sites: int) -> np.ndarray:
        """[S] bool — the fixed malicious set.  A pure function of
        ``(seed, num_sites)`` so every worker process and every resume
        derives the identical set with no coordination."""
        mask = np.zeros((num_sites,), bool)
        if self.f <= 0:
            return mask
        rng = np.random.default_rng((self.seed + _SELECT_SALT, num_sites))
        idx = rng.choice(num_sites, size=min(self.f, num_sites),
                         replace=False)
        mask[idx] = True
        return mask

    def is_malicious(self, site_id: int, num_sites: int) -> bool:
        return bool(self.malicious_mask(num_sites)[site_id])

    # -- noise key chain (shared by traced and host paths) ------------------

    def _round_key(self, rnd):
        return jax.random.fold_in(
            jax.random.PRNGKey(self.seed + _NOISE_SALT), rnd)

    # -- traced seam (stacked engines) --------------------------------------

    def perturb_stacked(self, params_stacked, mask, rnd):
        """Perturb the masked rows of a site-stacked params pytree.

        ``mask`` is [S] bool — the caller passes ``malicious & active``
        so inactive malicious rows keep their clean local state (parity
        with sockets, where a dropped site uploads nothing).  ``rnd``
        may be traced (the scan's round counter).
        """
        if not self.flips_params:
            return params_stacked
        if self.kind == "sign_flip":
            pert = jax.tree.map(lambda p: -p, params_stacked)
        elif self.kind == "scale":
            pert = jax.tree.map(
                lambda p: p * jnp.asarray(self.param, p.dtype),
                params_stacked)
        else:  # noise
            base = self._round_key(rnd)
            leaves, treedef = jax.tree.flatten(params_stacked)
            s = leaves[0].shape[0]
            site_keys = jax.vmap(
                lambda sid: jax.random.fold_in(base, sid))(jnp.arange(s))
            out = []
            for li, p in enumerate(leaves):
                noise = jax.vmap(
                    lambda k, sh=p.shape[1:], i=li: jax.random.normal(
                        jax.random.fold_in(k, i), sh))(site_keys)
                out.append((p.astype(jnp.float32)
                            + jnp.float32(self.param) * noise).astype(p.dtype))
            pert = jax.tree.unflatten(treedef, out)
        return where_site(mask, pert, params_stacked)

    def perturb_batches(self, batches, mask):
        """label_flip on the masked rows of a site-stacked batch dict:
        float targets negate, integer targets reverse along the example
        axis.  Non-target keys and other attack kinds pass through."""
        if not self.flips_labels or not isinstance(batches, dict):
            return batches
        out = dict(batches)
        for key in TARGET_KEYS:
            if key in out:
                v = out[key]
                out[key] = where_site(mask, _flip_target(v), v)
        return out

    # -- host seam (socket workers) -----------------------------------------

    def perturb_tree(self, tree, site_id: int, rnd: int):
        """Host twin of :meth:`perturb_stacked` for ONE site's upload
        payload (numpy leaves).  Same key chain at the same unstacked
        leaf shapes, so noise is bit-identical to the traced rows."""
        if not self.flips_params:
            return tree
        if self.kind == "sign_flip":
            return jax.tree.map(_neg_host, tree)
        if self.kind == "scale":
            return jax.tree.map(
                lambda p: _scale_host(p, self.param), tree)
        site_key = jax.random.fold_in(self._round_key(rnd), site_id)
        leaves, treedef = jax.tree.flatten(tree)
        out = []
        for li, p in enumerate(leaves):
            a = np.asarray(p)
            if not np.issubdtype(a.dtype, np.floating):
                out.append(a)
                continue
            noise = np.asarray(jax.random.normal(
                jax.random.fold_in(site_key, li), a.shape))
            out.append((a.astype(np.float32)
                        + np.float32(self.param) * noise).astype(a.dtype))
        return jax.tree.unflatten(treedef, out)

    def perturb_batch(self, batch):
        """Host twin of :meth:`perturb_batches` for one malicious site's
        (unstacked) batch dict."""
        if not self.flips_labels or not isinstance(batch, dict):
            return batch
        out = dict(batch)
        for key in TARGET_KEYS:
            if key in out:
                out[key] = _flip_target(out[key])
        return out


def _flip_target(v):
    v = jnp.asarray(v)
    if jnp.issubdtype(v.dtype, jnp.floating):
        return -v
    return jnp.flip(v, axis=-1)


def _neg_host(p):
    a = np.asarray(p)
    return -a if np.issubdtype(a.dtype, np.floating) else a


def _scale_host(p, c):
    a = np.asarray(p)
    if not np.issubdtype(a.dtype, np.floating):
        return a
    return (a.astype(np.float32) * np.float32(c)).astype(a.dtype)


def parse_adversary(spec, seed: int = 0) -> Optional[AdversaryPlan]:
    """``sign_flip:f | label_flip:f | scale:c:f | noise:s:f`` → plan.

    The LAST field is always the malicious-site count f; scale/noise
    carry their magnitude in the middle.  ``None``/empty → no adversary.
    Accepts an already-parsed plan (idempotent — the seed argument is
    ignored then).
    """
    if spec is None or isinstance(spec, AdversaryPlan):
        return spec
    text = str(spec).strip()
    if not text or text == "none":
        return None
    parts = text.split(":")
    kind = parts[0].strip()
    try:
        if kind in ("sign_flip", "label_flip"):
            if len(parts) != 2 or int(parts[1]) < 1:
                raise ValueError
            return AdversaryPlan(kind, f=int(parts[1]), seed=seed)
        if kind in ("scale", "noise"):
            if len(parts) != 3 or int(parts[2]) < 1:
                raise ValueError
            return AdversaryPlan(kind, f=int(parts[2]),
                                 param=float(parts[1]), seed=seed)
    except ValueError:
        pass
    raise ValueError(f"unknown adversary {text!r} (expected sign_flip:f | "
                     "label_flip:f | scale:c:f | noise:s:f)")
