"""Weighted model aggregation (paper Eq. 1) with dropout masking.

``fedavg_aggregate`` implements  w^{t+1} = Σ_i (m_i / m) w_i^{t+1}
restricted to active sites; inactive sites keep their local weights
(the "disconnect" scenario) — the coordination server simply exempts
them from the round.

``hierarchical_aggregate`` is the multi-pod path: aggregate within each
pod first (ICI all-reduce), then across pods (DCN) — bandwidth-optimal
when the "pod" axis is the slow link, and semantically identical because
FedAvg's weighted mean is associative over correctly re-weighted groups.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.stacking import broadcast_to_sites, weighted_mean, where_site


def normalized_weights(case_weights: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """m_i/m over the active subset; zero for inactive sites."""
    w = case_weights.astype(jnp.float32) * active.astype(jnp.float32)
    return w / (jnp.sum(w) + 1e-12)


def fedavg_aggregate(params_stacked, case_weights: jnp.ndarray,
                     active: Optional[jnp.ndarray] = None):
    """Eq. 1. Returns the new stacked params (global model broadcast to
    active sites; inactive sites keep their current local weights)."""
    s = jax.tree.leaves(params_stacked)[0].shape[0]
    if active is None:
        active = jnp.ones((s,), bool)
    w = normalized_weights(case_weights, active)
    global_params = weighted_mean(params_stacked, w)
    broadcast = broadcast_to_sites(global_params, s)
    return where_site(active, broadcast, params_stacked), global_params


def hierarchical_aggregate(params_stacked, case_weights: jnp.ndarray,
                           sites_per_pod: int,
                           active: Optional[jnp.ndarray] = None):
    """Two-level FedAvg: per-pod partial means, then cross-pod combine.

    Mathematically equal to ``fedavg_aggregate`` (weighted means compose);
    structurally it lowers to an in-pod all-reduce followed by a much
    smaller cross-pod exchange, matching how a real deployment would nest
    gRPC aggregation servers per region.
    """
    s = jax.tree.leaves(params_stacked)[0].shape[0]
    npods = s // sites_per_pod
    if active is None:
        active = jnp.ones((s,), bool)
    w = (case_weights.astype(jnp.float32) * active.astype(jnp.float32))
    wp = w.reshape(npods, sites_per_pod)
    pod_tot = jnp.sum(wp, axis=1)                          # [P]

    def agg(x):
        xp = x.astype(jnp.float32).reshape((npods, sites_per_pod) + x.shape[1:])
        pod_mean = jnp.einsum("ps,ps...->p...", wp / (pod_tot[:, None] + 1e-12), xp)
        g = jnp.einsum("p,p...->...", pod_tot / (jnp.sum(pod_tot) + 1e-12), pod_mean)
        return g.astype(x.dtype)

    global_params = jax.tree.map(agg, params_stacked)
    broadcast = broadcast_to_sites(global_params, s)
    return where_site(active, broadcast, params_stacked), global_params
