"""Weighted model aggregation (paper Eq. 1) with dropout masking.

``fedavg_aggregate`` implements  w^{t+1} = Σ_i (m_i / m) w_i^{t+1}
restricted to active sites; inactive sites keep their local weights
(the "disconnect" scenario) — the coordination server simply exempts
them from the round.

``hierarchical_aggregate`` is the multi-pod path: aggregate within each
pod first (ICI all-reduce), then across pods (DCN) — bandwidth-optimal
when the "pod" axis is the slow link, and semantically identical because
FedAvg's weighted mean is associative over correctly re-weighted groups.

Both are thin wrappers over the shared :class:`AggregationEngine`
(``repro.core.agg_engine``), the single implementation of Eq. 1: one
padded [S, N] ravel, Pallas kernel on TPU/GPU, jnp reduction on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.agg_engine import get_engine, normalized_weights  # noqa: F401


def fedavg_aggregate(params_stacked, case_weights: jnp.ndarray,
                     active: Optional[jnp.ndarray] = None):
    """Eq. 1 via the AggregationEngine.  Returns the new stacked params
    (global model broadcast to active sites; inactive sites keep their
    current local weights) and the global params."""
    return get_engine().aggregate(params_stacked, case_weights, active)


def hierarchical_aggregate(params_stacked, case_weights: jnp.ndarray,
                           sites_per_pod: int,
                           active: Optional[jnp.ndarray] = None):
    """Two-level FedAvg via the AggregationEngine: per-pod partial means,
    then cross-pod combine — mathematically equal to ``fedavg_aggregate``
    (weighted means compose)."""
    return get_engine().aggregate_hierarchical(
        params_stacked, case_weights, sites_per_pod, active)
