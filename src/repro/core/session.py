"""The sync/buffered-async scheduler seam + shared round bookkeeping.

A :class:`RoundScheduler` decides, for every upload the aggregation
point sees, (a) whether the upload is admitted and at what weight
(``discount``), and (b) when the buffered uploads are aggregated into a
new global model (``ready``).  The same two questions are asked by every
execution backend — the vmapped in-process simulator, the threaded TCP
stack, and the multi-process TCP stack — so one scheduler object plugs
into all of them:

  * :class:`SyncScheduler` — the classic barrier round: admit only
    uploads for the current round (anything else is acked ``stale`` and
    dropped), aggregate once every active site has reported.
  * :class:`BufferedScheduler` — FedBuff-style buffered async (Nguyen et
    al. 2022): aggregate after ``buffer_k`` of S uploads; admit late
    uploads at a staleness-discounted weight ``(1+τ)^(-alpha)``; reject
    uploads staler than ``max_staleness`` (the contributor resyncs to
    the current global instead).

Both fold straight into the PR-1 :class:`~repro.core.agg_engine.StreamingAccumulator`
— the accumulator normalizes by the folded weight total, so the
effective per-upload weights always sum to 1.

:class:`RoundRecorder` / :class:`JobResult` are the transport-agnostic
history + checkpoint bookkeeping every backend shares (``JobResult.comm``
carries the run's upload/download byte accounting — real wire bytes on
socket transports, simulated payload bytes on the stacked simulator),
and :func:`availability_masks` replays the Algorithm-2 dropout chain
deterministically so distributed site processes agree on the schedule
without extra coordination traffic.

Staleness interacts with the compression seam: a quantized *delta*
upload is anchored to the global version its ``discount`` staleness is
measured against, so the aggregation point keeps a bounded history of
recent globals to decode against (``AggregationServer.keep_globals``).
The full pull → local steps → upload → fold → broadcast lifecycle for
both schedulers is documented in ``docs/architecture.md``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np


# ---------------------------------------------------------------------------
# Scheduler seam
# ---------------------------------------------------------------------------


class RoundScheduler:
    """When do buffered uploads become a new global model, and at what
    weight does each upload enter the buffer?"""

    name: str = "base"

    def discount(self, staleness: int) -> Optional[float]:
        """Weight multiplier for an upload ``staleness`` global-model
        versions old (0 = trained on the current global).  ``None``
        rejects the upload outright."""
        raise NotImplementedError

    def ready(self, buffered: int, expected: int) -> bool:
        """True once ``buffered`` folded uploads should be finalized into
        a new global (``expected`` = currently active sites)."""
        raise NotImplementedError


@dataclass
class SyncScheduler(RoundScheduler):
    """Barrier semantics: current-round uploads only, wait for all.

    ``round_deadline_s`` bounds how long the barrier waits on
    stragglers: once at least one upload has folded and the deadline
    has elapsed since it arrived, the aggregation server finalizes the
    round with whoever reported (graceful degradation — late uploads
    then hit the ordinary stale-ack path, reusing the Algorithm-2 mask
    machinery).  ``None`` keeps the strict barrier.  Wall-clock is a
    socket-transport concept; stacked engines ignore the deadline.
    """

    round_deadline_s: Optional[float] = None

    name = "sync"

    def discount(self, staleness: int) -> Optional[float]:
        return 1.0 if staleness == 0 else None

    def ready(self, buffered: int, expected: int) -> bool:
        return buffered >= expected


@dataclass
class BufferedScheduler(RoundScheduler):
    """FedBuff-style K-of-S buffered aggregation with staleness discount.

    ``buffer_k``      — aggregate once this many uploads are buffered
                        (clamped to the active-site count).
    ``alpha``         — staleness exponent: weight ∝ (1+τ)^(-alpha).
    ``max_staleness`` — uploads more than this many versions old are
                        rejected; their site resyncs without contributing.
    """

    buffer_k: int = 2
    alpha: float = 0.5
    max_staleness: int = 4

    name = "buffered"

    def discount(self, staleness: int) -> Optional[float]:
        if staleness < 0 or staleness > self.max_staleness:
            return None
        return float((1.0 + staleness) ** (-self.alpha))

    def ready(self, buffered: int, expected: int) -> bool:
        return buffered >= min(self.buffer_k, max(expected, 1))

    def staleness_weights(self, staleness: Sequence[int]) -> np.ndarray:
        """Normalized buffer weights for uploads at the given staleness
        values (what the accumulator's finalize-normalization produces)."""
        weights = []
        for tau in staleness:
            w = self.discount(int(tau))
            if w is None:
                raise ValueError(f"staleness {tau} outside "
                                 f"[0, {self.max_staleness}]")
            weights.append(w)
        d = np.asarray(weights, dtype=np.float64)
        return (d / d.sum()).astype(np.float32)


_SCHEDULERS = {"sync": SyncScheduler, "buffered": BufferedScheduler}


def resolve_scheduler(spec: Union[str, RoundScheduler, None]) -> RoundScheduler:
    if spec is None:
        return SyncScheduler()
    if isinstance(spec, RoundScheduler):
        return spec
    try:
        return _SCHEDULERS[spec]()
    except KeyError:
        raise KeyError(f"unknown scheduler {spec!r}; known: {sorted(_SCHEDULERS)}")


# ---------------------------------------------------------------------------
# Deterministic dropout replay (shared by job process and site workers)
# ---------------------------------------------------------------------------


def availability_masks(num_sites: int, max_dropout: int, seed: int,
                       rounds: int, topology=None,
                       pod_dropout: int = 0) -> np.ndarray:
    """[rounds, num_sites] bool active masks from the Algorithm-2 chain.

    Every participant that replays this with the same arguments gets the
    same schedule — distributed site processes agree on who is active
    each round without talking to the coordinator.

    With a pods :class:`~repro.core.topology.Topology` and
    ``pod_dropout > 0``, a second Algorithm-2 chain runs at the POD tier
    (an institution hub losing its uplink takes all member sites offline
    that round); the two chains consume distinct streams and compose by
    intersection.
    """
    from repro.core.dropout import SiteAvailability
    chain = SiteAvailability(num_sites, max_dropout, seed=seed)
    masks = np.stack([chain.step() for _ in range(rounds)])
    if topology is not None and pod_dropout:
        from repro.core.topology import pod_availability_masks
        pod_masks = pod_availability_masks(topology, num_sites, pod_dropout,
                                           seed, rounds)
        combined = masks & pod_masks
        # each chain on its own guarantees survivors (max_dropout < S,
        # pod_dropout < P); their intersection does not — an all-offline
        # round would deadlock sync barriers and zero the Eq. 1 weights.
        # Rule: pod-tier churn takes precedence on such rounds (the
        # active pods' sites participate).  Deterministic, so every
        # replaying participant agrees.
        empty = ~combined.any(axis=1)
        combined[empty] = pod_masks[empty]
        masks = combined
    return masks


# ---------------------------------------------------------------------------
# Round history / checkpoint bookkeeping (transport-agnostic)
# ---------------------------------------------------------------------------


@dataclass
class JobResult:
    """What ``FederatedJob.run`` hands back, whatever the backend."""

    history: List[Dict[str, Any]]
    global_params: Any                      # the aggregated global model
    wall_s: float
    transport: str
    scheduler: str
    state: Optional[Dict[str, Any]] = None  # stacked fl_state (stacked only)
    # communication accounting: upload/download bytes for the run (real
    # wire bytes on socket transports, simulated payload bytes on the
    # stacked simulator — see benchmarks/comm_bytes.py); None when the
    # strategy has no measured exchange
    comm: Optional[Dict[str, Any]] = None
    # jit compile time, measured once per program shape and kept OUT of
    # the per-round ``step_s`` history (round 0 used to absorb it)
    compile_s: float = 0.0
    # crash resume: the checkpoint round this run re-entered from
    # (None = started at round 0); history then covers only the rounds
    # actually executed by this invocation
    resumed_from: Optional[int] = None
    # privacy report (repro.privacy): the run's (ε, δ) from the Rényi
    # accountant plus the DP-SGD / secure-aggregation settings; None
    # when no privacy mechanism is on
    privacy: Optional[Dict[str, Any]] = None
    # upload sanitation: how many uploads the aggregation point REJECTED
    # (non-finite leaves, norm outliers, undecodable payloads) instead
    # of folding into the global.  Server-authoritative on socket
    # transports; 0 on the stacked simulator, whose rows never cross a
    # wire.
    rejected_uploads: int = 0

    @property
    def losses(self) -> List[float]:
        return [h["loss"] for h in self.history]

    @property
    def final_loss(self) -> float:
        # empty history is legal: a resume that re-enters at the final
        # checkpoint has no rounds left to execute
        if not self.history:
            return float("nan")
        return self.history[-1]["loss"]

    def to_dict(self) -> Dict[str, Any]:
        return {"history": self.history, "final_loss": self.final_loss,
                "wall_s": self.wall_s, "compile_s": self.compile_s,
                "transport": self.transport,
                "scheduler": self.scheduler, "comm": self.comm,
                "resumed_from": self.resumed_from,
                "privacy": self.privacy,
                "rejected_uploads": self.rejected_uploads}


def check_engine_tag(meta: Dict[str, Any], engine: str):
    """Guard a ``driver_state`` resume: the checkpointed carry only fits
    the engine path that wrote it (scan carries ≠ loop state dicts)."""
    saved = meta.get("engine")
    if saved != engine:
        raise ValueError(
            f"driver_state checkpoint was written by engine {saved!r} but "
            f"this run resolves to {engine!r}; resume with the same "
            "round_engine / compression / scheduler settings")


def check_privacy_tag(meta: Dict[str, Any], dp_tag: Optional[List[Any]]):
    """Guard a resume across DP settings: the noise stream is a pure
    function of (seed, round, site, step) *given the DP config*, so
    re-entering with different clip/σ/mode would silently splice two
    different mechanisms into one trajectory (and void the accountant)."""
    saved = meta.get("dp")
    if saved is not None or dp_tag is not None:
        if list(saved or []) != list(dp_tag or []):
            raise ValueError(
                f"driver_state checkpoint was written with DP settings "
                f"{saved!r} but this run resolves to {dp_tag!r}; resume "
                "with the same dp_clip / dp_noise_multiplier / dp_mode "
                "/ seed")


class RoundRecorder:
    """Per-round history, progress printing and checkpointing — the
    bookkeeping every transport shares instead of reimplementing."""

    def __init__(self, rounds: int, *, verbose: bool = False,
                 log_every: Optional[int] = None,
                 checkpoint_dir: Optional[Union[str, Path]] = None,
                 ckpt_every: int = 10, num_sites: int = 1):
        self.rounds = rounds
        self.verbose = verbose
        self.log_every = log_every or max(rounds // 10, 1)
        self.ckpt_every = ckpt_every
        self.num_sites = num_sites
        self.history: List[Dict[str, Any]] = []
        self.store = None
        if checkpoint_dir:
            from repro.checkpoint import CheckpointStore
            self.store = CheckpointStore(Path(checkpoint_dir))
        self._t0 = time.time()
        self._t_last = self._t0

    @property
    def elapsed(self) -> float:
        """Seconds since the recorder (and hence the run) started."""
        return time.time() - self._t0

    def record(self, round_index: int, per_site_loss, active,
               global_fn=None, extra: Optional[Dict[str, Any]] = None):
        now = time.time()
        per_site = np.asarray(per_site_loss, dtype=np.float64).reshape(-1)
        loss = float(np.nanmean(per_site))
        n_active = int(np.sum(active))
        rec = {"round": round_index, "loss": loss, "active": n_active,
               "per_site_loss": per_site.tolist(),
               "wall_s": now - self._t_last, **(extra or {})}
        self.history.append(rec)
        self._t_last = now
        if self.verbose and (round_index % self.log_every == 0
                             or round_index == self.rounds - 1):
            print(f"round {round_index:4d} loss {loss:.4f} "
                  f"active {n_active}/{self.num_sites}")
        if (self.store and global_fn is not None
                and round_index % self.ckpt_every == 0):
            self.store.save("global", round_index, global_fn())

    def save_state(self, round_index: int, state_fn,
                   meta: Optional[Dict[str, Any]] = None):
        """Persist resumable engine state ("driver_state" tag) on the
        same ``ckpt_every`` grid as the global model.  ``state_fn`` is
        called lazily (host transfer of a scan carry is not free) and
        must return a pytree whose structure the resuming engine can
        rebuild as a ``like`` — the ``meta["engine"]`` tag guards
        against resuming across engine paths with different carries."""
        if self.store and round_index % self.ckpt_every == 0:
            self.store.save("driver_state", round_index, state_fn(),
                            meta=meta)

    def result(self, global_params, *, transport: str, scheduler: str,
               state=None, comm=None, compile_s: float = 0.0,
               resumed_from: Optional[int] = None,
               privacy: Optional[Dict[str, Any]] = None,
               rejected_uploads: int = 0) -> JobResult:
        return JobResult(history=self.history, global_params=global_params,
                         wall_s=time.time() - self._t0, transport=transport,
                         scheduler=scheduler, state=state, comm=comm,
                         compile_s=compile_s, resumed_from=resumed_from,
                         privacy=privacy, rejected_uploads=rejected_uploads)
