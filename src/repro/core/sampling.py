"""Per-round client sampling — the cross-device participation seam.

The paper evaluates FedKBP+ cross-silo: dozens of sites, every site in
every round.  The production regime the FL surveys treat as primary is
cross-device — thousands of sites, a *sampled* fraction per round.  This
module is that seam: a :class:`ClientSampler` decides which sites are
*scheduled* each round, independently of whether they are *available*
(the Algorithm-2 dropout chain).  The two compose by intersection:

    participate[r] = sampled[r] & available[r]

with one deterministic precedence rule (the same shape as the PR-5
pod-churn fix in :func:`repro.core.session.availability_masks`): if the
intersection of a round is empty — a sync barrier would deadlock and the
Eq. 1 weights would all be zero — the availability mask wins and every
available site participates at scale 1 that round.

Sampler specs, mirroring ``resolve_topology``:

  * ``"none"``        — every available site, every round (cross-silo).
  * ``"uniform:K"``   — K sites uniformly without replacement per round
                        (inclusion probability π = K/S).
  * ``"poisson:q"``   — each site independently with probability q per
                        round (π = q) — the sampling model the privacy
                        accountant's amplification bound assumes.

Determinism: each round's mask is a **pure function of (seed, round)**
— a fresh ``np.random.default_rng((seed + SAMPLER_SEED_OFFSET, r))``
per round, no chain state — so the scan engine, the retired loop, a
``--resume`` re-entry mid-job, and distributed socket workers all replay
the identical schedule from the job seed alone.

Eq. 1 reweighting: sampled aggregation weights each participant by
``case_weight · 1/π`` (Horvitz–Thompson inclusion-probability
reweighting) and then self-normalizes, the standard Hájek estimator:
numerator and denominator are each unbiased for their dense
counterparts, and with uniform case weights the full estimator is
exactly unbiased under ``uniform:K``.  :func:`compose_participation`
returns the per-round ``[S]`` float scale (``1/π`` on sampled rounds,
``1.0`` on fallback rounds) that the engines multiply into
``normalized_weights``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple, Union

import numpy as np

# the sampler draws from its own derived stream, disjoint from the
# Algorithm-2 site chain (seed), the pod chain (seed + 9973) and the
# buffered arrival order (seed + 13)
SAMPLER_SEED_OFFSET = 7919


@dataclass(frozen=True)
class ClientSampler:
    """Which sites are scheduled each round.  ``kind`` ∈ {none, uniform,
    poisson}; ``count`` is uniform's K, ``rate`` is poisson's q."""

    kind: str = "none"
    count: int = 0          # uniform:K
    rate: float = 0.0       # poisson:q

    @property
    def spec(self) -> str:
        """The canonical string form (what ``--sample`` parses)."""
        if self.kind == "uniform":
            return f"uniform:{self.count}"
        if self.kind == "poisson":
            return f"poisson:{self.rate:g}"
        return "none"

    def is_trivial(self, num_sites: int) -> bool:
        """True when the sampler schedules every site every round —
        ``none``, ``uniform:K`` with K ≥ S, ``poisson:q`` with q ≥ 1.
        Trivial samplers take the dense code path verbatim, which is
        what makes ``uniform:S`` bit-exact against an unsampled run."""
        if self.kind == "none":
            return True
        if self.kind == "uniform":
            return self.count >= num_sites
        return self.rate >= 1.0

    def inclusion_probability(self, num_sites: int) -> float:
        """π — every site's per-round inclusion probability (constant
        across sites for both sampler families)."""
        if self.is_trivial(num_sites):
            return 1.0
        if self.kind == "uniform":
            return self.count / num_sites
        return self.rate

    def round_mask(self, num_sites: int, seed: int,
                   round_index: int) -> np.ndarray:
        """[S] bool scheduled mask for one round — a pure function of
        (seed, round): no chain state, so every engine and every resumed
        or distributed participant replays it independently."""
        if self.is_trivial(num_sites):
            return np.ones((num_sites,), bool)
        rng = np.random.default_rng(
            (seed + SAMPLER_SEED_OFFSET, round_index))
        mask = np.zeros((num_sites,), bool)
        if self.kind == "uniform":
            mask[rng.permutation(num_sites)[:self.count]] = True
        else:
            mask = rng.random(num_sites) < self.rate
        return mask

    def masks(self, num_sites: int, seed: int, rounds: int) -> np.ndarray:
        """[rounds, S] scheduled masks (stacked :meth:`round_mask`)."""
        return np.stack([self.round_mask(num_sites, seed, r)
                         for r in range(rounds)])


NONE_SAMPLER = ClientSampler()


def resolve_sampler(spec: Union[str, ClientSampler, None]) -> ClientSampler:
    """``"none" | "uniform:K" | "poisson:q"`` (or a ClientSampler) →
    :class:`ClientSampler`, mirroring ``resolve_topology``."""
    if spec is None:
        return NONE_SAMPLER
    if isinstance(spec, ClientSampler):
        return spec
    if spec == "none":
        return NONE_SAMPLER
    if spec.startswith("uniform:"):
        try:
            k = int(spec.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad sampler spec {spec!r}: uniform:K needs "
                             "an integer K")
        if k < 1:
            raise ValueError(f"uniform:K needs K >= 1, got {k}")
        return ClientSampler(kind="uniform", count=k)
    if spec.startswith("poisson:"):
        try:
            q = float(spec.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad sampler spec {spec!r}: poisson:q needs "
                             "a float q")
        if not 0.0 < q:
            raise ValueError(f"poisson:q needs q > 0, got {q}")
        return ClientSampler(kind="poisson", rate=q)
    raise ValueError(f"unknown sampler spec {spec!r}; known: none, "
                     "uniform:K, poisson:q")


def compose_participation(sampler: ClientSampler, available: np.ndarray,
                          seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Intersect the sampler's schedule with the [rounds, S] Algorithm-2
    availability masks.

    Returns ``(participate, scale)``:

      * ``participate`` [rounds, S] bool — sampled ∩ available, except
        on rounds where that intersection is empty: there the
        availability mask takes precedence (deterministic, so every
        replaying participant agrees — the same rule the pod-churn
        composition uses), guaranteeing no round ever has all-zero
        Eq. 1 weights.
      * ``scale`` [rounds, S] float32 — the Horvitz–Thompson ``1/π``
        inclusion-probability factor on participating rows (``1.0`` on
        fallback rounds and for trivial samplers), zero elsewhere.
    """
    available = np.asarray(available, bool)
    rounds, num_sites = available.shape
    if sampler.is_trivial(num_sites):
        return available, available.astype(np.float32)
    sampled = sampler.masks(num_sites, seed, rounds)
    participate = sampled & available
    inv_pi = np.float32(1.0 / sampler.inclusion_probability(num_sites))
    scale = participate.astype(np.float32) * inv_pi
    # empty intersection: the availability mask wins at scale 1 — a
    # full-availability round, not a skipped one (sync barriers and the
    # Eq. 1 denominator both need at least one participant)
    empty = ~participate.any(axis=1)
    participate[empty] = available[empty]
    scale[empty] = available[empty].astype(np.float32)
    return participate, scale
