"""Regional Deep Contrastive Mutual Learning (paper Eq. 3, GCML's core).

The contrastive KL divergence D_CKL aligns two models' predictive
distributions where a *reference* model classifies correctly and pushes
them apart where it is wrong:

    D_CKL(P_a ∥ P_b) = mean_{region ok} KL(P_b ∥ P_a)
                     - β · mean_{region wrong} KL(P_b ∥ P_a)

where the region masks come from the reference model's argmax vs the
label, and P_b (the target) is gradient-stopped — model ``a`` learns
from ``b`` (mutual distillation) without ``b`` being dragged through
``a``'s loss.  "Region" is generic: voxels for SA-Net segmentation,
token positions for the LLM architectures (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def _kl(p_logits, q_logits):
    """KL(q ∥ p) per position (target q is the teacher; fp32)."""
    p = jax.nn.log_softmax(p_logits.astype(jnp.float32), axis=-1)
    q = jax.nn.log_softmax(q_logits.astype(jnp.float32), axis=-1)
    return jnp.sum(jnp.exp(q) * (q - p), axis=-1)


def contrastive_kl(student_logits, teacher_logits, labels, beta: float = 1.0):
    """D_CKL(P_student ∥ P_teacher) with the *teacher* as reference.

    student/teacher logits: [..., V]; labels: [...] int.  Returns scalar.
    """
    teacher_logits = jax.lax.stop_gradient(teacher_logits)
    correct = (jnp.argmax(teacher_logits, axis=-1) == labels)
    kl = _kl(student_logits, teacher_logits)
    ok = correct.astype(jnp.float32)
    align = jnp.sum(kl * ok) / (jnp.sum(ok) + 1e-6)
    wrong = 1.0 - ok
    diverge = jnp.sum(kl * wrong) / (jnp.sum(wrong) + 1e-6)
    return align - beta * diverge


def dcml_losses(logits_fn: Callable, params_r, params_s, batch,
                base_loss_fn: Callable, lam: float, beta: float
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The two Eq. 3 objectives, evaluated on the receiver's local batch.

    F̂_r = (1-λ) F_r(w_r) + λ D_CKL(P_r ∥ P_s)
    F̂_s = (1-λ) F_r(w_s) + λ D_CKL(P_s ∥ P_r)

    ``logits_fn(params, batch) -> (logits, labels)`` abstracts the task
    (next-token LM or voxel segmentation).
    """
    logits_r, labels = logits_fn(params_r, batch)
    logits_s, _ = logits_fn(params_s, batch)
    f_r = base_loss_fn(params_r, batch)
    f_s = base_loss_fn(params_s, batch)
    l_r = (1 - lam) * f_r + lam * contrastive_kl(logits_r, logits_s, labels, beta)
    l_s = (1 - lam) * f_s + lam * contrastive_kl(logits_s, logits_r, labels, beta)
    return l_r, l_s


def merge_by_validation(params_r, params_s, v_r, v_s):
    """w_r^{t+1} = (v_r w_r + v_s w_s) / (v_r + v_s)   (Eq. 3 last line).

    Lower validation loss should mean HIGHER weight, so (as in the GCML
    reference implementation) the weights are inverted validation
    losses — each model is weighted by the other's loss share.
    """
    tot = v_r + v_s + 1e-12
    a, b = v_s / tot, v_r / tot          # inverse weighting
    return jax.tree.map(
        lambda x, y: (a * x.astype(jnp.float32)
                      + b * y.astype(jnp.float32)).astype(x.dtype),
        params_r, params_s)
