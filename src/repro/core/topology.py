"""Federation topology — flat star vs two-tier pods, as a first-class config.

Real cross-institution deployments rarely form one flat star: hospitals
federate through regional/institutional hubs (cf. *Real-World Federated
Learning in Radiology*, and the multi-center OAR-segmentation studies
FedKBP+ cites), and at simulator scale the pod tier is also the
bandwidth split — intra-pod traffic rides the fast link (ICI / one
workstation), cross-pod traffic rides the slow one (DCN / WAN).

:class:`Topology` names that structure once, and every layer honors it:

  * **engine** — ``AggregationEngine.aggregate_pods`` segment-reduces the
    padded ``[S, N]`` buffer by pod id (per-pod partial means → cross-pod
    combine), dispatched from the strategy hooks via ``ctx.topology``
    (this retires the old ``ctx.hierarchical`` bool);
  * **comms**  — the socket transports build a two-tier server stack
    (:mod:`repro.comms.pods`): one ``AggregationServer`` per pod plus a
    root combiner that pod leaders re-upload partials to over the
    ordinary ``Peer``/codec wire, with intra-pod vs cross-pod bytes
    accounted separately;
  * **session** — the scheduler seam is per tier (``intra_scheduler`` /
    ``inter_scheduler``), so sync-within-pod + buffered-across-pods and
    the reverse are valid compositions on the socket transports;
  * **dropout** — a whole pod going offline is Algorithm-2 churn at the
    pod tier (:func:`pod_availability_masks`), composed with the
    site-tier chain.

``"flat"`` is the default and is byte- and math-identical to the
pre-topology stack.  With one pod, or with uniform weights and
``intra == inter == "fedavg"``, pod aggregation equals the flat Eq. 1
mean exactly (weighted means compose) — tier-1 tested.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

#: combine rules available at either tier: ``fedavg`` = case-weighted
#: Eq. 1 mean, ``uniform`` = unweighted mean over the tier's members
TIER_COMBINES = ("fedavg", "uniform")


@dataclasses.dataclass(frozen=True)
class Topology:
    """Where aggregation happens: one flat star, or two tiers of pods.

    ``assignment`` maps each site to a pod id (``None`` = contiguous,
    near-equal blocks).  ``intra``/``inter`` pick the combine rule within
    a pod and across pods.  ``intra_scheduler``/``inter_scheduler``
    override the job's scheduler per tier on the socket transports
    (``None`` = inherit the job's); the stacked simulator runs pods
    synchronously at both tiers.
    """

    kind: str = "flat"                      # flat | pods
    num_pods: int = 1
    assignment: Optional[Tuple[int, ...]] = None   # site index -> pod id
    intra: str = "fedavg"
    inter: str = "fedavg"
    intra_scheduler: Optional[object] = None       # str | RoundScheduler
    inter_scheduler: Optional[object] = None

    def __post_init__(self):
        if self.kind not in ("flat", "pods"):
            raise ValueError(f"unknown topology kind {self.kind!r}; "
                             "known: flat, pods")
        for tier, rule in (("intra", self.intra), ("inter", self.inter)):
            if rule not in TIER_COMBINES:
                raise ValueError(f"unknown {tier} combine {rule!r}; known: "
                                 f"{TIER_COMBINES}")
        if self.kind == "pods" and self.num_pods < 1:
            raise ValueError(f"num_pods must be >= 1, got {self.num_pods}")

    # -- structure ----------------------------------------------------------

    @property
    def is_pods(self) -> bool:
        return self.kind == "pods"

    @classmethod
    def pods(cls, num_pods: int, **kw) -> "Topology":
        return cls(kind="pods", num_pods=num_pods, **kw)

    def pod_of(self, num_sites: int) -> np.ndarray:
        """[S] int pod id per site.  Flat = everyone in pod 0; explicit
        ``assignment`` wins; default is contiguous near-equal blocks
        (``S=5, P=2 → [0, 0, 0, 1, 1]``)."""
        if not self.is_pods:
            return np.zeros(num_sites, np.int32)
        if self.assignment is not None:
            a = np.asarray(self.assignment, np.int32)
            if a.shape != (num_sites,):
                raise ValueError(f"topology assignment covers {a.shape[0]} "
                                 f"sites, federation has {num_sites}")
            if a.min() < 0 or a.max() >= self.num_pods:
                raise ValueError(f"assignment pod ids must lie in "
                                 f"[0, {self.num_pods}); got {sorted(set(a.tolist()))}")
            return a
        if self.num_pods > num_sites:
            raise ValueError(f"{self.num_pods} pods over {num_sites} sites "
                             "leaves empty pods; pass an explicit assignment")
        out = np.zeros(num_sites, np.int32)
        for p, block in enumerate(np.array_split(np.arange(num_sites),
                                                 self.num_pods)):
            out[block] = p
        return out

    def members(self, num_sites: int):
        """List of per-pod site-index arrays (index = pod id)."""
        pod = self.pod_of(num_sites)
        return [np.flatnonzero(pod == p) for p in range(self.num_pods)]

    def validate(self, num_sites: int) -> None:
        """Raise early on an inconsistent topology (empty pods included)."""
        for p, m in enumerate(self.members(num_sites)):
            if self.is_pods and len(m) == 0:
                raise ValueError(f"pod {p} has no sites")


FLAT = Topology()


def resolve_topology(spec: Union[str, Topology, None]) -> Topology:
    """``None``/name/instance → :class:`Topology` (the same resolver shape
    as transports, schedulers and codecs on the job surface).  String
    forms: ``"flat"`` and ``"pods:K"``."""
    if spec is None:
        return FLAT
    if isinstance(spec, Topology):
        return spec
    if spec == "flat":
        return FLAT
    if spec.startswith("pods:"):
        try:
            k = int(spec.split(":", 1)[1])
        except ValueError:
            raise ValueError(f"bad topology spec {spec!r}; want pods:<int>")
        return Topology.pods(k)
    if spec == "pods":
        raise ValueError("topology 'pods' needs a pod count: pods:<K>")
    raise KeyError(f"unknown topology {spec!r}; known: flat, pods:<K>")


def pod_availability_masks(topology: Topology, num_sites: int,
                           pod_dropout: int, seed: int,
                           rounds: int) -> np.ndarray:
    """[rounds, S] bool masks from the Algorithm-2 chain run at the POD
    tier: a dropped pod takes all of its member sites offline that round
    (an institution hub losing its uplink).  Deterministic replay, same
    contract as :func:`repro.core.session.availability_masks` — the pod
    chain consumes a stream distinct from the site chain's, so the two
    compose without interference."""
    from repro.core.dropout import SiteAvailability
    if pod_dropout <= 0 or not topology.is_pods:
        return np.ones((rounds, num_sites), bool)
    if pod_dropout >= topology.num_pods:
        raise ValueError(f"pod_dropout {pod_dropout} must be < num_pods "
                         f"{topology.num_pods}")
    chain = SiteAvailability(topology.num_pods, pod_dropout, seed=seed + 9973)
    pod_masks = np.stack([chain.step() for _ in range(rounds)])
    return pod_masks[:, topology.pod_of(num_sites)]


def active_pod_counts(topology: Topology, masks: np.ndarray) -> np.ndarray:
    """[rounds] number of pods with ≥1 active site — the cross-pod
    barrier's `expected` each round, and the simulated cross-pod upload
    count."""
    pod_of = topology.pod_of(masks.shape[1])
    return np.asarray([np.unique(pod_of[m]).size for m in masks], np.int64)


def simulated_pods_comm(topology: Topology, masks: np.ndarray, nbytes: int,
                        intra_upload_bytes: Optional[int] = None,
                        intra_download_bytes: Optional[int] = None,
                        compression: str = "none",
                        down_compression: str = "none") -> dict:
    """The stacked simulator's per-tier byte split for a pods run (the
    socket transports report measured ``WireStats`` with the same keys):
    intra-pod = one upload + one broadcast per active site per round,
    cross-pod = one fp32 partial up + one global down per *active pod*
    per round.  ``intra_upload_bytes`` overrides the site-upload total
    with the codec's accumulated payload bytes (compressed runs);
    ``intra_download_bytes`` does the same for the broadcasts under
    bidirectional compression.  Partials and uncompressed broadcasts
    ride dense fp32."""
    uploads = int(masks.sum())
    cross_count = int(active_pod_counts(topology, masks).sum())
    intra_up = int(intra_upload_bytes if intra_upload_bytes is not None
                   else uploads * nbytes)
    intra_down = int(intra_download_bytes if intra_download_bytes is not None
                     else uploads * nbytes)
    cross = cross_count * nbytes
    return {"upload_bytes": intra_up + cross,
            "download_bytes": intra_down + cross,
            "total_bytes": intra_up + intra_down + 2 * cross,
            "intra_pod_upload_bytes": intra_up,
            "intra_pod_download_bytes": intra_down,
            "cross_pod_upload_bytes": cross,
            "cross_pod_download_bytes": cross,
            "upload_count": uploads, "pods": topology.num_pods,
            "compression": compression,
            "down_compression": down_compression, "simulated": True}
