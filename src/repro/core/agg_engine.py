"""The single implementation of Eq. 1 — one padded, backend-dispatched engine.

Previously the case-weighted FedAvg average lived in three disjoint
places: a per-leaf ``jnp.einsum`` path (``core/aggregation.py``), an
interpret-only Pallas kernel that rejected any ``N`` not divisible by
its block (``kernels/fedagg.py``), and a pure-Python scaled-copy loop on
the aggregation server that materialized one full model per site
(O(S·N) server memory).  ``AggregationEngine`` replaces all three:

  * any params pytree is raveled ONCE into a contiguous ``[S, N]`` fp32
    buffer (the ravel layout is cached per treedef/shape/dtype key),
  * ``N`` is zero-padded up to a block multiple so the Pallas ``fedagg``
    kernel accepts arbitrary parameter counts,
  * the reduction dispatches to the compiled Pallas kernel on TPU/GPU
    and to a fused ``jnp.einsum`` on CPU (tests may force either path),
  * flat and hierarchical (per-pod partials → cross-pod combine)
    reductions plus active-site masking share the same buffer.

``StreamingAccumulator`` is the host-side (numpy) counterpart for the
aggregation server: each upload is folded into a running weighted sum on
arrival, so the server holds O(N) state mid-round instead of S decoded
models — the memory term that gates scaling FL to many institutions
(cf. Sheller et al. 2020; APPFL).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stacking import broadcast_to_sites, where_site
from repro.kernels.fedagg import fedagg as _fedagg_kernel
from repro.kernels.robust import (masked_median as _median_kernel,
                                  masked_median_ref,
                                  trimmed_mean as _trimmed_kernel,
                                  trimmed_mean_ref)

_EPS = 1e-12


def normalized_weights(case_weights: jnp.ndarray, active: jnp.ndarray,
                       scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """m_i/m over the active subset; zero for inactive sites.

    ``scale`` is the optional per-site Horvitz–Thompson factor from
    per-round client sampling (``repro.core.sampling``): each
    participant's weight is multiplied by ``1/π`` before the
    self-normalization, so the numerator and denominator are each
    unbiased for their dense counterparts (the Hájek estimator).
    ``None`` keeps the dense path bit-identical."""
    w = case_weights.astype(jnp.float32) * active.astype(jnp.float32)
    if scale is not None:
        w = w * scale.astype(jnp.float32)
    return w / (jnp.sum(w) + _EPS)


def per_site_nbytes(params_stacked) -> int:
    """Wire bytes of one site's uncompressed model (per-leaf dtypes) —
    the byte-accounting unit shared by the loop and scan engines."""
    return sum(int(np.prod(x.shape[1:], dtype=np.int64)) * x.dtype.itemsize
               for x in jax.tree.leaves(params_stacked))


@dataclasses.dataclass(frozen=True)
class AggregatorSpec:
    """Parsed site→global combine rule (the robust-aggregation seam).

    ``fedavg`` is Eq. 1 exactly; the rest tolerate up to ``f``
    adversarial rows.  Rank-based rules (trimmed/median/krum) are
    order statistics over the site axis: they are UNWEIGHTED over the
    active rows (case weights and rank rules don't compose — a
    100×-weighted adversary would defeat the trim) and they must see
    individual site updates, so they cannot compose with secure
    aggregation's pairwise masks.
    """
    name: str = "fedavg"       # fedavg | trimmed | median | krum | normclip
    f: int = 0                 # adversary budget (trimmed, krum)
    c: float = 0.0             # clip norm (normclip)

    @property
    def robust(self) -> bool:
        return self.name != "fedavg"

    @property
    def rank_based(self) -> bool:
        """Order-statistic rules that need the individual site rows —
        incompatible with secure-agg masks and with streaming folds."""
        return self.name in ("trimmed", "median", "krum")

    @property
    def spec(self) -> str:
        """Canonical string form (round-trips through parse_aggregator)."""
        if self.name in ("trimmed", "krum"):
            return f"{self.name}:{self.f}"
        if self.name == "normclip":
            return f"normclip:{self.c:g}"
        return self.name


FEDAVG_SPEC = AggregatorSpec()


def parse_aggregator(spec) -> AggregatorSpec:
    """``fedavg | trimmed:f | median | krum:f | normclip:c`` → spec.

    ``trimmed:0`` trims nothing, so it parses to the fedavg spec and the
    job runs the case-weighted Eq. 1 path — bit-exactness with fedavg is
    by construction, not numerical accident.  Accepts an already-parsed
    spec (idempotent) and ``None`` (fedavg).
    """
    if isinstance(spec, AggregatorSpec):
        return spec
    if spec is None:
        return FEDAVG_SPEC
    text = str(spec).strip()
    name, _, arg = text.partition(":")
    name = name.strip()
    if name in ("fedavg", "median"):
        if arg:
            raise ValueError(f"{name} takes no argument, got {text!r}")
        return FEDAVG_SPEC if name == "fedavg" else AggregatorSpec("median")
    if name in ("trimmed", "krum"):
        if not arg:
            raise ValueError(f"{name} needs an adversary budget: {name}:f")
        f = int(arg)
        if f < 0:
            raise ValueError(f"{name}:f needs f >= 0, got {text!r}")
        if f == 0 and name == "trimmed":
            return FEDAVG_SPEC
        return AggregatorSpec(name, f=f)
    if name == "normclip":
        if not arg:
            raise ValueError("normclip needs a clip norm: normclip:c")
        c = float(arg)
        if not c > 0:
            raise ValueError(f"normclip:c needs c > 0, got {text!r}")
        return AggregatorSpec("normclip", c=c)
    raise ValueError(f"unknown aggregator {text!r} (expected fedavg | "
                     "trimmed:f | median | krum:f | normclip:c)")


def krum_select(flat: jnp.ndarray, active: jnp.ndarray, f: int) -> jnp.ndarray:
    """Krum (Blanchard et al. 2017) over the active rows of [S, N].

    Each active row scores the sum of its ``m = max(k − f − 2, 1)``
    smallest squared distances to OTHER active rows (k = traced active
    count); the minimal-score row is returned verbatim.  Invalid pairs
    (self, inactive partner) enter the distance matrix at a large
    FINITE sentinel so every row's order stays total, while inactive
    rows' *scores* are +inf — the argmin therefore always lands on an
    active row, even at k = 1 where every pair is invalid but the lone
    active row's finite sentinel score still beats +inf.
    """
    x = flat.astype(jnp.float32)
    act = jnp.asarray(active).astype(jnp.float32) > 0.5
    s = x.shape[0]
    k = jnp.sum(act.astype(jnp.int32))
    sq = jnp.sum(x * x, axis=1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    pair_ok = act[:, None] & act[None, :] & ~jnp.eye(s, dtype=bool)
    ds = jnp.sort(jnp.where(pair_ok, d2, jnp.float32(1e30)), axis=1)
    m = jnp.minimum(jnp.maximum(k - jnp.int32(f) - 2, 1),
                    jnp.maximum(k - 1, 1))
    r = jax.lax.broadcasted_iota(jnp.int32, ds.shape, 1)
    score = jnp.sum(jnp.where(r < m, ds, 0.0), axis=1)
    score = jnp.where(act, score, jnp.inf)
    return jnp.take(x, jnp.argmin(score), axis=0)


def clip_rows(flat: jnp.ndarray, c: float) -> jnp.ndarray:
    """Row-wise L2 clip: each site's [N] row scaled by min(1, c/‖row‖).
    The ``normclip:c`` rule — bounds any single upload's pull on the
    mean without discarding it (composes with case weights)."""
    x = flat.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(x * x, axis=1))
    factor = jnp.minimum(1.0, c / jnp.maximum(norms, _EPS))
    return x * factor[:, None]


@dataclasses.dataclass(frozen=True)
class RavelLayout:
    """How a site-stacked pytree maps into one contiguous [S, N] buffer."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]    # per-leaf shapes WITHOUT the site axis
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    n: int                                 # total flat param count


class AggregationEngine:
    """Eq. 1 for every consumer: strategies, ``global_model``, kernels API.

    ``use_pallas``/``interpret`` default to backend detection: compiled
    Pallas on TPU/GPU, jnp fallback on CPU.  Construct with
    ``use_pallas=True, interpret=True`` to exercise the kernel path under
    the Pallas interpreter (bit-faithful to the TPU program) on CPU.
    """

    def __init__(self, *, block_n: int = 65536,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self.block_n = block_n
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._layouts: Dict[Any, RavelLayout] = {}

    # -- backend dispatch ---------------------------------------------------

    def _dispatch(self) -> Tuple[bool, bool]:
        backend = jax.default_backend()
        accel = backend in ("tpu", "gpu")
        use_pallas = accel if self.use_pallas is None else self.use_pallas
        interpret = (not accel) if self.interpret is None else self.interpret
        return use_pallas, interpret

    def reduce_flat(self, flat: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
        """One weighted reduction over the site axis: [S, N] × [S] → [N]."""
        w = weights.astype(jnp.float32)
        use_pallas, interpret = self._dispatch()
        if use_pallas:
            return _fedagg_kernel(flat, w, block_n=self.block_n,
                                  interpret=interpret)
        return jnp.einsum("s,sn->n", w, flat.astype(jnp.float32))

    def reduce_robust_flat(self, flat: jnp.ndarray, active: jnp.ndarray,
                           spec: AggregatorSpec) -> jnp.ndarray:
        """Rank-based combine over the active rows of [S, N] → [N].

        Dispatches the trimmed/median kernels like :meth:`reduce_flat`
        dispatches ``fedagg`` (Pallas on TPU/GPU, the bit-identical jnp
        twin on CPU); krum is a [S, S] distance program with a row
        gather, so it stays jnp on every backend."""
        act = jnp.asarray(active).astype(jnp.float32)
        use_pallas, interpret = self._dispatch()
        if spec.name == "trimmed":
            if use_pallas:
                return _trimmed_kernel(flat, act, spec.f,
                                       block_n=self.block_n,
                                       interpret=interpret)
            return trimmed_mean_ref(flat, act, spec.f)
        if spec.name == "median":
            if use_pallas:
                return _median_kernel(flat, act, block_n=self.block_n,
                                      interpret=interpret)
            return masked_median_ref(flat, act)
        if spec.name == "krum":
            return krum_select(flat, act, spec.f)
        raise ValueError(f"not a rank-based rule: {spec.name}")

    # -- ravel layout (cached per treedef/shapes/dtypes) --------------------

    def layout_of(self, params_stacked) -> RavelLayout:
        leaves, treedef = jax.tree.flatten(params_stacked)
        key = (treedef, tuple(x.shape for x in leaves),
               tuple(str(x.dtype) for x in leaves))
        layout = self._layouts.get(key)
        if layout is None:
            shapes = tuple(x.shape[1:] for x in leaves)
            dtypes = tuple(x.dtype for x in leaves)
            sizes = [int(np.prod(sh, dtype=np.int64)) for sh in shapes]
            offsets = tuple(int(o) for o in np.cumsum([0] + sizes[:-1]))
            layout = RavelLayout(treedef, shapes, dtypes, offsets, sum(sizes))
            self._layouts[key] = layout
        return layout

    def flatten(self, params_stacked) -> Tuple[jnp.ndarray, RavelLayout]:
        """Ravel a site-stacked pytree into one [S, N] fp32 buffer."""
        layout = self.layout_of(params_stacked)
        leaves = jax.tree.leaves(params_stacked)
        s = leaves[0].shape[0]
        flat = jnp.concatenate(
            [x.reshape(s, -1).astype(jnp.float32) for x in leaves], axis=1)
        return flat, layout

    def unflatten(self, flat_global: jnp.ndarray, layout: RavelLayout):
        """[N] buffer → unstacked pytree, restoring per-leaf dtypes."""
        leaves = []
        for shape, dtype, ofs in zip(layout.shapes, layout.dtypes, layout.offsets):
            size = int(np.prod(shape, dtype=np.int64))
            leaves.append(flat_global[ofs: ofs + size].reshape(shape).astype(dtype))
        return jax.tree.unflatten(layout.treedef, leaves)

    def unflatten_stacked(self, flat: jnp.ndarray, layout: RavelLayout):
        """[S, N] buffer → site-stacked pytree (inverse of :meth:`flatten`).
        The round engine's buffered path round-trips params through the
        flat buffer every round, so the arrival fold can stay [S, N]."""
        s = flat.shape[0]
        leaves = []
        for shape, dtype, ofs in zip(layout.shapes, layout.dtypes, layout.offsets):
            size = int(np.prod(shape, dtype=np.int64))
            leaves.append(flat[:, ofs: ofs + size]
                          .reshape((s,) + shape).astype(dtype))
        return jax.tree.unflatten(layout.treedef, leaves)

    # -- Eq. 1 entry points -------------------------------------------------

    def global_mean(self, params_stacked, weights: jnp.ndarray):
        """Σ_s weights_s · params_s (weights already normalized) → pytree."""
        flat, layout = self.flatten(params_stacked)
        return self.unflatten(self.reduce_flat(flat, weights), layout)

    def aggregate(self, params_stacked, case_weights: jnp.ndarray,
                  active: Optional[jnp.ndarray] = None,
                  scale: Optional[jnp.ndarray] = None,
                  aggregator: Optional[AggregatorSpec] = None):
        """Eq. 1 (or a robust combine).  Returns (new stacked params,
        global params): the global model broadcast to active sites;
        inactive sites keep their local weights (the "disconnect"
        scenario).  ``scale`` threads the client-sampling
        inclusion-probability reweighting into the weights (see
        :func:`normalized_weights`); the broadcast mask stays the bool
        ``active``.  ``aggregator`` swaps the combine: rank rules
        (trimmed/median/krum) replace the weighted mean outright
        (unweighted over active rows, ``scale`` ignored); ``normclip``
        row-clips before the usual weighted fold."""
        s = jax.tree.leaves(params_stacked)[0].shape[0]
        if active is None:
            active = jnp.ones((s,), bool)
        spec = aggregator or FEDAVG_SPEC
        flat, layout = self.flatten(params_stacked)
        if spec.rank_based:
            gflat = self.reduce_robust_flat(flat, jnp.asarray(active), spec)
        else:
            if spec.name == "normclip":
                flat = clip_rows(flat, spec.c)
            w = normalized_weights(jnp.asarray(case_weights), active, scale)
            gflat = self.reduce_flat(flat, w)
        global_params = self.unflatten(gflat, layout)
        broadcast = broadcast_to_sites(global_params, s)
        return where_site(active, broadcast, params_stacked), global_params

    def reduce_pods_flat(self, flat: jnp.ndarray, case_weights: jnp.ndarray,
                         active: jnp.ndarray, pod_ids, num_pods: int,
                         intra: str = "fedavg",
                         inter: str = "fedavg",
                         scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Two-tier Eq. 1 on the flat buffer: segment-reduce the [S, N]
        rows by pod id into per-pod partial means (a dense one-hot [P, S]
        contraction, so the padded buffer and the kernel path stay
        shape-static for arbitrary assignments), then cross-pod combine
        through :meth:`reduce_flat`.

        ``intra``/``inter`` pick each tier's combine rule: ``fedavg`` =
        case-weighted, ``uniform`` = unweighted mean over the tier's
        (active) members.  With ``fedavg`` at both tiers the result
        equals the flat reduction exactly — weighted means compose.
        """
        act = active.astype(jnp.float32)
        w = case_weights.astype(jnp.float32) * act
        if intra == "uniform":
            w = act
        if scale is not None:
            # client sampling: each participant enters its pod's partial
            # at the 1/π-scaled weight (the pod totals then carry the
            # scaled mass up to the cross-pod combine)
            w = w * scale.astype(jnp.float32)
        pod_ids = jnp.asarray(pod_ids)
        onehot = (pod_ids[None, :] == jnp.arange(num_pods)[:, None]
                  ).astype(jnp.float32)                       # [P, S]
        wp = onehot * w[None, :]                              # [P, S]
        pod_tot = jnp.sum(wp, axis=1)                         # [P]
        pod_mean = jnp.einsum("ps,sn->pn", wp / (pod_tot[:, None] + _EPS),
                              flat.astype(jnp.float32))       # [P, N]
        if inter == "uniform":
            pod_w = (pod_tot > 0).astype(jnp.float32)         # active pods
        else:
            pod_w = pod_tot
        return self.reduce_flat(pod_mean, pod_w / (jnp.sum(pod_w) + _EPS))

    def reduce_pods_robust(self, flat: jnp.ndarray, active: jnp.ndarray,
                           pod_ids, num_pods: int, spec: AggregatorSpec,
                           inter: str = "fedavg") -> jnp.ndarray:
        """Rank rule at the intra-pod tier: each pod robust-combines its
        own active members' rows (a static Python loop — P is a small
        static topology constant, so this stays traceable), then the
        per-pod partials cross-combine weighted by active member count
        (``inter='uniform'`` weights active pods equally).  A pod with
        zero active members contributes a zero row at weight 0, so it
        drops out of the cross-pod mean."""
        act = jnp.asarray(active).astype(jnp.float32)
        pod_ids = jnp.asarray(pod_ids)
        partials, counts = [], []
        for p in range(num_pods):
            member = (pod_ids == p).astype(jnp.float32) * act
            partials.append(self.reduce_robust_flat(flat, member, spec))
            counts.append(jnp.sum(member))
        pod_mean = jnp.stack(partials)                        # [P, N]
        cnt = jnp.stack(counts)                               # [P]
        if inter == "uniform":
            pod_w = (cnt > 0).astype(jnp.float32)
        else:
            pod_w = cnt
        return self.reduce_flat(pod_mean, pod_w / (jnp.sum(pod_w) + _EPS))

    def aggregate_pods(self, params_stacked, case_weights: jnp.ndarray,
                       pod_ids, num_pods: int,
                       active: Optional[jnp.ndarray] = None,
                       intra: str = "fedavg", inter: str = "fedavg",
                       scale: Optional[jnp.ndarray] = None,
                       aggregator: Optional[AggregatorSpec] = None):
        """Two-tier Eq. 1 for an arbitrary site→pod assignment: per-pod
        partial means → cross-pod combine, all through the same padded
        [S, N] buffer.  Returns (new stacked params, global params) with
        the usual active-site masking (inactive sites keep their local
        weights).  A rank-based ``aggregator`` applies at the INTRA tier
        (each pod defends against its own members — the Byzantine
        surface); ``normclip`` row-clips before the weighted tiers."""
        s = jax.tree.leaves(params_stacked)[0].shape[0]
        if active is None:
            active = jnp.ones((s,), bool)
        spec = aggregator or FEDAVG_SPEC
        flat, layout = self.flatten(params_stacked)
        if spec.rank_based:
            gflat = self.reduce_pods_robust(flat, jnp.asarray(active),
                                            pod_ids, num_pods, spec, inter)
        else:
            if spec.name == "normclip":
                flat = clip_rows(flat, spec.c)
            gflat = self.reduce_pods_flat(flat, jnp.asarray(case_weights),
                                          jnp.asarray(active), pod_ids,
                                          num_pods, intra, inter, scale=scale)
        global_params = self.unflatten(gflat, layout)
        broadcast = broadcast_to_sites(global_params, s)
        return where_site(active, broadcast, params_stacked), global_params

    def aggregate_hierarchical(self, params_stacked, case_weights: jnp.ndarray,
                               sites_per_pod: int,
                               active: Optional[jnp.ndarray] = None):
        """Contiguous-block special case of :meth:`aggregate_pods` (kept
        for the mesh-shaped callers: pod p owns sites
        [p·sites_per_pod, (p+1)·sites_per_pod))."""
        s = jax.tree.leaves(params_stacked)[0].shape[0]
        if sites_per_pod <= 0 or s % sites_per_pod:
            # a ragged tail would silently fall outside every pod's
            # one-hot row and be dropped from the mean — fail loudly,
            # as the old reshape-based path did
            raise ValueError(f"sites_per_pod={sites_per_pod} does not "
                             f"divide {s} sites; pass an explicit "
                             "assignment via aggregate_pods instead")
        pod_ids = jnp.arange(s) // sites_per_pod
        return self.aggregate_pods(params_stacked, case_weights, pod_ids,
                                   s // sites_per_pod, active)

    def aggregate_round(self, params_stacked, round_inputs, ctx):
        """Strategy ``post_exchange`` entry: flat vs two-tier is picked by
        the job's :class:`~repro.core.topology.Topology` (``ctx.topology``
        — this replaced the old ``ctx.hierarchical`` bool) and returns
        (new stacked params, global params)."""
        active = round_inputs["active"]
        # client sampling (repro.core.sampling): an optional [S] float
        # Horvitz–Thompson 1/π factor riding the round inputs; absent on
        # unsampled jobs so their trajectories stay bit-identical
        scale = round_inputs.get("weight_scale")
        spec = parse_aggregator(getattr(ctx, "aggregator", None))
        topo = ctx.topology
        if topo.is_pods:
            s = jax.tree.leaves(params_stacked)[0].shape[0]
            return self.aggregate_pods(
                params_stacked, ctx.case_weights, topo.pod_of(s),
                topo.num_pods, active, topo.intra, topo.inter, scale=scale,
                aggregator=spec)
        return self.aggregate(params_stacked, ctx.case_weights, active,
                              scale=scale, aggregator=spec)


_DEFAULT_ENGINE: Optional[AggregationEngine] = None


def get_engine() -> AggregationEngine:
    """Process-wide default engine (shared ravel-layout cache)."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = AggregationEngine()
    return _DEFAULT_ENGINE


class StreamingAccumulator:
    """O(N)-memory running Eq. 1 sum for the aggregation server.

    ``fold`` folds one site's upload into the accumulator on arrival —
    the server never holds more than one fp32 model copy, however many
    sites report.  Incoming fp32 leaves that are *writable* (see
    ``decode_message(..., writable=True)``) are scaled in place, so a
    fold allocates nothing beyond transient non-fp32 casts.
    """

    def __init__(self):
        self._treedef = None
        self._acc: Optional[List[np.ndarray]] = None
        self._weight_total = 0.0
        self.count = 0

    @property
    def nbytes(self) -> int:
        """Resident accumulator bytes (the O(N) mid-round state)."""
        return sum(a.nbytes for a in self._acc) if self._acc else 0

    @property
    def weight_total(self) -> float:
        """Sum of the folded weights so far — a pod server reads this
        right before ``finalize`` so its leader can re-upload the partial
        at the pod's true (active-member) weight."""
        return self._weight_total

    @staticmethod
    def _scaled(x, w: np.float32) -> np.ndarray:
        x = np.asarray(x)
        if np.issubdtype(x.dtype, np.integer):
            # secure-aggregation path: masked fixed-point words sum as
            # EXACT modular uint64 arithmetic — any float scaling would
            # destroy the pairwise mask cancellation, so integer leaves
            # only ever fold at weight 1 (the site weights ride the
            # upload metadata and divide out at unmask time)
            if float(w) != 1.0:
                raise ValueError("integer (masked) uploads fold at weight "
                                 f"1.0, got {float(w)}")
            v = x.view(np.uint64) if x.dtype.itemsize == 8 \
                else x.astype(np.uint64)
            return v if v.flags.writeable else v.copy()
        if x.dtype == np.float32 and x.flags.writeable:
            return np.multiply(x, w, out=x)        # in place — no model copy
        return np.multiply(x, w, dtype=np.float32)

    def fold(self, tree, weight: float) -> None:
        w = np.float32(weight)
        leaves, treedef = jax.tree.flatten(tree)
        if self._acc is None:
            self._treedef = treedef
            self._acc = [self._scaled(x, w) for x in leaves]
        else:
            if treedef != self._treedef:
                raise ValueError("upload pytree structure changed mid-round")
            for a, x in zip(self._acc, leaves):
                np.add(a, self._scaled(x, w), out=a)
        self._weight_total += float(weight)
        self.count += 1

    @property
    def is_integer(self) -> bool:
        """True when the buffered round is a masked (fixed-point) one."""
        return bool(self._acc) and \
            np.issubdtype(self._acc[0].dtype, np.integer)

    def finalize(self):
        """Normalize by the folded weight total and return the global pytree
        (fp32 leaves).  Resets the accumulator for the next round."""
        if self._acc is None:
            return None
        if self.is_integer:
            raise ValueError("masked integer rounds finalize via "
                             "finalize_int() + SecureAggState.unmask()")
        inv = np.float32(1.0 / self._weight_total)
        leaves = [np.multiply(a, inv, out=a) for a in self._acc]
        tree = jax.tree.unflatten(self._treedef, leaves)
        self._treedef, self._acc = None, None
        self._weight_total, self.count = 0.0, 0
        return tree

    def finalize_int(self):
        """The raw modular uint64 sum of a masked round, unnormalized —
        :meth:`~repro.privacy.secure_agg.SecureAggState.unmask` recovers
        the weighted mean.  Resets the accumulator for the next round."""
        if self._acc is None:
            return None
        tree = jax.tree.unflatten(self._treedef, self._acc)
        self._treedef, self._acc = None, None
        self._weight_total, self.count = 0.0, 0
        return tree


# -- host-side (numpy) twins for the socket servers -------------------------
#
# The AggregationServer runs on plain numpy (no device round-trips in its
# handler threads).  Sanitation checks every upload on arrival; the rank
# rules re-run the same fe/keep math as kernels/robust._trim_block over a
# per-round row buffer (rank statistics need all rows at once, so the
# robust server mode trades the O(N) streaming fold for O(S·N) — the
# cost of not trusting the rows).


def tree_all_finite(tree) -> bool:
    """True iff every float leaf is NaN/Inf-free.  Integer leaves (the
    masked fixed-point uploads) are trivially finite."""
    for x in jax.tree.leaves(tree):
        a = np.asarray(x)
        if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
            return False
    return True


def tree_l2_norm(tree) -> float:
    """Global L2 norm over the float leaves of an upload (float64
    accumulation so huge adversarial values don't overflow the check
    that is supposed to catch them)."""
    total = 0.0
    for x in jax.tree.leaves(tree):
        a = np.asarray(x)
        if np.issubdtype(a.dtype, np.floating):
            total += float(np.sum(a.astype(np.float64) ** 2))
    return float(np.sqrt(total))


def clip_tree_norm(tree, c: float):
    """Host twin of :func:`clip_rows` for one upload: scale the whole
    tree by min(1, c/‖tree‖).  Streaming-compatible — the server clips
    before the fold, so ``normclip`` keeps the O(N) accumulator."""
    norm = tree_l2_norm(tree)
    if norm <= c:
        return tree
    factor = np.float32(c / max(norm, _EPS))
    return jax.tree.map(
        lambda x: np.asarray(x, np.float32) * factor
        if np.issubdtype(np.asarray(x).dtype, np.floating) else x, tree)


def robust_combine_trees(trees: List[Any], spec: AggregatorSpec):
    """Host twin of the traced rank rules for the row-buffered server
    mode: the round's uploads are stacked per leaf and rank-combined
    coordinate-wise (same clamp math as ``kernels/robust._trim_block``);
    krum distances run over the concatenated ravels.  Parity with the
    traced path is allclose, not bit-exact (summation order differs).
    """
    if not trees:
        return None
    k = len(trees)
    flat_list = [jax.tree.flatten(t) for t in trees]
    treedef = flat_list[0][1]
    for _, td in flat_list[1:]:
        if td != treedef:
            raise ValueError("upload pytree structure changed mid-round")
    if spec.name == "krum":
        flats = np.stack([np.concatenate(
            [np.asarray(x, np.float32).ravel() for x in lv])
            for lv, _ in flat_list])
        sq = np.sum(flats * flats, axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (flats @ flats.T)
        np.maximum(d2, 0.0, out=d2)
        np.fill_diagonal(d2, np.inf)
        m = max(min(k - spec.f - 2, k - 1), 1)
        score = np.sum(np.sort(d2, axis=1)[:, :m], axis=1)
        return trees[int(np.argmin(score))]
    f = k if spec.name == "median" else spec.f
    fe = min(f, (k - 1) // 2)
    out = []
    for i in range(len(flat_list[0][0])):
        stack = np.stack([np.asarray(lv[i], np.float32)
                          for lv, _ in flat_list])
        xs = np.sort(stack, axis=0)
        out.append(np.mean(xs[fe: k - fe], axis=0, dtype=np.float32))
    return jax.tree.unflatten(treedef, out)
