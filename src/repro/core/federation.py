"""The federated round driver — builds the jitted `fl_round` step.

One FL round (Figs 3/4, Algorithm 1):

  1. (decentralized) pre-exchange: receive peer model + regional DCML
  2. local training: ``local_steps`` optimizer steps per site, vmapped
     over the stacked site axis (each site sees only its own batch shard)
  3. (centralized) post-exchange: weighted aggregation + broadcast
  4. dropout semantics: "shutdown" sites skip (2); inactive sites always
     skip exchanges (their aggregation weight is zero and they keep
     their local weights)

Host-side per-round inputs (active mask, gossip pairing) come from
``repro.core.dropout.SiteAvailability`` and ``repro.core.gossip`` —
mirroring the paper's coordination server, which tracks metadata outside
the training engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig, JobConfig, MeshConfig
from repro.core import stacking
from repro.core.topology import FLAT, Topology
from repro.core.strategies import base as strat_base
# strategy modules self-register on import
from repro.core.strategies import fedavg as _f  # noqa: F401
from repro.core.strategies import fedprox as _p  # noqa: F401
from repro.core.strategies import gcml as _g  # noqa: F401
from repro.core.strategies import individual as _i  # noqa: F401
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


@dataclasses.dataclass
class FLContext:
    """Everything a strategy hook may need (static, captured at trace time)."""
    fed: FederationConfig
    mesh: MeshConfig
    case_weights: jnp.ndarray
    loss_fn: Callable            # (params, batch) -> (loss, metrics)
    logits_fn: Optional[Callable]  # (params, batch) -> (logits, labels)
    optimizer: Optimizer
    grad_clip: float
    dcml_lr: float
    # where aggregation happens (flat star vs two-tier pods) — replaces
    # the old ``hierarchical`` bool; see repro.core.topology
    topology: Topology = FLAT
    microbatch: Optional[int] = None   # per-site microbatch for grad accumulation
    accum_dtype: Any = jnp.float32     # grad-accumulator dtype (bf16 for ≥236B)
    # DP-SGD (repro.privacy.dp.DPConfig or None): clip+noise inside the
    # site update, keys derived from (seed, fl_state["round"], global
    # site id, local step).  ``dp_site_base`` maps this context's site
    # rows onto GLOBAL site ids (a 1-site socket worker's row 0 is its
    # real site id), so every transport draws the same noise stream.
    privacy: Optional[Any] = None
    dp_site_base: int = 0
    # robust site→global combine (repro.core.agg_engine.AggregatorSpec
    # or its string form; None = fedavg).  Rides the context so the
    # compiled scan body dispatches the rule on-device with no change
    # to the round carry.
    aggregator: Optional[Any] = None
    # deterministic Byzantine fault injection
    # (repro.core.adversary.AdversaryPlan or None).  Stacked engines
    # apply it inside fl_round; socket workers get adversary=None here
    # and perturb their upload payload host-side instead.
    adversary: Optional[Any] = None

    def scalar_loss_fn(self, params, batch):
        return self.loss_fn(params, batch)[0]


def init_fl_state(ctx: FLContext, init_params_fn, key):
    """Round-0 federated state: identical params on every site (paper)."""
    params = stacking.init_stacked(init_params_fn, key, ctx.fed.num_sites)
    opt = jax.vmap(ctx.optimizer.init)(params)
    strategy = strat_base.get_strategy(ctx.fed.strategy)
    return {
        "params": params,
        "opt": opt,
        "strategy": strategy.init_state(params, ctx),
        "round": jnp.zeros((), jnp.int32),
    }


def make_round_inputs(ctx: FLContext, availability=None, rng=None,
                      round_index: int = 0,
                      active: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
    """Host-side coordinator outputs for one round.

    ``active`` overrides the availability chain with a precomputed mask
    (transports that replay the Algorithm-2 schedule deterministically
    pass the round's mask directly).
    """
    from repro.core.gossip import pair_sites
    s = ctx.fed.num_sites
    if active is None:
        active = (availability.step() if availability is not None
                  else np.ones((s,), bool))
    active = np.asarray(active, bool)
    partner = np.arange(s)
    is_recv = np.zeros(s, bool)
    if strat_base.get_strategy(ctx.fed.strategy).needs_pairing:
        rng = rng or np.random.default_rng(round_index)
        partner, is_recv, _ = pair_sites(active, rng)
    return {"active": active, "partner": partner, "is_receiver": is_recv}


def make_round_inputs_traced(ctx: FLContext, key, active):
    """Traced path of :func:`make_round_inputs` — the coordinator outputs
    (gossip pairing) produced on-device from a jax PRNG key, so the
    compiled round engine (``repro.core.round_engine``) can run many
    rounds in one ``lax.scan`` without host re-entry.

    ``active`` is this round's [S] bool mask (thread it through
    :func:`repro.core.dropout.availability_step_traced` for on-device
    Algorithm-2 churn).  The pairing *law* matches the host path; the
    random streams differ (numpy PCG64 vs jax threefry), so use the host
    path when bit-parity with a replayed schedule matters.
    """
    s = ctx.fed.num_sites
    active = jnp.asarray(active, bool)
    partner = jnp.arange(s)
    is_recv = jnp.zeros(s, bool)
    if strat_base.get_strategy(ctx.fed.strategy).needs_pairing:
        from repro.core.gossip import pair_sites_traced
        partner, is_recv, _ = pair_sites_traced(key, active)
    return {"active": active, "partner": partner, "is_receiver": is_recv}


def build_fl_round(ctx: FLContext, remat_local: bool = False):
    """Returns ``fl_round(fl_state, batches, round_inputs) -> (fl_state, metrics)``.

    ``batches`` pytree leaves are shaped [S, local_steps, per-site batch…];
    for GCML, ``round_inputs`` additionally carries ``dcml_batch`` and
    ``val_batch`` with leaves [S, …].
    """
    strategy = strat_base.get_strategy(ctx.fed.strategy)
    dp = ctx.privacy
    if dp is not None and ctx.microbatch:
        raise ValueError("DP-SGD composes its own per-example/per-site "
                         "clipping; microbatch gradient accumulation is "
                         "not supported alongside it")

    def site_train_step(params, opt, batch, strat_ref, noise_key=None):
        def lf(p, b):
            loss, metrics = ctx.loss_fn(p, b)
            loss = loss + strategy.local_loss_extra(p, strat_ref, ctx)
            return loss, metrics

        if dp is not None:
            from repro.privacy.dp import dp_gradients
            # DP clipping REPLACES ctx.grad_clip — the clip norm is the
            # mechanism's sensitivity, a second rescale would break the
            # accountant's calibration
            grads, loss, metrics, gnorm = dp_gradients(
                lf, params, batch, noise_key, dp)
            updates, opt = ctx.optimizer.update(grads, opt, params)
            params = apply_updates(params, updates)
            return params, opt, {"loss": loss, "grad_norm": gnorm,
                                 **metrics}

        bsz = jax.tree.leaves(batch)[0].shape[0]
        if ctx.microbatch and ctx.microbatch < bsz:
            # gradient accumulation over microbatches (fp32 accumulators)
            n = bsz // ctx.microbatch
            micro = jax.tree.map(
                lambda x: x.reshape((n, ctx.microbatch) + x.shape[1:]), batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    lf, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(ctx.accum_dtype), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, ctx.accum_dtype), params)
            (grads, loss_sum), ms = jax.lax.scan(accum, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
            metrics = jax.tree.map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        if ctx.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, ctx.grad_clip)
        else:
            gnorm = jnp.zeros(())
        updates, opt = ctx.optimizer.update(grads, opt, params)
        params = apply_updates(params, updates)
        return params, opt, {"loss": loss, "grad_norm": gnorm, **metrics}

    if remat_local:
        site_train_step = jax.checkpoint(site_train_step)

    def local_phase(fl_state, batches, active):
        strat_ref = fl_state["strategy"]

        if dp is not None:
            # noise keys threaded through the carry: fl_state["round"]
            # rides every engine's scan carry, so fold_in(round, site,
            # step) replays identically across scan/loop/socket paths
            # and across crash-resume re-entry
            from repro.privacy.dp import round_key, site_step_key
            rkey = round_key(dp, fl_state["round"])
            s = jax.tree.leaves(batches)[0].shape[0]
            site_ids = jnp.arange(s, dtype=jnp.int32) + ctx.dp_site_base

            def per_site_dp(params, opt, site_batches, site_id):
                k = jax.tree.leaves(site_batches)[0].shape[0]

                def body(carry, xs):
                    b, step = xs
                    p, o = carry
                    p, o, m = site_train_step(
                        p, o, b, strat_ref,
                        site_step_key(rkey, site_id, step))
                    return (p, o), m
                (params, opt), ms = jax.lax.scan(
                    body, (params, opt),
                    (site_batches, jnp.arange(k, dtype=jnp.int32)))
                return params, opt, jax.tree.map(lambda x: x[-1], ms)

            new_params, new_opt, metrics = jax.vmap(
                per_site_dp, in_axes=(0, 0, 0, 0))(
                fl_state["params"], fl_state["opt"], batches, site_ids)
        else:
            def per_site(params, opt, site_batches):
                def body(carry, b):
                    p, o = carry
                    p, o, m = site_train_step(p, o, b, strat_ref)
                    return (p, o), m
                (params, opt), ms = jax.lax.scan(body, (params, opt),
                                                 site_batches)
                return params, opt, jax.tree.map(lambda x: x[-1], ms)

            new_params, new_opt, metrics = jax.vmap(
                per_site, in_axes=(0, 0, 0))(fl_state["params"],
                                             fl_state["opt"], batches)

        if ctx.fed.dropout_scenario == "shutdown":
            # workstation off: inactive sites neither train nor update state
            new_params = stacking.where_site(active, new_params, fl_state["params"])
            new_opt = stacking.where_site(active, new_opt, fl_state["opt"])
        return {**fl_state, "params": new_params, "opt": new_opt}, metrics

    # Byzantine fault injection: the malicious set is a static pure
    # function of (plan.seed, num_sites), baked at trace time — no RNG
    # state threads through the scan carry
    adv = ctx.adversary
    adv_mask = (jnp.asarray(adv.malicious_mask(ctx.fed.num_sites))
                if adv is not None else None)

    def fl_round(fl_state, batches, round_inputs):
        active = jnp.asarray(round_inputs["active"])
        ri = {**round_inputs, "active": active}
        if adv is not None and adv.flips_labels:
            batches = adv.perturb_batches(batches, adv_mask)
        fl_state = strategy.pre_exchange(fl_state, ri, ctx)
        fl_state, metrics = local_phase(fl_state, batches, active)
        if adv is not None and adv.flips_params:
            # perturb what malicious ACTIVE sites expose to aggregation
            # (between local training and the exchange — the same seam
            # where a socket worker perturbs its upload payload).
            # post_exchange overwrites active rows with the new global,
            # so the perturbation never persists into the site's state —
            # matching sockets, where only the wire payload is dirty.
            fl_state = {**fl_state, "params": adv.perturb_stacked(
                fl_state["params"], adv_mask & active, fl_state["round"])}
        fl_state = strategy.post_exchange(fl_state, ri, ctx)
        fl_state = {**fl_state, "round": fl_state["round"] + 1}
        if "metrics" in fl_state:
            metrics = {**metrics, **fl_state.pop("metrics")}
        return fl_state, metrics

    return fl_round


def global_model(fl_state, ctx: FLContext):
    """Case-weighted global model from the current stacked params
    (what gets served / checkpointed as 'the' model)."""
    from repro.core.agg_engine import get_engine
    w = ctx.case_weights / jnp.sum(ctx.case_weights)
    return get_engine().global_mean(fl_state["params"], w)
