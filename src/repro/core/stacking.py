"""Site-stacked parameter pytrees — the federated representation.

Every federated quantity (params, optimizer state, metrics) carries a
leading ``S = num_sites`` axis.  On the FL mesh that axis is sharded over
the ``("pod","site")`` axes, so XLA's lowering of the aggregation einsums
*is* the paper's gRPC traffic (all-reduce for FedAvg, collective-permute
for gossip).  See DESIGN.md §2.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def stack_replicas(params, num_sites: int):
    """Replicate an unstacked pytree into [S, ...] (round-0 broadcast)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_sites,) + x.shape), params)


def init_stacked(init_fn: Callable[[jax.Array], Any], key, num_sites: int,
                 same_init: bool = True):
    """Initialize site-stacked params.

    ``same_init=True`` matches the paper: all sites start from the same
    global initialization (a FedAvg requirement for sensible averaging).
    """
    if same_init:
        return stack_replicas(init_fn(key), num_sites)
    keys = jax.random.split(key, num_sites)
    return jax.vmap(init_fn)(keys)


def site_slice(stacked, i: int):
    return jax.tree.map(lambda x: x[i], stacked)


def weighted_mean(stacked, weights: jnp.ndarray):
    """Weighted average over the site axis: Eq. 1's  Σ_i (m_i/m) w_i.

    ``weights`` must already be normalized (sum to 1 over active sites).
    Lowered by XLA to an all-reduce over the "site"/"pod" mesh axes.
    """
    return jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(jnp.float32),
                                x.astype(jnp.float32), axes=1).astype(x.dtype),
        stacked)


def broadcast_to_sites(tree, num_sites: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (num_sites,) + x.shape), tree)


def where_site(mask: jnp.ndarray, a, b):
    """Per-site select: mask [S] bool; a/b stacked pytrees."""
    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree.map(sel, a, b)


def gather_sites(stacked, indices: jnp.ndarray):
    """Permute the site axis (gossip exchange): out[i] = in[indices[i]].

    Lowered to a collective-permute over the "site" axis when ``indices``
    is a permutation.
    """
    return jax.tree.map(lambda x: jnp.take(x, indices, axis=0), stacked)
