"""Strategy interface.

A strategy contributes three hooks to the federated round (Fig 3/4):

  * ``init_state``   — per-federation state (e.g. FedProx's global model)
  * ``pre_exchange`` — model exchange BEFORE local training (decentralized
                       FL: receive + DCML, Algorithm 1)
  * ``post_exchange``— aggregation AFTER local training (centralized FL:
                       upload + weighted average + broadcast, Eq. 1/2)
  * ``local_loss_extra`` — an additive term on the local objective
                       (FedProx's proximal term, Eq. 2)

All hooks are pure and jit-traceable; host-side coordination (pairing,
availability) arrives through ``round_inputs``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp


class Strategy:
    name: str = "base"
    needs_pairing: bool = False
    needs_val_batch: bool = False

    def init_state(self, params_stacked, ctx) -> Dict[str, Any]:
        return {}

    def local_loss_extra(self, params_site, strat_state, ctx) -> jnp.ndarray:
        return jnp.zeros((), jnp.float32)

    def pre_exchange(self, fl_state, round_inputs, ctx):
        return fl_state

    def post_exchange(self, fl_state, round_inputs, ctx):
        return fl_state


_REGISTRY: Dict[str, type] = {}


def register(cls):
    _REGISTRY[cls.name] = cls
    return cls


def get_strategy(name: str) -> Strategy:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown FL strategy {name!r}; known: {sorted(_REGISTRY)}")
