"""GCML — Gossip Contrastive Mutual Learning (paper Eq. 3, Algorithm 1).

Fully decentralized: no aggregation server.  Each round the coordinator
pairs active sites into (sender, receiver); the receiver pulls the
sender's weights (a site-axis gather → collective-permute on the mesh),
runs regional DCML — both the local and the incoming model take one
mutual-distillation SGD step on the receiver's local batch — and merges
them weighted by their validation losses.  Local training then proceeds
as usual (handled by the round driver).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.agg_engine import parse_aggregator
from repro.core.dcml import dcml_losses, merge_by_validation
from repro.core.stacking import gather_sites, where_site
from repro.core.strategies.base import Strategy, register


def make_site_dcml(ctx):
    """Per-site regional DCML step (Eq. 3): mutual-distillation SGD on
    (receiver, incoming sender) models, merged by validation loss.

    Returned fn maps unstacked ``(p_r, p_s, batch, val_batch)`` →
    ``(merged_params, (l_r, l_s, v_r, v_s))``.  The stacked simulator
    vmaps it over the site axis; the socket transports jit it directly
    on the receiving site.

    With ``aggregator="normclip:c"`` the incoming model's delta against
    the receiver is L2-clipped to ``c`` before DCML — the serverless
    twin of the central rule: a Byzantine push can steer a receiver by
    at most ``c`` per round, whatever its magnitude.
    """
    lam = ctx.fed.gcml_lambda
    beta = ctx.fed.gcml_contrast_beta
    eta = ctx.dcml_lr
    spec = parse_aggregator(getattr(ctx, "aggregator", None))
    clip_c = spec.c if spec.name == "normclip" else 0.0

    def site_dcml(p_r, p_s, b, vb):
        if clip_c:
            delta = jax.tree.map(
                lambda s, r: s.astype(jnp.float32) - r.astype(jnp.float32),
                p_s, p_r)
            nrm = jnp.sqrt(sum(jnp.sum(d * d)
                               for d in jax.tree.leaves(delta)))
            fac = jnp.minimum(1.0, clip_c / jnp.maximum(nrm, 1e-12))
            p_s = jax.tree.map(
                lambda r, d: (r.astype(jnp.float32) + fac * d).astype(r.dtype),
                p_r, delta)
        def joint(pr, ps):
            l_r, l_s = dcml_losses(ctx.logits_fn, pr, ps, b,
                                   ctx.scalar_loss_fn, lam, beta)
            return l_r + l_s, (l_r, l_s)
        grads, (l_r, l_s) = jax.grad(joint, argnums=(0, 1), has_aux=True)(p_r, p_s)
        g_r, g_s = grads
        w_r = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - eta * g.astype(jnp.float32)
                          ).astype(p.dtype), p_r, g_r)
        w_s = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - eta * g.astype(jnp.float32)
                          ).astype(p.dtype), p_s, g_s)
        v_r = ctx.scalar_loss_fn(w_r, vb)
        v_s = ctx.scalar_loss_fn(w_s, vb)
        merged = merge_by_validation(w_r, w_s, v_r, v_s)
        return merged, (l_r, l_s, v_r, v_s)

    return site_dcml


@register
class GCML(Strategy):
    name = "gcml"
    needs_pairing = True
    needs_val_batch = True

    def pre_exchange(self, fl_state, round_inputs, ctx):
        params = fl_state["params"]
        partner = round_inputs["partner"]          # [S] int (identity if not recv)
        is_recv = round_inputs["is_receiver"]      # [S] bool
        active = round_inputs["active"]
        batch = round_inputs["dcml_batch"]         # [S, ...] one local batch
        val_batch = round_inputs["val_batch"]      # [S, ...]
        incoming = gather_sites(params, partner)

        merged, dcml_metrics = jax.vmap(make_site_dcml(ctx))(
            params, incoming, batch, val_batch)
        take = is_recv & active
        new_params = where_site(take, merged, params)
        metrics = {**fl_state.get("metrics", {}),
                   "dcml_loss_r": dcml_metrics[0], "dcml_loss_s": dcml_metrics[1],
                   "dcml_val_r": dcml_metrics[2], "dcml_val_s": dcml_metrics[3]}
        return {**fl_state, "params": new_params, "metrics": metrics}
