"""FedAvg (McMahan et al. 2017) — paper Eq. 1."""
from __future__ import annotations

from repro.core.agg_engine import get_engine
from repro.core.strategies.base import Strategy, register


@register
class FedAvg(Strategy):
    name = "fedavg"

    def post_exchange(self, fl_state, round_inputs, ctx):
        params, _global_params = get_engine().aggregate_round(
            fl_state["params"], round_inputs, ctx)
        return {**fl_state, "params": params}
