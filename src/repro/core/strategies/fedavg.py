"""FedAvg (McMahan et al. 2017) — paper Eq. 1."""
from __future__ import annotations

from repro.core.aggregation import fedavg_aggregate, hierarchical_aggregate
from repro.core.strategies.base import Strategy, register


@register
class FedAvg(Strategy):
    name = "fedavg"

    def post_exchange(self, fl_state, round_inputs, ctx):
        active = round_inputs["active"]
        if ctx.mesh.multi_pod and ctx.hierarchical:
            params, global_params = hierarchical_aggregate(
                fl_state["params"], ctx.case_weights, ctx.mesh.sites_per_pod, active)
        else:
            params, global_params = fedavg_aggregate(
                fl_state["params"], ctx.case_weights, active)
        return {**fl_state, "params": params}
