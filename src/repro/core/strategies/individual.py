"""Baselines: Individual (no exchange) and Pooled (handled by the trainer
as a single-site federation over the concatenated dataset)."""
from __future__ import annotations

from repro.core.strategies.base import Strategy, register


@register
class Individual(Strategy):
    """Each site trains alone on its local data — the paper's lower baseline."""
    name = "individual"


@register
class Pooled(Strategy):
    """Centralized training on pooled data — the paper's upper baseline.

    Implemented as a 1-site federation whose 'site' sees every case
    (the data pipeline concatenates all partitions); no exchange needed.
    """
    name = "pooled"
