"""FedProx (Li et al. 2018) — paper Eq. 2.

FedAvg aggregation plus a proximal term  (μ/2)·‖w_i − w^t‖²  on each
site's local objective, anchoring local models to the last global model
under data heterogeneity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.agg_engine import get_engine
from repro.core.strategies.base import Strategy, register


def prox_term(params_site, global_params, mu: float) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(p.astype(jnp.float32) - g.astype(jnp.float32)))
             for p, g in zip(jax.tree.leaves(params_site),
                             jax.tree.leaves(global_params)))
    return 0.5 * mu * sq


@register
class FedProx(Strategy):
    name = "fedprox"

    def init_state(self, params_stacked, ctx):
        # the round-0 global model is the shared initialization
        s = jax.tree.leaves(params_stacked)[0].shape[0]
        w = jnp.ones((s,), jnp.float32) / s
        return {"global": get_engine().global_mean(params_stacked, w)}

    def local_loss_extra(self, params_site, strat_state, ctx):
        return prox_term(params_site, strat_state["global"], ctx.fed.prox_mu)

    def post_exchange(self, fl_state, round_inputs, ctx):
        params, global_params = get_engine().aggregate_round(
            fl_state["params"], round_inputs, ctx)
        return {**fl_state, "params": params,
                "strategy": {"global": global_params}}


@register
class FedProxLocal(FedProx):
    """FedProx's *site half* only: the Eq. 2 proximal pull toward the
    anchored global, with no in-round aggregation.  The execution paths
    that simulate or own the server themselves (the compressed stacked
    loop/scan, the socket site workers) run local-only rounds under this
    strategy and re-anchor ``strategy["global"]`` whenever a broadcast
    global arrives — exactly what a real FedProx client does between
    exchanges."""

    name = "fedprox-local"

    def post_exchange(self, fl_state, round_inputs, ctx):
        return fl_state
