"""Algorithm 2 — simulation of random site drop-in/drop-out.

A bounded birth–death Markov chain on the number of *dropped* sites
``d ∈ [0, N_max]``:

  * d == 0      : 1/2 chance one site drops out, 1/2 nothing
  * d == N_max  : 1/2 chance one site drops back in, 1/2 nothing
  * otherwise   : 1/3 drop out, 1/3 drop in, 1/3 nothing

Which site drops is uniform among currently-active sites (resp. which
rejoins, among dropped sites).  Host-side (numpy RNG), since site
availability is an *input* to the jitted round step, exactly as the
paper's coordination server tracks status outside the training engine.

Two scenarios (paper §III.C.2):
  * ``disconnect`` — dropped sites keep training locally but do not
    exchange updates (temporary network loss)
  * ``shutdown``   — dropped sites neither train nor exchange
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class SiteAvailability:
    """Stateful Algorithm-2 chain producing per-round active masks."""

    def __init__(self, num_sites: int, max_dropout: int, seed: int = 0):
        assert 0 <= max_dropout < num_sites
        self.num_sites = num_sites
        self.max_dropout = max_dropout
        self.rng = np.random.default_rng(seed)
        self.active = np.ones(num_sites, dtype=bool)

    @property
    def num_dropped(self) -> int:
        return int((~self.active).sum())

    def _drop_one(self):
        idx = self.rng.choice(np.flatnonzero(self.active))
        self.active[idx] = False

    def _rejoin_one(self):
        idx = self.rng.choice(np.flatnonzero(~self.active))
        self.active[idx] = True

    def step(self) -> np.ndarray:
        """Advance one FL round; returns the active mask for this round."""
        if self.max_dropout > 0:
            d = self.num_dropped
            u = self.rng.random()
            if d == 0:
                if u < 0.5:
                    self._drop_one()
            elif d == self.max_dropout:
                if u < 0.5:
                    self._rejoin_one()
            else:
                if u < 1 / 3:
                    self._drop_one()
                elif u < 2 / 3:
                    self._rejoin_one()
        return self.active.copy()

    def masks(self, rounds: int) -> Iterator[np.ndarray]:
        for _ in range(rounds):
            yield self.step()


def availability_step_traced(key, active, max_dropout: int):
    """One Algorithm-2 transition as a pure jax function (same birth–death
    law as :class:`SiteAvailability`; the random *streams* differ — numpy
    PCG64 is not reproducible under the jax PRNG).

    Used by the compiled round engine's on-device input path, where the
    whole multi-round scan runs without host re-entry.  ``active`` is the
    previous round's [S] bool mask; returns this round's mask.
    """
    import jax
    import jax.numpy as jnp
    if max_dropout == 0:
        return active
    k_u, k_drop, k_join = jax.random.split(key, 3)
    d = jnp.sum(~active)
    u = jax.random.uniform(k_u)
    p_drop = jnp.where(d == 0, 0.5, jnp.where(d >= max_dropout, 0.0, 1 / 3))
    p_join = jnp.where(d == 0, 0.0, jnp.where(d >= max_dropout, 0.5, 1 / 3))
    do_drop = u < p_drop
    do_join = (u >= p_drop) & (u < p_drop + p_join)
    # uniform choice among eligible sites = argmax of iid noise on the mask
    drop_idx = jnp.argmax(jnp.where(active,
                                    jax.random.uniform(k_drop, active.shape),
                                    -1.0))
    join_idx = jnp.argmax(jnp.where(~active,
                                    jax.random.uniform(k_join, active.shape),
                                    -1.0))
    new = active.at[drop_idx].set(jnp.where(do_drop, False, active[drop_idx]))
    return new.at[join_idx].set(jnp.where(do_join, True, new[join_idx]))


def stationary_fraction(num_sites: int, max_dropout: int, rounds: int = 10000,
                        seed: int = 0) -> float:
    """Empirical long-run fraction of active sites (used in tests/benchmarks)."""
    chain = SiteAvailability(num_sites, max_dropout, seed)
    tot = 0
    for _ in range(rounds):
        tot += chain.step().sum()
    return tot / (rounds * num_sites)
