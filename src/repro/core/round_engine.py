"""Compiled round engine — K federated rounds in ONE jitted ``lax.scan``.

The paper's headline efficiency claim is wall-clock: parallel federated
rounds cut 86.2 h of sequential training to 13.4 h.  Our scale workhorse
for that claim is the stacked (vmapped) simulator, but its per-round
loop re-entered Python every round: a jit dispatch + ``block_until_ready``
per round, host-generated batches/masks, and — on the compressed and
buffered paths — a per-site device→host copy folded through a numpy
accumulator.  At simulator scale the machine was gated by dispatch and
PCIe, not FLOPs.

This module compiles the loop away.  ``execute_stacked`` runs the job as
a sequence of *chunks*; each chunk is one jitted ``lax.scan`` over
``chunk_rounds`` federated rounds with the carry (fl_state + engine
buffers) **donated**, per-round losses/metrics accumulated into a
``[K, S]`` device buffer and fetched once per chunk:

  * **sync** rounds (every strategy incl. GCML gossip and the pooled
    baseline) scan the existing jitted ``fl_round`` body; host inputs
    (Algorithm-2 masks, gossip pairings, synthetic batches) are
    precomputed per chunk and transferred once — or, with
    ``device_data=True`` on token tasks, produced *on device* from a
    threaded jax PRNG (``make_round_inputs_traced`` +
    ``TokenTaskGenerator.traced_stacked_batches``) so a chunk runs with
    zero host↔device traffic beyond the loss buffer;
  * **compressed** rounds (int8/fp8/topk-fixed, fedavg or fedprox) keep
    simulated compression entirely on device: error-feedback residuals
    ride the scan as ``[S, …]`` state, quantize→dequantize runs through
    the ``kernels/quantize.py`` math (Pallas kernel on TPU/GPU —
    including the fused dequantize+weighted-fold ``fedagg_dequant`` so
    dense per-site models never hit HBM — pure-jnp twin on CPU,
    bit-identical to the numpy wire codec) or the ``jax.lax.top_k``
    exact-k sparsifier, FedProx's proximal anchor re-pins to each
    broadcast global inside the scan (``fedprox-local``), and the fold
    goes through ``AggregationEngine``'s padded ``[S, N]`` buffer — the
    two-tier segment-reduce when the job has a pods topology — instead
    of the host ``StreamingAccumulator``;
  * **buffered** (FedBuff) rounds trace the arrival loop itself: the
    per-round upload order is precomputed host-side (same RNG stream as
    the retired loop), and staleness discounts, K-of-S finalization,
    version counters and the bounded ``keep_globals`` ring of decode
    references are all device state inside the scan.

Chunk boundaries align with checkpoint rounds (the only places a global
model must materialize); compile time is measured once per chunk shape
via AOT lowering and reported as ``JobResult.compile_s``, separate from
the per-round ``step_s``.

The host path is still taken for: the ``topk-sparse`` codec (data-
dependent index payloads — the fixed-k ``topk-fixed`` variant
compiles), buffered runs whose ``max_staleness`` reaches past the
``keep_globals`` ring (or that use a top-k codec), and
``round_engine="loop"`` — the retired per-round driver kept in
``repro.api`` as the parity oracle for tests and benchmarks.  Socket
transports are untouched.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.compression import KEEP_GLOBALS_DEFAULT
from repro.core import federation as F
from repro.core import stacking
from repro.core.agg_engine import (get_engine, normalized_weights,
                                   per_site_nbytes)
from repro.core.session import (BufferedScheduler, JobResult,
                                check_engine_tag, check_privacy_tag)
from repro.core.strategies import base as strat_base

AUTO_CHUNK_ROUNDS = 32      # scan compiles its body once, so chunks are cheap


# ---------------------------------------------------------------------------
# Chunking + compile/timing machinery
# ---------------------------------------------------------------------------


def chunk_plan(rounds: int, chunk_rounds: Optional[int] = None,
               ckpt_every: Optional[int] = None,
               start: int = 0) -> List[int]:
    """Split rounds ``[start, rounds)`` into scan-chunk lengths.

    With checkpointing, a chunk boundary lands right after every
    checkpoint round (``r % ckpt_every == 0``) so the recorder can
    materialize the global model there — mid-chunk states never exist
    on the host.  A resumed run passes ``start`` (the round after its
    checkpoint) and the grid stays aligned because the boundary rule is
    a function of the *absolute* round index.
    """
    chunk = max(1, chunk_rounds or min(max(rounds - start, 1),
                                       AUTO_CHUNK_ROUNDS))
    plan, r = [], start
    while r < rounds:
        kc = min(chunk, rounds - r)
        if ckpt_every:
            next_ckpt = r + (-r) % ckpt_every      # first ckpt round ≥ r
            if next_ckpt < rounds:
                kc = min(kc, next_ckpt + 1 - r)
        plan.append(kc)
        r += kc
    return plan


class _ChunkRunner:
    """Compile-once-per-chunk-shape executor with donated carry buffers.

    ``fn(carry, xs) -> (carry, ys)`` is AOT-lowered and compiled the
    first time each chunk length appears — compile time is measured
    exactly once per program shape and reported separately
    (``JobResult.compile_s``) instead of polluting round 0's ``step_s``.
    The carry (fl_state + engine buffers) is donated, so K rounds run
    without an extra resident copy of the federation's parameters; the
    caller must never touch a carry it has already passed in.
    """

    def __init__(self, fn: Callable):
        self.fn = fn
        self.compile_s = 0.0
        self._cache: Dict[int, Any] = {}

    def run(self, kc: int, carry, xs):
        """Execute one chunk; returns ``(carry', ys, exec_seconds)``."""
        compiled = self._cache.get(kc)
        if compiled is None:
            t0 = time.perf_counter()
            compiled = (jax.jit(self.fn, donate_argnums=0)
                        .lower(carry, xs).compile())
            self._cache[kc] = compiled
            self.compile_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        carry, ys = compiled(carry, xs)
        jax.block_until_ready((carry, ys))
        return carry, ys, time.perf_counter() - t0


def _pairings(masks: np.ndarray, seed: int):
    """Host gossip pairings for every round, consuming the pairing RNG in
    the exact order the retired per-round loop did (parity)."""
    from repro.core.gossip import pair_sites
    rng = np.random.default_rng(seed)
    ps, rs = [], []
    for r in range(masks.shape[0]):
        p, rv, _ = pair_sites(masks[r], rng)
        ps.append(p)
        rs.append(rv)
    return np.stack(ps), np.stack(rs)


def _arrival_orders(masks: np.ndarray, seed: int):
    """Buffered arrival permutations, one per round, padded with zeros
    past the active count — same RNG stream as the retired loop."""
    rng = np.random.default_rng(seed + 13)
    rounds, num_sites = masks.shape
    order = np.zeros((rounds, num_sites), np.int32)
    n_act = np.zeros((rounds,), np.int32)
    for r in range(rounds):
        perm = rng.permutation(np.flatnonzero(masks[r])).astype(np.int32)
        order[r, :len(perm)] = perm
        n_act[r] = len(perm)
    return order, n_act


def _chunk_batches(bundle, r0: int, kc: int, local_steps: int, pooled: bool):
    """[Kc, S, K, …] device batches for one chunk: numpy generation per
    round, stacked, ONE host→device transfer per chunk."""
    rows = []
    for r in range(r0, r0 + kc):
        b = bundle.stacked(r, local_steps)
        if pooled:
            b = bundle.pooled_view(b)
        rows.append(b)
    return {k: jnp.asarray(np.stack([row[k] for row in rows]))
            for k in rows[0]}


# ---------------------------------------------------------------------------
# On-device compression (per-leaf chunk geometry mirrors comms.compression)
# ---------------------------------------------------------------------------


def _chunk_geom(n: int, chunkw: int, align: int):
    """(rows, width) of the quantization chunk matrix for an n-element
    leaf — the wire codec's one chunk-geometry rule, so device and wire
    codecs agree on scales and payload bytes by construction."""
    from repro.comms.compression import chunk_geom
    return chunk_geom(n, chunkw, align)


def _to_chunks(x, chunkw: int, align: int):
    """[S, …] leaf → ([S, rows, c] fp32 chunk matrix, flat size n)."""
    s = x.shape[0]
    n = int(np.prod(x.shape[1:], dtype=np.int64))
    rows, c = _chunk_geom(n, chunkw, align)
    flat = x.reshape(s, n).astype(jnp.float32)
    if rows * c != n:
        flat = jnp.pad(flat, ((0, 0), (0, rows * c - n)))
    return flat.reshape(s, rows, c), n


def _from_chunks(mat, shape, n: int):
    """[…, rows, c] → […, *shape] (drop the zero padding)."""
    lead = mat.shape[:-2]
    return mat.reshape(lead + (-1,))[..., :n].reshape(lead + tuple(shape))


def _qdq_tree(u, chunkw: int, align: int, codec_name: str):
    """Traced quantize→dequantize of a stacked [S, …] pytree with the
    wire codec's per-leaf chunk geometry (pure jnp — bit-identical to
    the numpy codec on CPU).

    Leaves sharing a chunk width are batched into ONE [S, ΣR, c] call:
    chunks never cross leaf boundaries (every leaf is padded to whole
    rows first), so the grouped math is element-identical to per-leaf
    encoding while cutting the op count from O(leaves) to O(widths).
    """
    from repro.kernels.quantize import (quantize_dequantize_fp8_ref,
                                        quantize_dequantize_ref)
    qdq = (quantize_dequantize_ref if codec_name == "int8"
           else quantize_dequantize_fp8_ref)
    leaves, treedef = jax.tree.flatten(u)
    groups: Dict[int, List[int]] = {}
    chunked = []
    for i, x in enumerate(leaves):
        mat, n = _to_chunks(x, chunkw, align)
        chunked.append((mat, n))
        groups.setdefault(mat.shape[-1], []).append(i)
    out: List[Any] = [None] * len(leaves)
    for c, idxs in groups.items():
        mats = [chunked[i][0] for i in idxs]
        deq = qdq(jnp.concatenate(mats, axis=1))
        r0 = 0
        for i, mat in zip(idxs, mats):
            rows = mat.shape[1]
            out[i] = _from_chunks(deq[:, r0:r0 + rows], leaves[i].shape[1:],
                                  chunked[i][1])
            r0 += rows
    return jax.tree.unflatten(treedef, out)


def _topk_tree(u, fraction: float):
    """Traced exact-k magnitude sparsification of a stacked [S, …] pytree
    — the ``topk-fixed`` codec's device twin.  ``k`` per leaf is the same
    ``ceil(fraction · n)`` the wire codec uses, a *static* function of
    the leaf shape, so the scan body stays fixed-shape (the reason the
    original data-shaped ``topk-sparse`` path could not compile)."""
    def one(x):
        s = x.shape[0]
        flat = x.reshape(s, -1).astype(jnp.float32)
        n = flat.shape[1]
        k = max(1, int(np.ceil(fraction * n)))
        if k >= n:
            return flat.reshape(x.shape)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)            # [S, k]
        rows = jnp.arange(s)[:, None]
        kept = jnp.zeros_like(flat).at[rows, idx].set(
            jnp.take_along_axis(flat, idx, axis=1))
        return kept.reshape(x.shape)
    return jax.tree.map(one, u)


def _topk_nbytes(params_stacked, fraction: float) -> int:
    """Wire payload bytes of ONE ``topk-fixed`` upload: a uint32 index +
    fp32 value per kept entry — matches ``tree_payload_nbytes`` over the
    host codec's ``QuantizedTensor``s."""
    total = 0
    for x in jax.tree.leaves(params_stacked):
        n = int(np.prod(x.shape[1:], dtype=np.int64))
        total += 8 * max(1, int(np.ceil(fraction * n)))
    return total


def _compressed_fold(u, w, codec_name: str, chunkw: int, align: int,
                     accel: bool, engine, fold_tree=None, dense=None,
                     fraction: float = 0.1):
    """One round's simulated server step, fully on device: quantize→
    dequantize (or top-k sparsify) every site's upload ``u`` and fold
    Eq. 1 at weights ``w``.  Returns ``(global_delta_tree,
    residual_tree)`` with ``residual = u − deQ(Q(u))``.

    ``fold_tree`` overrides the flat reduction (the pods topology folds
    per-pod partials first); ``dense`` is a traced bool that bypasses the
    codec for the round (the top-k sparsifier's dense bootstrap — it
    must not decimate the one full-model upload of a run).

    On TPU/GPU the int8 path runs the Pallas quantize kernel and the
    fused ``fedagg_dequant`` dequantize+fold, so the dense fp32 per-site
    models never materialize off-chip; on CPU (and for fp8/top-k) the
    jnp twin folds through the ``AggregationEngine``'s padded [S, N]
    buffer.
    """
    if accel and codec_name == "int8" and fold_tree is None:
        from repro.kernels import ops
        leaves, treedef = jax.tree.flatten(u)
        g_leaves, r_leaves = [], []
        for x in leaves:
            mat, n = _to_chunks(x, chunkw, align)
            s, rows, c = mat.shape
            q, sc = ops.quantize_int8(mat.reshape(s * rows, c))
            g, res = ops.fedagg_dequant(q.reshape(s, rows, c),
                                        sc.reshape(s, rows), mat, w)
            g_leaves.append(_from_chunks(g[None], x.shape[1:], n)[0])
            r_leaves.append(_from_chunks(res, x.shape[1:], n))
        return (jax.tree.unflatten(treedef, g_leaves),
                jax.tree.unflatten(treedef, r_leaves))
    if codec_name == "topk-fixed":
        deq = _topk_tree(u, fraction)
    else:
        deq = _qdq_tree(u, chunkw, align, codec_name)
    if dense is not None:
        # `dense` is either a per-round scalar (the top-k sparsifier's
        # round-0 bootstrap) or a per-site [S] mask (bidirectional
        # compression: each site bootstraps on ITS OWN rejoin schedule)
        def _sel(full, q):
            d = dense
            if getattr(d, "ndim", 0) == 1:
                d = d.reshape((-1,) + (1,) * (full.ndim - 1))
            return jnp.where(d, full.astype(jnp.float32), q)
        deq = jax.tree.map(_sel, u, deq)
    if fold_tree is not None:
        gdelta = fold_tree(deq)
    else:
        flat, layout = engine.flatten(deq)
        gdelta = engine.unflatten(engine.reduce_flat(flat, w), layout)
    return gdelta, jax.tree.map(jnp.subtract, u, deq)


def _encoded_nbytes(params_stacked, chunkw: int, align: int) -> int:
    """Wire payload bytes of ONE quantized upload under the per-leaf
    chunk layout (1-byte values + fp32 per-chunk scales) — matches
    ``tree_payload_nbytes`` over the host codec's ``QuantizedTensor``s."""
    total = 0
    for x in jax.tree.leaves(params_stacked):
        n = int(np.prod(x.shape[1:], dtype=np.int64))
        rows, c = _chunk_geom(n, chunkw, align)
        total += rows * c + rows * 4
    return total


def _down_install_tree(gref, down_ref, codec_name: str, chunkw: int,
                       align: int, accel: bool, fraction: float):
    """Traced per-site compressed install: each site's new model is its
    held download reference plus the quantized (or top-k sparsified)
    delta of the fresh global against that reference — the device twin
    of ``DownlinkCompressor.encode`` + ``decode_download``.  Feeding the
    result back as the next round's reference IS the downlink error-
    feedback recurrence (``held ← held + deQ(Q(g − held))``), so
    quantization errors telescope across rounds.  On accelerators the
    int8 path runs the fused ``dequant_install`` Pallas kernel, so the
    dense per-site deltas never materialize in HBM."""
    delta = jax.tree.map(lambda g, h: g[None] - h, gref, down_ref)
    if accel and codec_name == "int8":
        from repro.kernels import ops

        def one(d, h):
            mat, n = _to_chunks(d, chunkw, align)
            s, rows, c = mat.shape
            q, sc = ops.quantize_int8(mat.reshape(s * rows, c))
            hmat, _ = _to_chunks(h, chunkw, align)
            inst = ops.dequant_install(q.reshape(s, rows, c),
                                       sc.reshape(s, rows), hmat)
            return _from_chunks(inst, d.shape[1:], n)
        return jax.tree.map(one, delta, down_ref)
    if codec_name == "topk-fixed":
        qd = _topk_tree(delta, fraction)
    else:
        qd = _qdq_tree(delta, chunkw, align, codec_name)
    return jax.tree.map(jnp.add, down_ref, qd)


def _bootstrap_masks(masks: np.ndarray, keep: int) -> np.ndarray:
    """[rounds, S] — which (round, site) exchanges bootstrap dense under
    bidirectional compression: the site's previous participation is
    ``keep`` or more rounds back (its upload reference left the server's
    ``keep_globals`` window and its download reference was evicted on
    the same clock), or it never participated.  A pure function of the
    participation masks, so a resumed run replays the identical
    schedule."""
    rounds, s = masks.shape
    last = np.full(s, -keep, np.int64)          # "never": forces bootstrap
    boot = np.zeros((rounds, s), bool)
    for r in range(rounds):
        boot[r] = masks[r] & (r - last >= keep)
        last[masks[r]] = r
    return boot


def _accel() -> bool:
    from repro.kernels.ops import _default_interpret
    return not _default_interpret()


# ---------------------------------------------------------------------------
# Sync rounds (every strategy) — one scan per chunk
# ---------------------------------------------------------------------------


def _run_sync_scan(job, bundle, scheduler, rounds: int,
                   resume_round: Optional[int] = None) -> JobResult:
    ctx = job.context(bundle)
    strategy = strat_base.get_strategy(job.strategy)
    num_sites = ctx.fed.num_sites
    state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
    # a pods topology changes nothing here beyond the strategy's
    # post_exchange hook: aggregate_round segment-reduces the padded
    # [S, N] buffer by pod id inside the same scanned body
    fl_round = F.build_fl_round(ctx)
    needs_val = strategy.needs_val_batch
    needs_pair = strategy.needs_pairing
    pooled = job.strategy == "pooled"
    device_data = bool(job.device_data)

    masks = job.masks(rounds)
    # client sampling: the [rounds, S] 1/π Eq. 1 factor rides the chunk
    # xs only when sampling thins participation — unsampled runs keep a
    # bit-identical scan body and carry
    wscale = job.weight_scale(rounds) if job.sampled else None
    if needs_pair and not device_data:
        partner, is_recv = _pairings(masks, job.seed)
    else:
        partner = np.broadcast_to(np.arange(num_sites), masks.shape).copy()
        is_recv = np.zeros(masks.shape, bool)

    def add_val_batches(ri, b):
        if needs_val:
            ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
            ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
        return ri

    if device_data:
        from repro.core.dropout import availability_step_traced
        data_key = jax.random.fold_in(jax.random.PRNGKey(job.seed), 7)

        def chunk_fn(carry, xs):
            def body(c, r):
                st, active = c
                k_av, k_pair, k_data = jax.random.split(
                    jax.random.fold_in(data_key, r), 3)
                if job.max_dropout:
                    active = availability_step_traced(k_av, active,
                                                      job.max_dropout)
                ri = F.make_round_inputs_traced(ctx, k_pair, active)
                b = bundle.traced_stacked(k_data, job.local_steps,
                                          job.task.batch)
                st, metrics = fl_round(st, b, add_val_batches(ri, b))
                ys = {"loss": metrics["loss"], "active": active,
                      "partner": ri["partner"],
                      "is_receiver": ri["is_receiver"]}
                return (st, active), ys
            return jax.lax.scan(body, carry, xs)

        carry = (state, jnp.ones((num_sites,), bool))
    else:
        def chunk_fn(carry, xs):
            def body(st, x):
                b = x["batches"]
                ri = {"active": x["active"], "partner": x["partner"],
                      "is_receiver": x["is_receiver"]}
                if "wscale" in x:
                    ri["weight_scale"] = x["wscale"]
                st, metrics = fl_round(st, b, add_val_batches(ri, b))
                return st, {"loss": metrics["loss"]}
            return jax.lax.scan(body, carry, xs)

        carry = state

    runner = _ChunkRunner(chunk_fn)
    recorder = job.recorder(rounds, num_sites)
    start_round = 0
    if resume_round is not None:
        lmeta = recorder.store.meta("driver_state", resume_round)
        check_engine_tag(lmeta, "sync-scan")
        check_privacy_tag(lmeta, job.dp_tag())
        loaded, _ = recorder.store.load(
            "driver_state", resume_round, jax.tree.map(np.asarray, carry))
        carry = jax.tree.map(jnp.asarray, loaded)
        state = carry[0] if device_data else carry
        start_round = resume_round + 1
    masks_seen: List[np.ndarray] = []
    r0 = start_round
    plan = chunk_plan(rounds, job.chunk_rounds,
                      job.ckpt_every if recorder.store else None,
                      start=start_round)
    for kc in plan:
        if device_data:
            xs = jnp.arange(r0, r0 + kc)
        else:
            xs = {"batches": _chunk_batches(bundle, r0, kc, job.local_steps,
                                            pooled),
                  "active": jnp.asarray(masks[r0:r0 + kc]),
                  "partner": jnp.asarray(partner[r0:r0 + kc]),
                  "is_receiver": jnp.asarray(is_recv[r0:r0 + kc])}
            if wscale is not None:
                xs["wscale"] = jnp.asarray(wscale[r0:r0 + kc])
        carry, ys, exec_s = runner.run(kc, carry, xs)
        state = carry[0] if device_data else carry
        losses = np.asarray(ys["loss"])
        if device_data:
            rows = np.asarray(ys["active"])
            p_rows, r_rows = np.asarray(ys["partner"]), np.asarray(
                ys["is_receiver"])
            masks_seen.append(rows)
        else:
            rows = masks[r0:r0 + kc]
            p_rows, r_rows = partner[r0:r0 + kc], is_recv[r0:r0 + kc]
        step_s = exec_s / kc
        for i in range(kc):
            extra = {"step_s": step_s, "wall_s": step_s}
            if needs_pair:
                extra["partner"] = [int(v) for v in p_rows[i]]
                extra["is_receiver"] = [bool(v) for v in r_rows[i]]
            recorder.record(
                r0 + i, losses[i], rows[i],
                global_fn=(lambda st=state: F.global_model(st, ctx))
                if i == kc - 1 else None,
                extra=extra)
        recorder.save_state(r0 + kc - 1,
                            lambda: jax.tree.map(np.asarray, carry),
                            meta={"engine": "sync-scan",
                                  "dp": job.dp_tag()})
        r0 += kc
    all_masks = (np.concatenate(masks_seen) if masks_seen
                 else masks[start_round:])
    comm = None
    if job.strategy in ("fedavg", "fedprox"):
        nbytes = per_site_nbytes(state["params"])
        if ctx.topology.is_pods:
            from repro.core.topology import simulated_pods_comm
            comm = simulated_pods_comm(ctx.topology, all_masks, nbytes)
        else:
            uploads = int(all_masks.sum())
            comm = {"upload_bytes": uploads * nbytes,
                    "download_bytes": uploads * nbytes,
                    "total_bytes": 2 * uploads * nbytes,
                    "upload_count": uploads, "download_count": uploads,
                    "compression": "none", "down_compression": "none",
                    "simulated": True}
    return recorder.result(F.global_model(state, ctx), transport="stacked",
                           scheduler=scheduler.name, state=state, comm=comm,
                           compile_s=runner.compile_s,
                           resumed_from=resume_round,
                           privacy=job.privacy_report(rounds))


# ---------------------------------------------------------------------------
# Compressed sync rounds (int8/fp8 fedavg) — on-device codec + fold
# ---------------------------------------------------------------------------


def _run_compressed_scan(job, bundle, scheduler, rounds: int, codec,
                         resume_round: Optional[int] = None,
                         down_codec=None) -> JobResult:
    """Compressed sync rounds on device.  Local training runs under the
    strategy's *site half* — ``individual`` for FedAvg, ``fedprox-local``
    for FedProx (the Eq. 2 proximal pull, re-anchored to every broadcast
    global inside the scan) — and the simulated server fold goes through
    the codec's device twin: int8/fp8 quantize→dequantize or the
    ``topk-fixed`` exact-k sparsifier (dense on the bootstrap round).  A
    pods topology swaps the flat fold for the two-tier segment-reduce.

    With ``down_codec`` (bidirectional compression) the broadcast rides
    the codec seam too: per-site download references become additional
    ``[S, …]`` scan carry, every install is a quantized delta against
    that site's held reference (``_down_install_tree`` — the fused
    ``dequant_install`` kernel on accelerators), uploads anchor to the
    site's OWN install instead of the shared global, and the fold becomes
    ``g = Σ wₛ(anchorₛ + deQ(uₛ))`` — exactly the socket server's
    per-site decode.  Sites whose reference left the ``keep_globals``
    window bootstrap dense both ways on a host-precomputed
    ``_bootstrap_masks`` schedule.  Engines anchor FedProx's Eq. 2 at
    the exact global (the vmapped round body broadcasts ONE anchor);
    socket sites anchor at their decoded install — the difference is the
    downlink quantization error, which the EF recurrence telescopes."""
    local_strategy = ("fedprox-local" if job.strategy == "fedprox"
                      else "individual")
    prox = local_strategy == "fedprox-local"
    ctx = job.context(bundle, strategy=local_strategy)  # local-only rounds
    num_sites = ctx.fed.num_sites
    state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
    fl_round = F.build_fl_round(ctx)
    masks = job.masks(rounds)
    wscale = job.weight_scale(rounds) if job.sampled else None
    case_w = jnp.asarray(np.asarray(job.federation().case_weights()),
                         jnp.float32)
    engine = get_engine()
    accel = _accel()
    chunkw = int(getattr(codec, "chunk", 1024))
    align = 128 if (accel and codec.name == "int8") else 1
    fraction = float(getattr(codec, "fraction", 0.1))
    topk = codec.name == "topk-fixed"
    up = codec.name != "none"
    down = down_codec is not None and down_codec.name != "none"
    d_chunkw = int(getattr(down_codec, "chunk", 1024)) if down else chunkw
    d_align = 128 if (accel and down and down_codec.name == "int8") else 1
    d_fraction = (float(getattr(down_codec, "fraction", 0.1)) if down
                  else fraction)
    error_feedback = bool(job.error_feedback)
    identity = np.arange(num_sites)
    no_recv = np.zeros(num_sites, bool)
    topo = job.topo
    pod_ids = jnp.asarray(topo.pod_of(num_sites)) if topo.is_pods else None

    # the init model is "reference zero": round 0's delta against zeros IS
    # the dense bootstrap upload the wire codec would send
    reference = jax.tree.map(lambda x: jnp.zeros(x.shape[1:], jnp.float32),
                             state["params"])
    residual = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                            state["params"])

    def fold_plain(tree, w, active, scale):
        """Σ wₛ · treeₛ over a stacked [S, …] tree — the flat Eq. 1
        reduce, or the two-tier segment-reduce under a pods topology."""
        flat, layout = engine.flatten(tree)
        if pod_ids is not None:
            g = engine.reduce_pods_flat(flat, case_w, active, pod_ids,
                                        topo.num_pods, topo.intra,
                                        topo.inter, scale=scale)
        else:
            g = engine.reduce_flat(flat, w)
        return engine.unflatten(g, layout)

    def chunk_fn(carry, xs):
        def body(c, x):
            st, ref, res = c
            active = x["active"]
            st, metrics = fl_round(st, x["batches"],
                                   {"active": active, "partner": identity,
                                    "is_receiver": no_recv})
            # delta vs last broadcast global, plus the carried EF residual
            u = jax.tree.map(
                lambda p, g, e: p.astype(jnp.float32) - g[None] + e,
                st["params"], ref, res)
            scale = x.get("wscale")
            w = normalized_weights(case_w, active, scale)
            fold_tree = None
            if pod_ids is not None:
                def fold_tree(deq, active=active, scale=scale):
                    return fold_plain(deq, None, active, scale)
            gdelta, new_res = _compressed_fold(
                u, w, codec.name, chunkw, align, accel, engine,
                fold_tree=fold_tree,
                dense=x["bootstrap"] if topk else None, fraction=fraction)
            if error_feedback:
                res = stacking.where_site(active, new_res, res)
            ref = jax.tree.map(jnp.add, ref, gdelta)
            bcast = jax.tree.map(
                lambda g, p: jnp.broadcast_to(g[None], p.shape).astype(p.dtype),
                ref, st["params"])
            st = {**st, "params": stacking.where_site(active, bcast,
                                                      st["params"])}
            if prox:            # next round's proximal anchor = this global
                st = {**st, "strategy": {"global": ref}}
            return (st, ref, res), {"loss": metrics["loss"]}
        return jax.lax.scan(body, carry, xs)

    def chunk_fn_bidir(carry, xs):
        def body(c, x):
            st, gref, dref, res = c
            active = x["active"]
            boot = x["bootstrap"]                       # [S] bool
            st, metrics = fl_round(st, x["batches"],
                                   {"active": active, "partner": identity,
                                    "is_receiver": no_recv})
            scale = x.get("wscale")
            w = normalized_weights(case_w, active, scale)

            def rowsel(a, b):
                # per-site select on the stacked axis
                return jax.tree.map(
                    lambda aa, bb: jnp.where(
                        boot.reshape((-1,) + (1,) * (aa.ndim - 1)), aa, bb),
                    a, b)
            # upload anchor: the site's OWN held install; a site whose
            # reference left the server window uploads dense (anchor 0)
            anchor = rowsel(jax.tree.map(jnp.zeros_like, dref), dref)
            if up:
                u = jax.tree.map(
                    lambda p, a, e: p.astype(jnp.float32) - a + e,
                    st["params"], anchor, res)
                fold_tree = None
                if pod_ids is not None:
                    def fold_tree(deq, active=active, scale=scale):
                        return fold_plain(deq, None, active, scale)
                gdelta, new_res = _compressed_fold(
                    u, w, codec.name, chunkw, align, accel, engine,
                    fold_tree=fold_tree, dense=boot if topk else None,
                    fraction=fraction)
                if error_feedback:
                    res = stacking.where_site(active, new_res, res)
                # per-site decode: g = Σ wₛ(anchorₛ + deQ(uₛ)) — the
                # anchors differ per site, so the fold carries them too
                gref = jax.tree.map(
                    jnp.add, fold_plain(anchor, w, active, scale), gdelta)
            else:
                # down-only compression: uploads ride dense fp32
                gref = fold_plain(
                    jax.tree.map(lambda p: p.astype(jnp.float32),
                                 st["params"]), w, active, scale)
            # downlink: quantized delta against each site's held
            # reference; bootstrap rows (new/evicted) get the dense global
            inst = _down_install_tree(gref, dref, down_codec.name, d_chunkw,
                                      d_align, accel, d_fraction)
            inst = rowsel(jax.tree.map(
                lambda g, q: jnp.broadcast_to(g[None], q.shape), gref, inst),
                inst)
            dref = stacking.where_site(active, inst, dref)
            bcast = jax.tree.map(lambda i_, p: i_.astype(p.dtype),
                                 inst, st["params"])
            st = {**st, "params": stacking.where_site(active, bcast,
                                                      st["params"])}
            if prox:        # engines broadcast ONE Eq. 2 anchor (exact
                            # global); socket sites anchor at their install
                st = {**st, "strategy": {"global": gref}}
            return (st, gref, dref, res), {"loss": metrics["loss"]}
        return jax.lax.scan(body, carry, xs)

    engine_tag = "compressed-scan-bidir" if down else "compressed-scan"
    runner = _ChunkRunner(chunk_fn_bidir if down else chunk_fn)
    recorder = job.recorder(rounds, num_sites)
    dense_nbytes = per_site_nbytes(state["params"])
    enc_nbytes = (dense_nbytes if not up
                  else _topk_nbytes(state["params"], fraction) if topk
                  else _encoded_nbytes(state["params"], chunkw, align))
    # host-precomputed per-round wire bytes — bit-identical to the loop
    # twin's tree_payload_nbytes counters.  Dense bootstrap uploads still
    # ride the codec (quantized dense) except under top-k, whose
    # dense_bootstrap rule ships raw fp32; dense bootstrap downloads
    # always ship raw fp32 (the DownlinkCompressor's "none" reply).
    if down:
        boot_mask = _bootstrap_masks(masks, KEEP_GLOBALS_DEFAULT)
        down_enc = (_topk_nbytes(state["params"], d_fraction)
                    if down_codec.name == "topk-fixed"
                    else _encoded_nbytes(state["params"], d_chunkw, d_align))
        per_up = (np.where(boot_mask, dense_nbytes, enc_nbytes) if topk
                  else np.full(masks.shape, enc_nbytes, np.int64))
        round_up_bytes = np.where(masks, per_up, 0).sum(axis=1)
        round_down_bytes = np.where(
            masks, np.where(boot_mask, dense_nbytes, down_enc), 0).sum(axis=1)
    else:
        # the wire codec's dense_bootstrap rule: round 0 (no reference
        # global yet) rides dense; sparsity starts once deltas exist
        round_up_bytes = np.asarray(
            [int(masks[r].sum()) * (dense_nbytes if (topk and r == 0)
                                    else enc_nbytes)
             for r in range(rounds)], np.int64)
        round_down_bytes = masks.sum(axis=1).astype(np.int64) * dense_nbytes
    if down:
        down_ref0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                 state["params"])
        carry = (state, reference, down_ref0, residual)
    else:
        carry = (state, reference, residual)
    start_round = 0
    if resume_round is not None:
        lmeta = recorder.store.meta("driver_state", resume_round)
        check_engine_tag(lmeta, engine_tag)
        check_privacy_tag(lmeta, job.dp_tag())
        loaded, _ = recorder.store.load(
            "driver_state", resume_round, jax.tree.map(np.asarray, carry))
        carry = jax.tree.map(jnp.asarray, loaded)
        start_round = resume_round + 1
    r0 = start_round
    for kc in chunk_plan(rounds, job.chunk_rounds,
                         job.ckpt_every if recorder.store else None,
                         start=start_round):
        xs = {"batches": _chunk_batches(bundle, r0, kc, job.local_steps,
                                        False),
              "active": jnp.asarray(masks[r0:r0 + kc])}
        if wscale is not None:
            xs["wscale"] = jnp.asarray(wscale[r0:r0 + kc])
        if down:
            xs["bootstrap"] = jnp.asarray(boot_mask[r0:r0 + kc])
        elif topk:
            xs["bootstrap"] = jnp.asarray(
                [r == 0 for r in range(r0, r0 + kc)])
        carry, ys, exec_s = runner.run(kc, carry, xs)
        losses = np.asarray(ys["loss"])
        step_s = exec_s / kc
        for i in range(kc):
            extra = {"step_s": step_s, "wall_s": step_s,
                     "upload_bytes": int(round_up_bytes[r0 + i])}
            if down:
                extra["download_bytes"] = int(round_down_bytes[r0 + i])
            recorder.record(
                r0 + i, losses[i], masks[r0 + i],
                global_fn=(lambda c=carry: c[1]) if i == kc - 1 else None,
                extra=extra)
        recorder.save_state(r0 + kc - 1,
                            lambda: jax.tree.map(np.asarray, carry),
                            meta={"engine": engine_tag,
                                  "dp": job.dp_tag()})
        r0 += kc
    state, reference = carry[0], carry[1]
    uploads = int(masks[start_round:].sum())
    upload_bytes = int(round_up_bytes[start_round:].sum())
    download_bytes = int(round_down_bytes[start_round:].sum())
    comm = {"upload_bytes": upload_bytes,
            "upload_raw_bytes": uploads * dense_nbytes,
            "download_bytes": download_bytes,
            "download_raw_bytes": uploads * dense_nbytes,
            "total_bytes": upload_bytes + download_bytes,
            "upload_count": uploads, "download_count": uploads,
            "compression": codec.name,
            "down_compression": down_codec.name if down else "none",
            "simulated": True}
    if topo.is_pods:
        from repro.core.topology import simulated_pods_comm
        comm.update(simulated_pods_comm(
            topo, masks[start_round:], dense_nbytes,
            intra_upload_bytes=upload_bytes,
            intra_download_bytes=download_bytes if down else None,
            compression=codec.name,
            down_compression=down_codec.name if down else "none"))
    return recorder.result(reference, transport="stacked",
                           scheduler=scheduler.name, state=state, comm=comm,
                           compile_s=runner.compile_s,
                           resumed_from=resume_round,
                           privacy=job.privacy_report(rounds))


# ---------------------------------------------------------------------------
# Buffered (FedBuff) rounds — the arrival loop as device state
# ---------------------------------------------------------------------------


def _run_buffered_scan(job, bundle, scheduler, rounds: int, codec,
                       resume_round: Optional[int] = None) -> JobResult:
    compress = codec.name != "none"
    ctx = job.context(bundle, strategy="individual")
    num_sites = ctx.fed.num_sites
    state = F.init_fl_state(ctx, bundle.init_fn, jax.random.PRNGKey(job.seed))
    fl_round = F.build_fl_round(ctx)
    masks = job.masks(rounds)
    order, n_act = _arrival_orders(masks, job.seed)
    case_w = jnp.asarray(np.asarray(job.federation().case_weights()),
                         jnp.float32)
    engine = get_engine()
    flat0, layout = engine.flatten(state["params"])
    n = layout.n
    g0 = engine.reduce_flat(flat0, case_w / jnp.sum(case_w))
    identity = np.arange(num_sites)
    no_recv = np.zeros(num_sites, bool)
    buffer_k = int(scheduler.buffer_k)
    alpha = float(scheduler.alpha)
    max_st = int(scheduler.max_staleness)
    keep = KEEP_GLOBALS_DEFAULT
    error_feedback = bool(job.error_feedback)
    chunkw = int(getattr(codec, "chunk", 1024))
    rows_f, c_f = _chunk_geom(n, chunkw, 1)
    if compress:
        from repro.kernels.quantize import (quantize_dequantize_fp8_ref,
                                            quantize_dequantize_ref)
        qdq = (quantize_dequantize_ref if codec.name == "int8"
               else quantize_dequantize_fp8_ref)

        def qdq_flat(u):
            mat = jnp.pad(u, (0, rows_f * c_f - n)).reshape(rows_f, c_f)
            return qdq(mat).reshape(-1)[:n]

    carry = {"state": state, "acc": jnp.zeros((n,), jnp.float32),
             "accw": jnp.zeros((), jnp.float32),
             "count": jnp.zeros((), jnp.int32),
             "version": jnp.zeros((), jnp.int32),
             "base": jnp.zeros((num_sites,), jnp.int32), "gflat": g0}
    if compress:
        # version → decode reference, as a bounded on-device ring (the
        # AggregationServer's keep_globals window); slot 0 = init model
        carry["ring"] = jnp.zeros((keep, n), jnp.float32).at[0].set(g0)
        carry["residual"] = jnp.zeros((num_sites, n), jnp.float32)

    def chunk_fn(carry, xs):
        def body(c, x):
            st, metrics = fl_round(c["state"], x["batches"],
                                   {"active": x["active"],
                                    "partner": identity,
                                    "is_receiver": no_recv})
            pflat = engine.flatten(st["params"])[0]
            ord_r, na = x["order"], x["n_act"]
            kmin = jnp.minimum(buffer_k, jnp.maximum(na, 1))

            def arrival(j, a):
                (pflat, acc, accw, count, version, base, gflat, ring,
                 residual, uploaded, folds) = a
                site = ord_r[j]
                valid = j < na
                tau = version - base[site]
                ok = (tau >= 0) & (tau <= max_st)
                admit = valid & ok
                reject = valid & ~ok
                disc = (1.0 + jnp.clip(tau, 0, max_st).astype(jnp.float32)
                        ) ** (-alpha)
                upload = pflat[site]
                if compress:
                    ref = ring[base[site] % keep]
                    u = upload - ref + residual[site]
                    deq = qdq_flat(u)
                    if error_feedback:
                        residual = residual.at[site].set(
                            jnp.where(admit, u - deq, residual[site]))
                    decoded = deq + ref
                else:
                    decoded = upload
                w = case_w[site] * disc * admit
                acc = acc + w * decoded
                accw = accw + w
                count = count + admit
                folds = folds + admit
                uploaded = uploaded.at[site].set(uploaded[site] | admit)
                # too stale: resync to the current global, no contribution
                pflat = pflat.at[site].set(jnp.where(reject, gflat,
                                                     pflat[site]))
                base = base.at[site].set(jnp.where(reject, version,
                                                   base[site]))
                fire = admit & (count >= kmin)
                newg = acc / jnp.maximum(accw, jnp.float32(1e-12))
                gflat = jnp.where(fire, newg, gflat)
                version = version + fire
                if compress:
                    slot = version % keep
                    ring = ring.at[slot].set(jnp.where(fire, newg,
                                                       ring[slot]))
                acc = jnp.where(fire, jnp.zeros_like(acc), acc)
                accw = jnp.where(fire, jnp.zeros_like(accw), accw)
                count = jnp.where(fire, jnp.zeros_like(count), count)
                return (pflat, acc, accw, count, version, base, gflat, ring,
                        residual, uploaded, folds)

            a0 = (pflat, c["acc"], c["accw"], c["count"], c["version"],
                  c["base"], c["gflat"],
                  c.get("ring", jnp.zeros((), jnp.float32)),
                  c.get("residual", jnp.zeros((), jnp.float32)),
                  jnp.zeros((num_sites,), bool), jnp.zeros((), jnp.int32))
            (pflat, acc, accw, count, version, base, gflat, ring, residual,
             uploaded, folds) = jax.lax.fori_loop(0, num_sites, arrival, a0)
            # uploaders pull the latest global and re-anchor
            pflat = jnp.where(uploaded[:, None], gflat[None, :], pflat)
            base = jnp.where(uploaded, version, base)
            st = {**st, "params": engine.unflatten_stacked(pflat, layout)}
            nc = {"state": st, "acc": acc, "accw": accw, "count": count,
                  "version": version, "base": base, "gflat": gflat}
            if compress:
                nc["ring"], nc["residual"] = ring, residual
            return nc, {"loss": metrics["loss"], "version": version,
                        "folds": folds}
        return jax.lax.scan(body, carry, xs)

    runner = _ChunkRunner(chunk_fn)
    recorder = job.recorder(rounds, num_sites)
    start_round = 0
    if resume_round is not None:
        lmeta = recorder.store.meta("driver_state", resume_round)
        check_engine_tag(lmeta, "buffered-scan")
        check_privacy_tag(lmeta, job.dp_tag())
        loaded, _ = recorder.store.load(
            "driver_state", resume_round, jax.tree.map(np.asarray, carry))
        carry = jax.tree.map(jnp.asarray, loaded)
        start_round = resume_round + 1
    total_folds = 0
    r0 = start_round
    for kc in chunk_plan(rounds, job.chunk_rounds,
                         job.ckpt_every if recorder.store else None,
                         start=start_round):
        xs = {"batches": _chunk_batches(bundle, r0, kc, job.local_steps,
                                        False),
              "active": jnp.asarray(masks[r0:r0 + kc]),
              "order": jnp.asarray(order[r0:r0 + kc]),
              "n_act": jnp.asarray(n_act[r0:r0 + kc])}
        carry, ys, exec_s = runner.run(kc, carry, xs)
        losses = np.asarray(ys["loss"])
        versions = np.asarray(ys["version"])
        total_folds += int(np.asarray(ys["folds"]).sum())
        step_s = exec_s / kc
        for i in range(kc):
            recorder.record(
                r0 + i, losses[i], masks[r0 + i],
                global_fn=(lambda c=carry: engine.unflatten(c["gflat"],
                                                            layout))
                if i == kc - 1 else None,
                extra={"version": int(versions[i]), "step_s": step_s,
                       "wall_s": step_s})
        recorder.save_state(r0 + kc - 1,
                            lambda: jax.tree.map(np.asarray, carry),
                            meta={"engine": "buffered-scan",
                                  "dp": job.dp_tag()})
        r0 += kc
    state = carry["state"]
    global_params = engine.unflatten(carry["gflat"], layout)
    comm = None
    if compress:
        enc = rows_f * c_f + rows_f * 4          # flat-layout payload bytes
        down_b = total_folds * per_site_nbytes(state["params"])
        comm = {"upload_bytes": total_folds * enc,
                "upload_raw_bytes": total_folds * n * 4,
                "download_bytes": down_b,
                "total_bytes": total_folds * enc + down_b,
                "upload_count": total_folds, "download_count": total_folds,
                "compression": codec.name, "down_compression": "none",
                "simulated": True}
    return recorder.result(global_params, transport="stacked",
                           scheduler=scheduler.name, state=state, comm=comm,
                           compile_s=runner.compile_s,
                           resumed_from=resume_round,
                           privacy=job.privacy_report(rounds))


# ---------------------------------------------------------------------------
# Sharded cross-device engine — the [S, …] site state partitioned over a mesh
# ---------------------------------------------------------------------------


def _pack_participants(participate: np.ndarray, weight: np.ndarray,
                       pod_of: np.ndarray, s_loc: int, num_devices: int):
    """Pack each round's participants into static per-device slots.

    Sites live in contiguous blocks of ``s_loc`` rows per device, so a
    participant never moves between devices: each device trains exactly
    the sampled rows it already owns and only the O(N) fold crosses the
    mesh.  Returns ``(lidx, valid, w, pod, gsite, k_cap)`` where every
    array is [rounds, D, k_cap]; padded slots carry ``lidx == s_loc``
    (out of range — gathers clip to a throwaway row, scatters drop) and
    weight 0.
    """
    rounds = participate.shape[0]
    dev_of = np.arange(participate.shape[1]) // s_loc
    counts = [[int(np.sum(participate[r] & (dev_of == d)))
               for d in range(num_devices)] for r in range(rounds)]
    k_cap = max(1, max(max(c) for c in counts))
    lidx = np.full((rounds, num_devices, k_cap), s_loc, np.int32)
    valid = np.zeros((rounds, num_devices, k_cap), bool)
    w = np.zeros((rounds, num_devices, k_cap), np.float32)
    pod = np.zeros((rounds, num_devices, k_cap), np.int32)
    gsite = np.zeros((rounds, num_devices, k_cap), np.int32)
    for r in range(rounds):
        for d in range(num_devices):
            sites = np.flatnonzero(participate[r] & (dev_of == d))
            k = len(sites)
            lidx[r, d, :k] = sites - d * s_loc
            valid[r, d, :k] = True
            w[r, d, :k] = weight[r, sites]
            pod[r, d, :k] = pod_of[sites]
            gsite[r, d, :k] = sites
    return lidx, valid, w, pod, gsite, k_cap


def execute_sharded(job, bundle, scheduler, codec, rounds: int,
                    resume_round: Optional[int] = None) -> JobResult:
    """Cross-device scale: the stacked simulator with its per-site state
    sharded over a ``("site",)`` device mesh and only the *sampled* rows
    trained each round.

    The dense engines materialize every site every round — [S, …] params
    AND [S, …] batches AND an S-wide vmap — which caps S at what one
    device holds and trains.  Here the persistent per-site state (params
    + the stateful adamw moments, plus the int8 error-feedback residual)
    stays resident as ``shard_map``-partitioned ``[S, …]`` blocks, and a
    round touches exactly the ``participate = sampled ∩ available`` rows
    (``repro.core.sampling``): each device gathers its own participants
    into a static ``[k_cap, …]`` slab, trains them vmapped, folds Eq. 1
    partial sums (Hájek 1/π-scaled weights) through a per-pod
    segment-reduce + ``psum``, scatters the trained rows back and
    broadcasts the new global to the participants only — so a
    10,000-site job at 1% sampling costs ~100 sites of compute and one
    O(N) collective per round.

    Non-participants are frozen (neither train nor see the broadcast):
    exactly ``dropout_scenario="shutdown"`` semantics, hence the gate.
    Sampling schedules, weights and batches are pure functions of
    (seed, round) shared with every other engine, so a full-participation
    sharded run is the dense run (allclose; summation order differs
    across device blocks).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch.mesh import make_site_mesh

    if isinstance(scheduler, BufferedScheduler):
        raise ValueError("shard_sites=True runs synchronous rounds only; "
                         "buffered-async scheduling needs the dense engine")
    if job.strategy not in ("fedavg", "fedprox"):
        raise ValueError("shard_sites=True supports the centrally-"
                         "aggregated strategies (fedavg/fedprox), not "
                         f"{job.strategy!r}")
    if codec.name not in ("none", "int8"):
        raise ValueError("shard_sites=True supports compression 'none' or "
                         f"'int8', not {codec.name!r}")
    from repro.comms.compression import resolve_codec
    if resolve_codec(getattr(job, "down_compression", "none")).name != "none":
        raise ValueError("shard_sites=True broadcasts the global through "
                         "the mesh collective, not the download codec; run "
                         "down_compression jobs on the dense engines")
    if job.device_data:
        raise ValueError("shard_sites=True generates only the sampled "
                         "rows' batches host-side; device_data=True would "
                         "regenerate all S on device")
    if job.dp is not None:
        raise ValueError("shard_sites=True does not thread DP-SGD noise "
                         "keys yet; run dp jobs on the dense engines")
    if resume_round is not None:
        raise ValueError("shard_sites=True does not checkpoint its "
                         "sharded carry; resume dense jobs instead")
    thinned = job.sampled or job.max_dropout or job.pod_dropout
    if thinned and job.dropout_scenario != "shutdown":
        raise ValueError(
            "shard_sites=True freezes non-participants entirely (they "
            "neither train nor receive the broadcast), which is the "
            "'shutdown' scenario; run sampled/dropout sharded jobs with "
            "dropout_scenario='shutdown'")

    mesh = make_site_mesh()
    num_devices = int(mesh.devices.size)
    num_sites = job.task.sites
    s_loc = -(-num_sites // num_devices)
    s_pad = s_loc * num_devices

    participate, wscale = job.participation(rounds)
    case_w = np.asarray(job.federation().case_weights(), np.float32)
    topo = job.topo
    if topo.is_pods:
        topo.validate(num_sites)
        num_pods = topo.num_pods
        pod_of = np.asarray(topo.pod_of(num_sites), np.int32)
        intra, inter = topo.intra, topo.inter
    else:
        # the flat fold is the 1-pod special case of the segment-reduce
        num_pods, pod_of = 1, np.zeros(num_sites, np.int32)
        intra, inter = "fedavg", "fedavg"
    base_w = np.ones(num_sites, np.float32) if intra == "uniform" else case_w
    lidx_a, valid_a, w_a, pod_a, gsite_a, k_cap = _pack_participants(
        participate, base_w[None] * wscale, pod_of, s_loc, num_devices)

    quant = codec.name == "int8"
    prox = job.strategy == "fedprox"
    local_strategy = "fedprox-local" if prox else "individual"
    ctx = job.context(bundle, strategy=local_strategy)
    fl_round = F.build_fl_round(ctx)
    engine = get_engine()
    chunkw = int(getattr(codec, "chunk", 1024))
    align = 128 if (_accel() and quant) else 1
    error_feedback = bool(job.error_feedback)
    identity_k = np.arange(k_cap)
    no_recv_k = np.zeros(k_cap, bool)
    steps = job.local_steps

    one = bundle.init_fn(jax.random.PRNGKey(job.seed))
    opt_one = ctx.optimizer.init(one)
    # byte accounting up front: `one`'s buffers may be donated into the
    # carry below (device_put aliases an already-placed array)
    stacked_one = jax.tree.map(lambda x: np.asarray(x)[None], one)
    dense_nbytes = per_site_nbytes(stacked_one)
    row_shard = NamedSharding(mesh, PartitionSpec("site"))
    repl = NamedSharding(mesh, PartitionSpec())

    def bcast_rows(t):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (s_pad,) + x.shape), t)

    carry = dict(zip(
        ("params", "opt"),
        jax.jit(lambda p, o: (bcast_rows(p), bcast_rows(o)),
                out_shardings=(row_shard, row_shard))(one, opt_one)))
    carry["round"] = jax.device_put(jnp.zeros((), jnp.int32), repl)
    if prox:
        # dense FedProx anchors round 0 at the shared init (all rows equal)
        carry["anchor"] = jax.device_put(one, repl)
    if quant:
        # compressed-path convention: reference zero, so round 0's delta
        # IS the dense bootstrap upload
        carry["ref"] = jax.device_put(
            jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), one),
            repl)
        carry["ef"] = jax.jit(
            lambda: jax.tree.map(
                lambda x: jnp.zeros((s_pad,) + x.shape, jnp.float32), one),
            out_shardings=row_shard)()

    rows, rep = PartitionSpec("site"), PartitionSpec()
    carry_specs = {"params": rows, "opt": rows, "round": rep}
    if prox:
        carry_specs["anchor"] = rep
    if quant:
        carry_specs.update(ref=rep, ef=rows)
    xs_specs = {"batches": rows, "lidx": rows, "valid": rows, "w": rows,
                "pod": rows}

    def device_step(c, x):
        lidx, valid = x["lidx"][0], x["valid"][0]
        w, pod = x["w"][0], x["pod"][0]
        batches = jax.tree.map(lambda b: b[0], x["batches"])
        st = {"params": jax.tree.map(lambda a: a[lidx], c["params"]),
              "opt": jax.tree.map(lambda a: a[lidx], c["opt"]),
              "strategy": {"global": c["anchor"]} if prox else {},
              "round": c["round"]}
        st, metrics = fl_round(st, batches,
                               {"active": jnp.ones((k_cap,), bool),
                                "partner": identity_k,
                                "is_receiver": no_recv_k})
        new_p, new_o = st["params"], st["opt"]
        if quant:
            u = jax.tree.map(
                lambda p, g, e: p.astype(jnp.float32) - g[None] + e[lidx],
                new_p, c["ref"], c["ef"])
            vals = _qdq_tree(u, chunkw, align, codec.name)
        else:
            vals = new_p

        def mask_pad(v):
            m = valid.reshape((-1,) + (1,) * (v.ndim - 1))
            return jnp.where(m, v.astype(jnp.float32), 0.0)

        # padded slots gather a clipped throwaway row — zero them so the
        # fold (weight 0) can never be polluted by a non-finite garbage
        # value (0 · nan = nan)
        flat, layout = engine.flatten(jax.tree.map(mask_pad, vals))
        wk = w * valid.astype(jnp.float32)                      # [K]
        onehot = (pod[None, :] == jnp.arange(num_pods)[:, None]
                  ).astype(jnp.float32)                         # [P, K]
        wp = onehot * wk[None, :]
        # per-device partial pod sums; ONE O(P·N) psum crosses the mesh
        pod_num = jax.lax.psum(jnp.einsum("pk,kn->pn", wp, flat), "site")
        pod_tot = jax.lax.psum(jnp.sum(wp, axis=1), "site")
        pod_mean = pod_num / (pod_tot[:, None] + 1e-12)
        pod_w = ((pod_tot > 0).astype(jnp.float32) if inter == "uniform"
                 else pod_tot)
        gflat = jnp.einsum("p,pn->n", pod_w / (jnp.sum(pod_w) + 1e-12),
                           pod_mean)
        gtree = engine.unflatten(gflat, layout)
        c2 = {"round": c["round"] + 1}
        if quant:
            gbc = jax.tree.map(jnp.add, c["ref"], gtree)
            c2["ref"] = gbc
            if error_feedback:
                c2["ef"] = jax.tree.map(
                    lambda e, p_, d_: e.at[lidx].set(jnp.subtract(p_, d_),
                                                     mode="drop"),
                    c["ef"], u, vals)
            else:
                c2["ef"] = c["ef"]
        else:
            gbc = gtree
        if prox:
            c2["anchor"] = gbc
        c2["params"] = jax.tree.map(
            lambda a, g: a.at[lidx].set(
                jnp.broadcast_to(g[None], (k_cap,) + g.shape).astype(a.dtype),
                mode="drop"),
            c["params"], gbc)
        c2["opt"] = jax.tree.map(
            lambda a, v: a.at[lidx].set(v, mode="drop"), c["opt"], new_o)
        losses = jnp.full((s_loc,), jnp.nan, jnp.float32).at[lidx].set(
            metrics["loss"].astype(jnp.float32), mode="drop")
        return c2, losses

    step = shard_map(device_step, mesh, in_specs=(carry_specs, xs_specs),
                     out_specs=(carry_specs, rows), check_rep=False)

    w_all = np.zeros(s_pad, np.float32)
    w_all[:num_sites] = case_w / case_w.sum()
    w_all_dev = jax.device_put(jnp.asarray(w_all), row_shard)

    def _global_mean(params, w):
        flat, layout = engine.flatten(params)
        g = jax.lax.psum(jnp.einsum("s,sn->n", w, flat), "site")
        return engine.unflatten(g, layout)

    global_mean = jax.jit(shard_map(_global_mean, mesh,
                                    in_specs=(rows, rows), out_specs=rep,
                                    check_rep=False))

    def site_rows(site: int, r: int):
        ks = [bundle.sample(site, r * steps + k) for k in range(steps)]
        return {key: np.stack([x[key] for x in ks]) for key in ks[0]}

    def round_xs(r: int):
        cache = {int(s): site_rows(int(s), r) for s in np.unique(gsite_a[r])}
        keys = next(iter(cache.values())).keys()
        batches = {key: np.stack([np.stack(
            [cache[int(gsite_a[r, d, i])][key] for i in range(k_cap)])
            for d in range(num_devices)]) for key in keys}
        xs = {"batches": batches, "lidx": lidx_a[r], "valid": valid_a[r],
              "w": w_a[r], "pod": pod_a[r]}
        return jax.device_put(xs, row_shard)

    recorder = job.recorder(rounds, num_sites)
    xs0 = round_xs(0)
    t0 = time.perf_counter()
    compiled = jax.jit(step, donate_argnums=0).lower(carry, xs0).compile()
    compile_s = time.perf_counter() - t0

    for r in range(rounds):
        xs = xs0 if r == 0 else round_xs(r)
        t0 = time.perf_counter()
        carry, losses_dev = compiled(carry, xs)
        jax.block_until_ready(losses_dev)
        step_s = time.perf_counter() - t0

        def global_fn(c=carry):
            return (c["ref"] if quant
                    else global_mean(c["params"], w_all_dev))

        on_grid = (recorder.store is not None
                   and r % job.ckpt_every == 0) or r == rounds - 1
        recorder.record(r, np.asarray(losses_dev)[:num_sites],
                        participate[r],
                        global_fn=global_fn if on_grid else None,
                        extra={"step_s": step_s, "wall_s": step_s,
                               "participants": int(participate[r].sum()),
                               "k_cap": k_cap})

    uploads = int(participate.sum())
    if quant:
        enc = _encoded_nbytes(stacked_one, chunkw, align)
        comm = {"upload_bytes": uploads * enc,
                "upload_raw_bytes": uploads * dense_nbytes,
                "download_bytes": uploads * dense_nbytes,
                "total_bytes": uploads * (enc + dense_nbytes),
                "upload_count": uploads, "download_count": uploads,
                "compression": codec.name, "down_compression": "none",
                "simulated": True}
        if topo.is_pods:
            from repro.core.topology import simulated_pods_comm
            comm.update(simulated_pods_comm(topo, participate, dense_nbytes,
                                            intra_upload_bytes=uploads * enc,
                                            compression=codec.name))
    elif topo.is_pods:
        from repro.core.topology import simulated_pods_comm
        comm = simulated_pods_comm(topo, participate, dense_nbytes)
    else:
        comm = {"upload_bytes": uploads * dense_nbytes,
                "download_bytes": uploads * dense_nbytes,
                "total_bytes": 2 * uploads * dense_nbytes,
                "upload_count": uploads, "download_count": uploads,
                "compression": "none", "down_compression": "none",
                "simulated": True}
    comm.update({"sharded": True, "devices": num_devices, "k_cap": k_cap})

    global_params = (carry["ref"] if quant
                     else global_mean(carry["params"], w_all_dev))
    state = {"params": jax.tree.map(lambda x: x[:num_sites], carry["params"]),
             "opt": jax.tree.map(lambda x: x[:num_sites], carry["opt"]),
             "strategy": {"global": carry["anchor"]} if prox else {},
             "round": carry["round"]}
    return recorder.result(global_params, transport="stacked",
                           scheduler=scheduler.name, state=state, comm=comm,
                           compile_s=compile_s,
                           privacy=job.privacy_report(rounds))


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def execute_stacked(job, bundle, scheduler, codec, rounds: int,
                    resume_round: Optional[int] = None,
                    down_codec=None) -> Optional[JobResult]:
    """Run ``job`` on the compiled scan engine, or return ``None`` when
    the engine cannot replicate the job's semantics (the caller falls
    back to the retired per-round loop):

      * ``topk-sparse`` uploads (data-dependent index payloads — the
        fixed-k ``topk-fixed`` variant compiles),
      * buffered runs whose ``max_staleness`` reaches past the
        ``keep_globals`` decode-reference ring,
      * ``topk-sparse`` downloads under bidirectional compression.

    ``device_data=True`` is an explicit request for on-device batch
    generation (token tasks AND the jnp dose/seg generators) and raises
    when the combination doesn't support it.
    """
    buffered = isinstance(scheduler, BufferedScheduler)
    down = down_codec is not None and down_codec.name != "none"
    if job.device_data:
        if (buffered or codec.name != "none" or down
                or job.strategy == "pooled"
                or getattr(bundle, "traced_stacked", None) is None):
            raise ValueError(
                "device_data=True (on-device batch generation) currently "
                "supports sync uncompressed jobs whose task has a traced "
                "generator (tokens, and dose/seg without site_pools); use "
                "host batches for buffered scheduling or compressed "
                "uploads/downloads")
        if job.pod_dropout:
            raise ValueError(
                "device_data=True runs the Algorithm-2 chain on device, "
                "which covers the site tier only; pod_dropout needs the "
                "host-precomputed schedule (device_data=False)")
    if codec.name not in ("none", "int8", "fp8", "topk-fixed"):
        return None
    if down and down_codec.name not in ("int8", "fp8", "topk-fixed"):
        return None
    if buffered:
        if compress_past_ring(scheduler, codec) or codec.name == "topk-fixed":
            return None        # flat-chunk qdq only; top-k buffers host-side
        return _run_buffered_scan(job, bundle, scheduler, rounds, codec,
                                  resume_round)
    if codec.name != "none" or down:
        return _run_compressed_scan(job, bundle, scheduler, rounds, codec,
                                    resume_round,
                                    down_codec=down_codec if down else None)
    return _run_sync_scan(job, bundle, scheduler, rounds, resume_round)


def compress_past_ring(scheduler: BufferedScheduler, codec) -> bool:
    """True when compressed-buffered staleness could outlive the decode
    ring — the one buffered configuration the host loop still owns."""
    return (codec.name != "none"
            and scheduler.max_staleness >= KEEP_GLOBALS_DEFAULT)
