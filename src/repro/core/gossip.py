"""Gossip pairing — the decentralized coordinator's role assignment.

Each FL round the coordination server (Fig 4 / Algorithm 1) selects
Sender/Receiver pairs among *active* sites and broadcasts the roles.
Here the host computes the pairing (numpy RNG, mirroring the
coordinator process) and the jitted exchange consumes it as three
arrays:

  * ``partner[i]``   — index whose model site ``i`` pulls (identity when
                       not a receiver, so the gather is always a valid
                       permutation → lowers to collective-permute)
  * ``is_receiver``  — bool mask of receiver sites
  * ``is_sender``    — bool mask of sender sites
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def pair_sites(active: np.ndarray, rng: np.random.Generator
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Random sender→receiver pairing among active sites.

    Active sites are shuffled and split into (sender, receiver) pairs;
    an odd site out participates as neither (it only does local training
    this round, as in the paper's implementation).
    """
    n = active.shape[0]
    partner = np.arange(n)
    is_recv = np.zeros(n, bool)
    is_send = np.zeros(n, bool)
    idx = np.flatnonzero(active)
    rng.shuffle(idx)
    for a, b in zip(idx[0::2], idx[1::2]):
        # a sends to b: receiver b pulls a's model
        partner[b] = a
        is_send[a] = True
        is_recv[b] = True
    return partner, is_recv, is_send


def pair_sites_traced(key, active):
    """Traced counterpart of :func:`pair_sites` (same pairing law, jax
    PRNG stream): shuffle the active sites, pair them off consecutively,
    odd one out sits the exchange out.  Runs inside the compiled round
    engine's scan, so gossip rounds need no host coordinator re-entry.
    Returns ``(partner, is_receiver, is_sender)`` as jnp arrays.
    """
    import jax
    import jax.numpy as jnp
    n = active.shape[0]
    # actives first in random order: inactive sites get +2 on U(0,1) keys
    noise = jax.random.uniform(key, (n,))
    order = jnp.argsort(jnp.where(active, noise, noise + 2.0))
    n_act = jnp.sum(active)
    pairs = n // 2                   # an odd site out joins neither role
    senders = order[0:2 * pairs:2]
    receivers = order[1::2]
    # pair j = (order[2j] → order[2j+1]) is real iff both land in actives
    valid = (2 * jnp.arange(pairs) + 1) < n_act
    safe_recv = jnp.where(valid, receivers, n)        # n = OOB → dropped
    safe_send = jnp.where(valid, senders, n)
    partner = jnp.arange(n).at[safe_recv].set(senders, mode="drop")
    is_recv = jnp.zeros(n, bool).at[safe_recv].set(True, mode="drop")
    is_send = jnp.zeros(n, bool).at[safe_send].set(True, mode="drop")
    return partner, is_recv, is_send


def ring_pairs(active: np.ndarray, round_index: int
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic ring gossip (every active site both sends and
    receives from its clockwise active neighbour) — the lower-variance
    alternative schedule; used by the communication benchmarks."""
    n = active.shape[0]
    partner = np.arange(n)
    idx = np.flatnonzero(active)
    k = len(idx)
    is_recv = np.zeros(n, bool)
    is_send = np.zeros(n, bool)
    if k >= 2:
        shift = 1 + (round_index % max(k - 1, 1))
        for j, i in enumerate(idx):
            partner[i] = idx[(j + shift) % k]
            is_recv[i] = True
            is_send[i] = True
    return partner, is_recv, is_send
