"""Pallas TPU kernel for federated weight aggregation (paper Eq. 1).

The aggregation server's hot loop: ``global = Σ_s (m_s/m) · w_s`` over
the stacked site axis.  Purely memory-bound (one pass over S×N param
bytes), so the kernel's job is to stream HBM at full bandwidth with a
single fused multiply-accumulate per element — no intermediate global
buffers per site (which a naive ``sum`` of scaled pytrees would
allocate).

  grid = (ceil(N / block_n)); each cell loads the [S, block_n] slab into
  VMEM, reduces against the [S] weight vector on the VPU, and writes
  [block_n] once.

Arbitrary ``N`` is supported: the buffer is zero-padded up to a block
multiple (zero columns contribute nothing and are sliced off the
output).  ``interpret`` defaults to compiled on TPU/GPU and to the
Pallas interpreter elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128   # TPU lane width — pad so compiled blocks tile cleanly


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _fedagg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [S, block_n]
    w = w_ref[...].astype(jnp.float32)            # [S]
    o_ref[...] = (w @ x).astype(o_ref.dtype)


def _fedagg_dequant_kernel(q_ref, s_ref, u_ref, w_ref, g_ref, r_ref):
    q = q_ref[...].astype(jnp.float32)            # [S, block_c, chunk]
    deq = q * s_ref[...][..., None]               # scales [S, block_c]
    r_ref[...] = u_ref[...] - deq                 # error-feedback residual
    w = w_ref[...].astype(jnp.float32)            # [S]
    g_ref[...] = jnp.sum(deq * w[:, None, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def fedagg_dequant(q, scales, u, weights, *, block_c: int = 32,
                   interpret: Optional[bool] = None):
    """Fused dequantize + weighted fold for quantized site uploads.

    The compressed round engine's server step: each site's int8 delta
    (``q`` [S, C, chunk] with per-chunk fp32 ``scales`` [S, C]) is
    dequantized and folded into the Eq. 1 weighted sum in ONE pass —
    the dense fp32 per-site models never exist in HBM.  Because error
    feedback needs exactly ``u − deQ(Q(u))``, the kernel also emits the
    next residual from the same VMEM-resident dequantized block:

      returns ``(global [C, chunk] = Σ_s weights_s · deq_s,``
      ``residual [S, C, chunk] = u − deq)``.

    ``u`` is the pre-quantization input (delta + carried residual).  One
    [S, block_c, chunk] slab per grid cell; int8 loads keep the HBM
    traffic at ~1/4 of an fp32 fold.
    """
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    s, c, chunk = q.shape
    if c == 0:
        return (jnp.zeros((0, chunk), jnp.float32),
                jnp.zeros((s, 0, chunk), jnp.float32))
    block_c = min(block_c, c)
    padded = _round_up(c, block_c)
    if padded != c:
        q = jnp.pad(q, ((0, 0), (0, padded - c), (0, 0)))
        scales = jnp.pad(scales, ((0, 0), (0, padded - c)))
        u = jnp.pad(u, ((0, 0), (0, padded - c), (0, 0)))
    g, r = pl.pallas_call(
        _fedagg_dequant_kernel,
        grid=(padded // block_c,),
        in_specs=[
            pl.BlockSpec((s, block_c, chunk), lambda i: (0, i, 0)),
            pl.BlockSpec((s, block_c), lambda i: (0, i)),
            pl.BlockSpec((s, block_c, chunk), lambda i: (0, i, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_c, chunk), lambda i: (i, 0)),
            pl.BlockSpec((s, block_c, chunk), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, chunk), jnp.float32),
            jax.ShapeDtypeStruct((s, padded, chunk), jnp.float32),
        ],
        interpret=interpret,
    )(q, scales, u, weights)
    return (g[:c], r[:, :c]) if padded != c else (g, r)


def _dequant_install_kernel(q_ref, s_ref, b_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # [S, block_c, chunk]
    deq = q * s_ref[...][..., None]               # scales [S, block_c]
    o_ref[...] = b_ref[...] + deq                 # install = held + deQ(delta)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def dequant_install(q, scales, base, *, block_c: int = 32,
                    interpret: Optional[bool] = None):
    """Fused dequantize + per-site install for quantized downloads.

    The downlink mirror of :func:`fedagg_dequant`: each site's int8
    broadcast delta (``q`` [S, C, chunk] with per-chunk fp32 ``scales``
    [S, C]) is dequantized and added onto that site's held reference
    ``base`` [S, C, chunk] in ONE pass — the dense fp32 per-site deltas
    never exist in HBM.  Returns the installed models [S, C, chunk];
    installing this result back as the next round's ``base`` is exactly
    the server-side error-feedback recurrence ``held ← held + deQ(Q(g −
    held))``, so downlink quantization errors telescope instead of
    accumulating.
    """
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    s, c, chunk = q.shape
    if c == 0:
        return jnp.zeros((s, 0, chunk), jnp.float32)
    block_c = min(block_c, c)
    padded = _round_up(c, block_c)
    if padded != c:
        q = jnp.pad(q, ((0, 0), (0, padded - c), (0, 0)))
        scales = jnp.pad(scales, ((0, 0), (0, padded - c)))
        base = jnp.pad(base, ((0, 0), (0, padded - c), (0, 0)))
    out = pl.pallas_call(
        _dequant_install_kernel,
        grid=(padded // block_c,),
        in_specs=[
            pl.BlockSpec((s, block_c, chunk), lambda i: (0, i, 0)),
            pl.BlockSpec((s, block_c), lambda i: (0, i)),
            pl.BlockSpec((s, block_c, chunk), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((s, block_c, chunk), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, padded, chunk), jnp.float32),
        interpret=interpret,
    )(q, scales, base)
    return out[:, :c] if padded != c else out


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fedagg(stacked, weights, *, block_n: int = 65536,
           interpret: Optional[bool] = None):
    """stacked: [S, N] (flattened params); weights: [S] -> [N]."""
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    s, n = stacked.shape
    block_n = min(block_n, _round_up(n, _LANE))
    padded = _round_up(n, block_n)
    if padded != n:
        stacked = jnp.pad(stacked, ((0, 0), (0, padded - n)))
    out = pl.pallas_call(
        _fedagg_kernel,
        grid=(padded // block_n,),
        in_specs=[
            pl.BlockSpec((s, block_n), lambda i: (0, i)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
    return out[:n] if padded != n else out
