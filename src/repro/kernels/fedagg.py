"""Pallas TPU kernel for federated weight aggregation (paper Eq. 1).

The aggregation server's hot loop: ``global = Σ_s (m_s/m) · w_s`` over
the stacked site axis.  Purely memory-bound (one pass over S×N param
bytes), so the kernel's job is to stream HBM at full bandwidth with a
single fused multiply-accumulate per element — no intermediate global
buffers per site (which a naive ``sum`` of scaled pytrees would
allocate).

  grid = (ceil(N / block_n)); each cell loads the [S, block_n] slab into
  VMEM, reduces against the [S] weight vector on the VPU, and writes
  [block_n] once.

Arbitrary ``N`` is supported: the buffer is zero-padded up to a block
multiple (zero columns contribute nothing and are sliced off the
output).  ``interpret`` defaults to compiled on TPU/GPU and to the
Pallas interpreter elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128   # TPU lane width — pad so compiled blocks tile cleanly


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _fedagg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [S, block_n]
    w = w_ref[...].astype(jnp.float32)            # [S]
    o_ref[...] = (w @ x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fedagg(stacked, weights, *, block_n: int = 65536,
           interpret: Optional[bool] = None):
    """stacked: [S, N] (flattened params); weights: [S] -> [N]."""
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    s, n = stacked.shape
    block_n = min(block_n, _round_up(n, _LANE))
    padded = _round_up(n, block_n)
    if padded != n:
        stacked = jnp.pad(stacked, ((0, 0), (0, padded - n)))
    out = pl.pallas_call(
        _fedagg_kernel,
        grid=(padded // block_n,),
        in_specs=[
            pl.BlockSpec((s, block_n), lambda i: (0, i)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
    return out[:n] if padded != n else out
