"""Pallas TPU kernel for federated weight aggregation (paper Eq. 1).

The aggregation server's hot loop: ``global = Σ_s (m_s/m) · w_s`` over
the stacked site axis.  Purely memory-bound (one pass over S×N param
bytes), so the kernel's job is to stream HBM at full bandwidth with a
single fused multiply-accumulate per element — no intermediate global
buffers per site (which a naive ``sum`` of scaled pytrees would
allocate).

  grid = (N / block_n); each cell loads the [S, block_n] slab into VMEM,
  reduces against the [S] weight vector on the VPU, and writes
  [block_n] once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedagg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # [S, block_n]
    w = w_ref[...].astype(jnp.float32)            # [S]
    o_ref[...] = (w @ x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fedagg(stacked, weights, *, block_n: int = 65536, interpret: bool = True):
    """stacked: [S, N] (flattened params); weights: [S] -> [N]."""
    s, n = stacked.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        _fedagg_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((s, block_n), lambda i: (0, i)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
