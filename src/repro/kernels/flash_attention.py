"""Pallas TPU flash attention (causal GQA, optional sliding window).

Block-tiled online-softmax attention targeting the MXU:

  * grid = (batch, q_heads, Lq/block_q, Lk/block_k); the kv dimension is
    the innermost ("arbitrary") axis so the fp32 accumulators live in
    VMEM scratch across kv steps and the HBM traffic is one pass over
    Q/K/V plus one write of O — the flash property.
  * BlockSpecs tile Q[block_q, d] / K,V[block_k, d] into VMEM; block
    sizes default to 128 (MXU-aligned: multiples of the 128-lane register
    tiling and the 128x128 systolic array).
  * GQA: the K/V index_map folds q-head -> kv-head (h // group).
  * causal + sliding-window masks are applied with position iotas; blocks
    entirely outside the window contribute zero (masked) — a future
    refinement can skip them via a custom grid.

Validated on CPU in interpret mode against ``ref.py`` (tests/test_kernels.py
sweeps shapes/dtypes); the TPU path is the same kernel with
``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref,
                  *, scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int], kv_seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
    s = q @ k.T                                          # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (kv_seq_len - pl.num_programs(2) * block_q)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: [B, Hq, Lq, D]; k/v: [B, Hkv, Lk, D] -> [B, Hq, Lq, D].

    Queries occupy the LAST Lq positions of the kv sequence (prefill /
    training: Lq == Lk).
    """
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0
    scale = d ** -0.5

    grid = (b, hq, lq // block_q, lk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, kv_seq_len=lk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, qi, ki: (b_, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, qi, ki: (b_, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),    # acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(q, k, v)
