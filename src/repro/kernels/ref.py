"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q: [B, Hq, Lq, D]; k/v: [B, Hkv, Lk, D]. fp32 softmax, GQA."""
    b, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * (d ** -0.5)
    q_pos = jnp.arange(lq) + (lk - lq)
    k_pos = jnp.arange(lk)
    ok = jnp.ones((lq, lk), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, state=None):
    """Exact WKV recurrence. r/k/v/w: [B, H, L, D]; u: [H, D].

    out_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = w_t ⊙ S_{t-1} + k_t ⊗ v_t
    (decay applies along the k-index of S).
    """
    b, h, l, d = r.shape
    if state is None:
        state = jnp.zeros((b, h, d, d), jnp.float32)

    def step(s, xs):
        r_t, k_t, v_t, w_t = [x.astype(jnp.float32) for x in xs]
        kv = k_t[..., :, None] * v_t[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), state


def fedagg_ref(stacked, weights):
    """Weighted site aggregation: out = Σ_s w_s · x_s.  stacked: [S, N]."""
    return jnp.tensordot(weights.astype(jnp.float32),
                         stacked.astype(jnp.float32), axes=1).astype(stacked.dtype)


def mamba_scan_ref(dt, b_mat, c_mat, x, log_a):
    """Exact selective scan oracle. dt/x: [B, L, di]; b/c: [B, L, ds]."""
    a = -jnp.exp(log_a.astype(jnp.float32))

    def step(s, inp):
        dt_t, b_t, c_t, x_t = [i.astype(jnp.float32) for i in inp]
        dec = jnp.exp(dt_t[..., None] * a)
        s = dec * s + (dt_t * x_t)[..., None] * b_t[..., None, :]
        y = jnp.einsum("bis,bs->bi", s, c_t)
        return s, y

    bsz, l, di = dt.shape
    s0 = jnp.zeros((bsz, di, log_a.shape[-1]), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (dt, b_mat, c_mat, x))
    s, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(dt.dtype), s
