"""Pallas TPU kernel for the RWKV-6 WKV recurrence.

TPU adaptation of the (GPU, warp-per-head) CUDA wkv6 kernel: instead of
per-warp shuffles, each grid cell owns one (batch, head) recurrence and
keeps the [D, D] state resident in VMEM scratch across time-chunk grid
steps — HBM traffic is one pass over r/k/v/w plus the output, never the
per-step state (which is what makes the jnp ``lax.scan`` version
memory-bound: it round-trips the state every token).

  grid = (B, H, L/chunk)   — time chunks are the innermost "arbitrary"
                             axis; state scratch persists across them
  blocks: r/k/v/w [1, 1, chunk, D] in VMEM; out the same; u [1, D].

The time loop inside a chunk is a ``fori_loop`` over VMEM-resident
slices (D=64: one MXU-aligned [64,64] outer product per step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)                     # [D]

    def step(t, state):
        r_t = r_ref[0, 0, t].astype(jnp.float32)         # [D]
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                 # [D, D]
        out = ((state + u[:, None] * kv) * r_t[:, None]).sum(axis=0)
        o_ref[0, 0, t] = out.astype(o_ref.dtype)
        return w_t[:, None] * state + kv

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = 128, interpret: bool = True):
    """r/k/v/w: [B, H, L, D]; u: [H, D] -> out [B, H, L, D].

    Returns the WKV outputs (final state write-back variant lives in
    ``ops.rwkv6_scan_with_state`` for decode hand-off).
    """
    b, h, l, d = r.shape
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    grid = (b, h, l // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, d), lambda b_, h_, c: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, l, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(r, k, v, w, u)
