"""Pallas TPU kernels for Byzantine-robust coordinate-wise aggregation.

Robust combine rules replace the Eq. 1 weighted mean when up to ``f`` of
the stacked site rows may be adversarial (sign-flipped, rescaled, or
noised uploads).  The coordinate-wise rules are rank statistics over the
site axis, so the kernel's shape is the same streaming pass as
``fedagg``: one [S, block_n] slab per grid cell, a per-coordinate sort
over S (S is small — the site axis), and one [block_n] write.

Masked-row awareness: rows with ``active == 0`` (Algorithm-2 dropout,
client sampling) are pushed to +inf before the sort, so they fall past
every active rank; the trim depth and the divisor use the *traced*
active count, which is what lets the rule compile into the multi-round
``lax.scan`` where the active mask changes per round.

  trimmed mean  f  — drop the f smallest and f largest active values per
                     coordinate (f clamps to ⌊(k−1)/2⌋ for k active
                     rows, so the keep set is never empty), mean the
                     rest.  UNWEIGHTED over the keep set: rank rules and
                     case weights don't compose (a 100×-weighted
                     adversary would defeat the trim).
  median           — the trimmed mean at maximal trim depth: for k odd
                     the middle rank, for k even the mean of the two
                     middle ranks — exactly ``trimmed_mean(f=S)``.

``_trim_block`` is the single op sequence both the kernel body and the
jnp twin (``trimmed_mean_ref``) execute, so kernel-vs-twin parity is
bit-exact by construction (tested in ``tests/test_kernels.py``).
``interpret`` defaults to compiled on TPU/GPU and to the Pallas
interpreter elsewhere, like every kernel in this package.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128   # TPU lane width — pad so compiled blocks tile cleanly


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _trim_block(x, a, f: int):
    """Coordinate-wise trimmed mean of the active rows of one block.

    x: [S, n] values; a: [S] active mask (float, >0.5 = active); f: trim
    depth.  Inactive rows sort to +inf (past every active rank); the
    where-before-sum keeps ``inf · 0`` out of the fold.
    """
    x = x.astype(jnp.float32)
    act = a > 0.5
    k = jnp.sum(act.astype(jnp.int32))
    xs = jnp.sort(jnp.where(act[:, None], x, jnp.inf), axis=0)
    r = jax.lax.broadcasted_iota(jnp.int32, xs.shape, 0)
    fe = jnp.minimum(jnp.int32(f), jnp.maximum(k - 1, 0) // 2)
    keep = (r >= fe) & (r < k - fe)
    total = jnp.sum(jnp.where(keep, xs, 0.0), axis=0)
    return total / jnp.maximum(k - 2 * fe, 1).astype(jnp.float32)


def _trimmed_kernel(f, x_ref, a_ref, o_ref):
    o_ref[...] = _trim_block(x_ref[...], a_ref[...], f).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("f", "block_n", "interpret"))
def trimmed_mean(stacked, active, f: int, *, block_n: int = 65536,
                 interpret: Optional[bool] = None):
    """Coordinate-wise trimmed mean over the active rows of [S, N].

    stacked: [S, N] flattened params; active: [S] mask; f: rows trimmed
    from each end of the per-coordinate order.  Returns [N] fp32.
    """
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    s, n = stacked.shape
    active = jnp.asarray(active, jnp.float32)
    block_n = min(block_n, _round_up(n, _LANE))
    padded = _round_up(n, block_n)
    if padded != n:
        stacked = jnp.pad(stacked, ((0, 0), (0, padded - n)))
    out = pl.pallas_call(
        functools.partial(_trimmed_kernel, f),
        grid=(padded // block_n,),
        in_specs=[
            pl.BlockSpec((s, block_n), lambda i: (0, i)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(stacked, active)
    return out[:n] if padded != n else out


def masked_median(stacked, active, *, block_n: int = 65536,
                  interpret: Optional[bool] = None):
    """Coordinate-wise median over the active rows of [S, N] — the
    trimmed mean at maximal trim depth (f = S clamps to ⌊(k−1)/2⌋)."""
    return trimmed_mean(stacked, active, int(stacked.shape[0]),
                        block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("f",))
def trimmed_mean_ref(stacked, active, f: int):
    """jnp twin of :func:`trimmed_mean` — the identical op sequence on
    the whole [S, N] buffer (the CPU engine path; bit-exact vs the
    kernel because both run :func:`_trim_block` elementwise over N)."""
    return _trim_block(jnp.asarray(stacked),
                       jnp.asarray(active, jnp.float32), f)


def masked_median_ref(stacked, active):
    """jnp twin of :func:`masked_median`."""
    return trimmed_mean_ref(stacked, active, int(jnp.shape(stacked)[0]))
