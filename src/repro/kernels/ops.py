"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode
(the kernel body runs under the Pallas interpreter — bit-faithful to the
TPU program structure); on a real TPU pass ``interpret=False`` (the
default flips on backend detection).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fedagg import fedagg as _fedagg
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv6


def _default_interpret() -> bool:
    return jax.default_backend() not in ("tpu", "gpu")


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """Blocked causal GQA attention. q: [B,Hq,L,D], k/v: [B,Hkv,L,D]."""
    interp = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interp)


def rwkv6_scan(r, k, v, w, u, *, chunk: int = 128,
               interpret: Optional[bool] = None):
    """RWKV-6 WKV recurrence with VMEM-resident state."""
    interp = _default_interpret() if interpret is None else interpret
    return _rwkv6(r, k, v, w, u, chunk=chunk, interpret=interp)


def fedagg(stacked_params, weights, *, block_n: int = 65536,
           interpret: Optional[bool] = None):
    """Streaming FedAvg aggregation over a [S, N] stacked param matrix."""
    interp = _default_interpret() if interpret is None else interpret
    return _fedagg(stacked_params, weights, block_n=block_n, interpret=interp)


def trimmed_mean(stacked_params, active, f: int, *, block_n: int = 65536,
                 interpret: Optional[bool] = None):
    """Byzantine-robust coordinate-wise trimmed mean over the active
    rows of a [S, N] stacked param matrix (see kernels/robust.py)."""
    from repro.kernels.robust import trimmed_mean as _trimmed
    interp = _default_interpret() if interpret is None else interpret
    return _trimmed(stacked_params, active, f, block_n=block_n,
                    interpret=interp)


def masked_median(stacked_params, active, *, block_n: int = 65536,
                  interpret: Optional[bool] = None):
    """Coordinate-wise median over the active rows of [S, N] — the
    trimmed mean at maximal trim depth."""
    from repro.kernels.robust import masked_median as _median
    interp = _default_interpret() if interpret is None else interpret
    return _median(stacked_params, active, block_n=block_n, interpret=interp)


_PYTREE_ENGINES = {}


def fedagg_pytree(stacked_tree, weights, *, interpret: Optional[bool] = None):
    """Eq. 1 over a site-stacked pytree: flatten → one streaming kernel pass
    → unflatten.  Delegates to the AggregationEngine (forced onto the
    Pallas path), which pads the flat buffer to the kernel's block
    multiple and caches the ravel layout."""
    from repro.core.agg_engine import AggregationEngine
    eng = _PYTREE_ENGINES.get(interpret)
    if eng is None:
        eng = _PYTREE_ENGINES.setdefault(
            interpret, AggregationEngine(use_pallas=True, interpret=interpret))
    return eng.global_mean(stacked_tree, weights)


def fedagg_dequant(q, scales, u, weights, *, block_c: int = 32,
                   interpret: Optional[bool] = None):
    """Fused dequantize + Eq. 1 weighted fold over int8 site deltas
    ([S, C, chunk] values + [S, C] scales), also emitting the next
    error-feedback residual ``u − deq`` — the compressed round engine's
    one-pass server step (see ``repro.core.round_engine``)."""
    from repro.kernels.fedagg import fedagg_dequant as _fused
    interp = _default_interpret() if interpret is None else interpret
    return _fused(q, scales, u, weights, block_c=block_c, interpret=interp)


def dequant_install(q, scales, base, *, block_c: int = 32,
                    interpret: Optional[bool] = None):
    """Fused dequantize + install for quantized broadcast deltas
    ([S, C, chunk] int8 values + [S, C] scales + [S, C, chunk] held
    references) → the per-site installed models ``base + deQ(q)`` — the
    downlink mirror of :func:`fedagg_dequant` (see
    ``repro.core.round_engine``'s bidirectional compressed scan)."""
    from repro.kernels.fedagg import dequant_install as _install
    interp = _default_interpret() if interpret is None else interpret
    return _install(q, scales, base, block_c=block_c, interpret=interp)


def quantize_int8(x2d, *, block_c: int = 256, interpret: Optional[bool] = None):
    """Per-chunk int8 quantization: [C, chunk] fp32 → (int8 [C, chunk],
    fp32 scales [C]).  The upload-compression hot path (see
    ``repro.comms.compression``)."""
    from repro.kernels.quantize import quantize_int8 as _quant
    interp = _default_interpret() if interpret is None else interpret
    return _quant(x2d, block_c=block_c, interpret=interp)


def dequantize_int8(values, scales, *, block_c: int = 256,
                    interpret: Optional[bool] = None):
    """Inverse of :func:`quantize_int8`: int8 values × per-chunk scales."""
    from repro.kernels.quantize import dequantize_int8 as _dequant
    interp = _default_interpret() if interpret is None else interpret
    return _dequant(values, scales, block_c=block_c, interpret=interp)


def mamba_scan(dt, b_mat, c_mat, x, log_a, *, chunk: int = 128,
               block_di: int = 512, interpret: Optional[bool] = None):
    """Mamba selective scan with VMEM-resident state (see mamba_scan.py)."""
    from repro.kernels.mamba_scan import mamba_scan as _mamba
    interp = _default_interpret() if interpret is None else interpret
    return _mamba(dt, b_mat, c_mat, x, log_a, chunk=chunk,
                  block_di=block_di, interpret=interp)
