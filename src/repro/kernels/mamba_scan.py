"""Pallas TPU kernel for the Mamba-1 selective scan (beyond-paper).

The jnp nested-scan path round-trips the [d_inner, d_state] state through
HBM every token — the §Roofline memory term for jamba train_4k is
dominated by exactly that traffic.  This kernel is the TPU analogue of
the original CUDA selective-scan: the time loop runs on-chip with the
state resident in VMEM scratch; HBM sees one pass over (dt, B, C, x) and
one write of y.  Discretization (exp(dt·A), dt·x·B) happens in-register.

  grid = (B, d_inner/block_di, L/chunk)  — time chunks innermost
  ("arbitrary") so the state scratch persists across them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(dt_ref, b_ref, c_ref, x_ref, log_a_ref, o_ref, state_ref,
                  *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = -jnp.exp(log_a_ref[...].astype(jnp.float32))          # [di_blk, ds]

    def step(t, state):
        dt_t = dt_ref[0, t].astype(jnp.float32)               # [di_blk]
        x_t = x_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)                 # [ds]
        c_t = c_ref[0, t].astype(jnp.float32)
        dec = jnp.exp(dt_t[:, None] * a)                      # [di_blk, ds]
        state = dec * state + (dt_t * x_t)[:, None] * b_t[None, :]
        o_ref[0, t] = (state @ c_t).astype(o_ref.dtype)       # [di_blk]
        return state

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "block_di", "interpret"))
def mamba_scan(dt, b_mat, c_mat, x, log_a, *, chunk: int = 128,
               block_di: int = 512, interpret: bool = True):
    """dt/x: [B, L, d_inner]; b_mat/c_mat: [B, L, d_state];
    log_a: [d_inner, d_state] -> y [B, L, d_inner]."""
    bsz, l, di = dt.shape
    ds = b_mat.shape[-1]
    chunk = min(chunk, l)
    block_di = min(block_di, di)
    assert l % chunk == 0 and di % block_di == 0
    grid = (bsz, di // block_di, l // chunk)
    kernel = functools.partial(_mamba_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b_, d_, c: (b_, c, d_)),
            pl.BlockSpec((1, chunk, ds), lambda b_, d_, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b_, d_, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, block_di), lambda b_, d_, c: (b_, c, d_)),
            pl.BlockSpec((block_di, ds), lambda b_, d_, c: (d_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_di), lambda b_, d_, c: (b_, c, d_)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, di), dt.dtype),
        scratch_shapes=[pltpu.VMEM((block_di, ds), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(dt, b_mat, c_mat, x, log_a)
