"""Pallas TPU kernels for per-chunk int8 quantize / dequantize.

The communication bottleneck of federated rounds is upload bandwidth
(one full model per site per round), so site deltas are quantized before
they hit the wire (see ``repro.comms.compression``).  On an accelerator
the quantize step is purely memory-bound — one pass over the [C, chunk]
delta buffer computing a per-chunk absmax scale and the rounded int8
values — so, like ``fedagg``, the kernel's job is to stream HBM once:

  grid = (ceil(C / block_c)); each cell loads a [block_c, chunk] slab
  into VMEM, reduces |x| along the chunk axis on the VPU for the scales,
  and writes the int8 values and fp32 scales exactly once.

``chunk`` is the quantization granularity (one fp32 scale per chunk);
keep it a multiple of 128 so compiled blocks tile the lane width
cleanly.  ``interpret`` defaults to compiled on TPU/GPU and to the
Pallas interpreter elsewhere — the same dispatch as ``fedagg``; the
numpy reference lives in ``repro.comms.compression`` and the two are
tested to agree exactly (both round half-to-even).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the ONE scale floor, shared with the numpy encoder so both backends
# stay bit-exact (comms.compression has no module-level kernel imports,
# so this cross-layer import cannot cycle)
from repro.comms.compression import MIN_SCALE
_QMAX = 127.0


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def quantize_dequantize_ref(mat):
    """Pure-jnp int8 quantize→dequantize round trip over [..., C, chunk]
    fp32 — the traced CPU counterpart of the kernel pair, used by the
    compiled round engine where the quantized values never leave the
    device.  Same math as the kernel and the numpy encoder (absmax/127
    scales with the shared ``MIN_SCALE`` floor, half-to-even rounding),
    so all three backends agree bit-exactly.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(mat), axis=-1) / _QMAX, MIN_SCALE)
    q = jnp.clip(jnp.round(mat / scale[..., None]), -_QMAX, _QMAX)
    return q * scale[..., None]


def quantize_dequantize_fp8_ref(mat):
    """Traced float8_e4m3 quantize→dequantize round trip (absmax→448
    per-chunk scaling, RTNE cast) — mirrors ``Fp8Codec`` on device."""
    scale = jnp.maximum(jnp.max(jnp.abs(mat), axis=-1) / 448.0, MIN_SCALE)
    q = (mat / scale[..., None]).astype(jnp.float8_e4m3fn)
    return q.astype(jnp.float32) * scale[..., None]


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # [block_c, chunk]
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1) / _QMAX, MIN_SCALE)
    q = jnp.round(x / scale[:, None])                     # half-to-even, VPU
    q_ref[...] = jnp.clip(q, -_QMAX, _QMAX).astype(jnp.int8)
    s_ref[...] = scale


def _dequantize_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)                    # [block_c, chunk]
    o_ref[...] = q * s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def quantize_int8(x, *, block_c: int = 256,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [C, chunk] fp32 → (values int8 [C, chunk], scales fp32 [C])."""
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    c, chunk = x.shape
    if c == 0:                               # empty leaf: nothing to quantize
        return (jnp.zeros((0, chunk), jnp.int8), jnp.zeros((0,), jnp.float32))
    block_c = min(block_c, c)
    padded = _round_up(c, block_c)
    if padded != c:
        x = jnp.pad(x, ((0, padded - c), (0, 0)))
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(padded // block_c,),
        in_specs=[pl.BlockSpec((block_c, chunk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_c, chunk), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, chunk), jnp.int8),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return (q[:c], s[:c]) if padded != c else (q, s)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def dequantize_int8(q, s, *, block_c: int = 256,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """values int8 [C, chunk] + scales fp32 [C] → fp32 [C, chunk]."""
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu")
    c, chunk = q.shape
    if c == 0:
        return jnp.zeros((0, chunk), jnp.float32)
    block_c = min(block_c, c)
    padded = _round_up(c, block_c)
    if padded != c:
        q = jnp.pad(q, ((0, padded - c), (0, 0)))
        s = jnp.pad(s, ((0, padded - c),))
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(padded // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, chunk), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_c, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, chunk), jnp.float32),
        interpret=interpret,
    )(q, s)
    return out[:c] if padded != c else out
