"""Two-tier pod federation over the socket stack (the ``PodTransport``).

A pods :class:`~repro.core.topology.Topology` on the ``thread``/``tcp``
transports builds this server hierarchy instead of the flat star:

    sites ──upload──►  PodAggregationServer (one per pod)
                            │ pod_partial            ▲ install_global
                            ▼                        │
                       pod leader ──upload──►  root AggregationServer
                                  ◄─download──       (cross-pod combine)

Each pod runs its own :class:`~repro.comms.coordinator.AggregationServer`
subclass that finalizes arrivals into a *pod partial* (the pod's
case-weighted mean at the pod's folded weight) instead of advancing a
global round.  A **pod leader** — one relay per pod, the paper's
institutional-hub role — pulls the partial, re-uploads it to the root
server over the ordinary ``Peer``/codec wire (the partial's weight rides
the upload metadata), downloads the combined global, and installs it
back into its pod server, which is when the pod's sites see the round
advance.  Sites run the *unchanged* site script against their pod
server's address: the two-tier structure is invisible below the seam.

The scheduler seam applies per tier: the pod servers take the
topology's ``intra_scheduler`` (sync barrier within the pod, or FedBuff
K-of-members buffering) and the root takes ``inter_scheduler`` (barrier
across pods, or buffered with staleness-discounted pod partials) — so
sync-within-pod + buffered-across-pods and the reverse are both valid
compositions.

Byte accounting is split by tier: the pod servers' ``WireStats`` count
the **intra-pod** traffic (site uploads in, global downloads out — the
fast link), the root server's count the **cross-pod** traffic (partials
in, globals out — the slow/WAN link that scales with the pod count, not
the site count).  ``benchmarks/pod_scaling.py`` measures exactly that
split.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

import numpy as np

from repro.comms.codec import encode_message
from repro.comms.coordinator import AggregationServer
from repro.comms.transport import WireConfig, make_channel
from repro.core.session import BufferedScheduler, RoundScheduler
from repro.core.topology import Topology


class PodAggregationServer(AggregationServer):
    """A pod's tier-1 aggregation point.

    Uploads stream through the inherited :class:`StreamingAccumulator`
    fold (same staleness/compression rules, same duplicate guard), but a
    complete buffer finalizes into a **partial** for the pod leader —
    ``self._round`` (what site downloads block on) only advances when
    the leader installs the root's combined global.  Two extra rpcs:

      ``pod_partial``     — leader: block until partial ``round`` exists,
                            return it with its folded weight;
      ``install_global``  — leader: set the round's global model (also
                            registered as a delta decode reference) and
                            wake blocked site downloads.
    """

    def __init__(self, *args, pod_id: int = 0, **kw):
        self.pod_id = pod_id
        self._partial: Any = None
        self._partial_weight = 0.0
        self._partial_round = 0
        super().__init__(*args, **kw)

    def _on_ready(self):                     # lock held
        self._partial, self._partial_weight = self._finalize_buffer()
        self._folded = set()
        self._rejected = set()
        self._first_fold_t = None
        self._partial_round += 1
        self._lock.notify_all()

    def _handle(self, kind, meta, tree):
        if kind == "pod_partial":
            want = int(meta["round"])
            with self._lock:
                done = self._lock.wait_for(
                    lambda: self._partial_round >= want,
                    timeout=self.download_timeout)
                if not done:
                    return encode_message(
                        "error",
                        {"message": f"timeout: pod {self.pod_id} partial "
                                    f"{want} not complete (at "
                                    f"{self._partial_round}, "
                                    f"{len(self._folded)} folded)"}, None)
                return encode_message(
                    "partial", {"round": self._partial_round,
                                "weight": self._partial_weight},
                    self._partial)
        if kind == "install_global":
            new_round = int(meta["round"])
            with self._lock:
                self._global = tree
                self._round = max(self._round, new_round)
                self._globals[new_round] = tree
                for old in [k for k in self._globals
                            if k <= self._round - self.keep_globals]:
                    del self._globals[old]
                if self._down is not None:
                    # pod rounds advance here, not in _on_ready — the
                    # per-site download references age on the same clock
                    self._down.evict_stale(self._round, self.keep_globals)
                self._lock.notify_all()
            return encode_message("ack", {"round": self._round}, None)
        return super()._handle(kind, meta, tree)


class PodTransport:
    """The two-tier server stack + leader relays for one pods run.

    Owned by the socket transports (``thread``/``tcp``): construct,
    :meth:`start`, point each site worker at :meth:`site_addr`, then
    :meth:`stop` and read :meth:`comm` for the per-tier byte split.
    Leaders run as driver-side threads (they are infrastructure, like
    the servers — the paper's hub process, not a training site).
    """

    def __init__(self, topology: Topology, num_sites: int,
                 case_weights: List[float], masks: np.ndarray,
                 intra_scheduler: RoundScheduler,
                 inter_scheduler: RoundScheduler,
                 io_timeout: float = 120.0,
                 wire: Optional[WireConfig] = None,
                 lease_ttl: Optional[float] = None,
                 start_round: int = 0, initial_global: Any = None,
                 ckpt_store=None, ckpt_every: int = 10,
                 codec=None, error_feedback: bool = True,
                 down_codec=None,
                 mask_secret: Optional[str] = None,
                 aggregator=None, max_upload_norm: Optional[float] = None,
                 initial_down=None):
        topology.validate(num_sites)
        # robust combine applies at the INTRA tier — each pod defends
        # against its own members (the Byzantine surface); the root
        # combines already-sanitized pod partials with the plain
        # weighted fold, matching the stacked engine's
        # ``reduce_pods_robust`` (partials weighted by member count).
        self.aggregator = aggregator
        self.max_upload_norm = max_upload_norm
        # codec: leader→root partial re-uploads ride the same upload
        # compressor as site uploads (delta against the last pulled root
        # global, error-feedback residual per leader) — the WAN link
        # shrinks with the pod count AND the codec ratio.
        self.codec = codec if codec is not None and codec.name != "none" \
            else None
        self.error_feedback = error_feedback
        # down_codec: BOTH install hops compress — the root encodes each
        # leader's download as a per-leader delta (cross-pod/WAN link),
        # and every pod server encodes its sites' downloads as per-site
        # deltas (intra-pod link); leaders decode then install the dense
        # global into their pod server locally.
        self.down_codec = down_codec \
            if down_codec is not None and down_codec.name != "none" else None
        self.initial_down = initial_down
        # mask_secret: secure aggregation at BOTH tiers — sites mask
        # against their pod's scheduled members, leaders mask partials
        # against the round's active pods, so neither the pod servers
        # nor the root ever see a plaintext contribution.
        self.mask_secret = mask_secret
        self.topology = topology
        self.num_sites = num_sites
        self.case_weights = list(case_weights)
        self.masks = np.asarray(masks, bool)
        self.rounds = self.masks.shape[0]
        self.intra_scheduler = intra_scheduler
        self.inter_scheduler = inter_scheduler
        self.io_timeout = io_timeout
        self.wire = wire
        self.lease_ttl = lease_ttl
        self.start_round = int(start_round)
        self.initial_global = initial_global
        self.ckpt_store = ckpt_store
        self.ckpt_every = ckpt_every
        self.pod_of = topology.pod_of(num_sites)
        self.root: Optional[AggregationServer] = None
        self.pod_servers: List[PodAggregationServer] = []
        self._leaders: List[threading.Thread] = []
        self.leader_errors: Dict[int, str] = {}

    # -- lifecycle ----------------------------------------------------------

    def _pod_active_rows(self) -> np.ndarray:
        """[rounds, P] bool: pod p has ≥1 active site in round r — the
        pod-tier Algorithm-2 schedule (what the root's secure-agg masks
        and the leaders' participant lists derive from)."""
        p = self.topology.num_pods
        rows = np.zeros((self.rounds, p), bool)
        for q in range(p):
            rows[:, q] = self.masks[:, self.pod_of == q].any(axis=1)
        return rows

    def start(self) -> "PodTransport":
        p = self.topology.num_pods
        root_sa = None
        self._pod_sa = [None] * p
        if self.mask_secret is not None:
            from repro.privacy import SecureAggState
            root_sa = SecureAggState(self.mask_secret, "pod",
                                     self._pod_active_rows())
            # each pod server schedules only its own members (other
            # pods' columns zeroed), matching the participant set its
            # sites mask against
            for q in range(p):
                rows = self.masks & (self.pod_of == q)[None, :]
                self._pod_sa[q] = SecureAggState(self.mask_secret, "site",
                                                 rows)
        # root combiner: "sites" are pod ids; fold weights arrive per
        # upload (the pod's folded active-member weight), so the static
        # per-pod weights are never used
        self.root = AggregationServer(
            "127.0.0.1", 0, num_sites=p,
            download_timeout=self.io_timeout / 2,
            scheduler=self.inter_scheduler, wire=self.wire,
            initial_round=self.start_round,
            initial_global=self.initial_global,
            ckpt_store=self.ckpt_store, ckpt_every=self.ckpt_every,
            secure_agg=root_sa, down_compression=self.down_codec,
            initial_down=self.initial_down)
        # pod servers keep GLOBAL site ids (uploads carry them), so they
        # take the full case-weight table; `expected` comes from each
        # upload's pod-local active_sites count.  intra="uniform" folds
        # every member at weight 1 (the engine's uniform branch).
        intra_w = (None if self.topology.intra == "uniform"
                   else self.case_weights)
        self.pod_servers = [
            PodAggregationServer("127.0.0.1", 0, num_sites=self.num_sites,
                                 case_weights=intra_w,
                                 download_timeout=self.io_timeout / 2,
                                 scheduler=self.intra_scheduler, pod_id=i,
                                 wire=self.wire, lease_ttl=self.lease_ttl,
                                 initial_round=self.start_round,
                                 initial_global=self.initial_global,
                                 secure_agg=self._pod_sa[i],
                                 aggregator=self.aggregator,
                                 max_upload_norm=self.max_upload_norm,
                                 down_compression=self.down_codec)
            for i in range(p)]
        self._leaders = [threading.Thread(target=self._leader, args=(i,),
                                          daemon=True) for i in range(p)]
        for t in self._leaders:
            t.start()
        return self

    def stop(self):
        """Tear down servers and relays.  Leader failures are collected
        in ``leader_errors`` (not raised here — the driver reports them
        together with any dead site workers)."""
        for t in self._leaders:
            t.join(timeout=5)
        for s in self.pod_servers:
            s.stop()
        if self.root is not None:
            self.root.stop()

    @property
    def rejected_uploads(self) -> int:
        """Sanitation rejections across both tiers (pod servers see the
        site uploads; the root sees leader partials)."""
        total = sum(s.rejected_uploads for s in self.pod_servers)
        if self.root is not None:
            total += self.root.rejected_uploads
        return total

    def site_addr(self, site_id: int):
        """The aggregation address a site worker should use — its pod
        server (sites never talk across the pod boundary)."""
        return self.pod_servers[int(self.pod_of[site_id])].addr

    def site_addrs(self) -> Dict[int, Any]:
        return {i: self.site_addr(i) for i in range(self.num_sites)}

    # -- the leader relay (Algorithm 1, hub side) ---------------------------

    def _active_pods(self, r: int) -> int:
        """Pods with at least one active site in round ``r`` — the root
        barrier's `expected` (pod-tier Algorithm-2 churn: a fully-offline
        pod simply misses the round, like a dropped site).  Shares the
        one definition with the simulated byte split."""
        from repro.core.topology import active_pod_counts
        return int(active_pod_counts(self.topology,
                                     self.masks[r:r + 1])[0])

    def _leader(self, pod_id: int):
        from repro.comms.peer import Peer
        # leaders speak the same authenticated/streaming wire as sites
        peer = Peer(site_id=pod_id, wire=self.wire)
        chan = make_channel(self.pod_servers[pod_id].addr,
                            timeout=self.io_timeout, wire=self.wire,
                            identity=f"leader:{pod_id}")
        buffered = isinstance(self.inter_scheduler, BufferedScheduler)
        mine = self.pod_of == pod_id
        base_round = self.start_round   # root round of the last pulled global
        partials = 0            # partials the pod server has produced:
        #                         one per round with ≥1 active member —
        #                         NOT the loop round (a fully-off pod
        #                         produces none that round)
        comp = reference = sa = None
        if self.codec is not None:
            from repro.comms.compression import (KEEP_GLOBALS_DEFAULT,
                                                 UploadCompressor)
            comp = UploadCompressor(self.codec, self.error_feedback)
        # compressed downloads: the leader holds its own copy of the last
        # decoded root global and acks its round, entering the root's
        # per-leader residual stream (first pull is a dense bootstrap)
        down = self.down_codec is not None
        down_ref = down_acked = None
        if down:
            from repro.comms.compression import decode_download
        if self.mask_secret is not None:
            from repro.privacy import SecureAggClient
            sa = SecureAggClient(self.mask_secret, "pod", pod_id)
            pod_rows = self._pod_active_rows()
        try:
            for r in range(self.start_round, self.rounds):
                partial = None
                if bool((self.masks[r] & mine).any()):
                    partials += 1
                    _, pmeta, partial = chan.request("pod_partial",
                                                     {"round": partials})
                    # buffered inter tier: staleness anchored to the last
                    # pulled root global, exactly like a site client.
                    # inter="uniform" combines active pods at weight 1
                    # instead of their folded member weight.
                    upload_round = base_round + 1 if buffered else r + 1
                    pw = (1.0 if self.topology.inter == "uniform"
                          else float(pmeta["weight"]))
                    payload, xmeta = partial, {"weight": pw}
                    if sa is not None:
                        # pod-tier masking: the root only ever sees the
                        # masked cross-pod sum
                        payload, xmeta = sa.encode(
                            partial, pw, np.flatnonzero(pod_rows[r]), r)
                    elif comp is not None:
                        # delta-encode the partial against the last
                        # pulled root global (same dense-resend guard as
                        # the site client: an anchor past the root's
                        # keep_globals window cannot decode)
                        if (reference is not None and upload_round
                                - base_round >= KEEP_GLOBALS_DEFAULT):
                            reference = None
                        payload, xmeta = comp.encode(partial, reference)
                        xmeta["base_round"] = base_round \
                            if reference is not None else 0
                        xmeta["weight"] = pw
                    peer.upload(self.root.addr, payload, upload_round,
                                active_sites=self._active_pods(r),
                                meta_extra=xmeta)
                want = 0 if buffered else r + 1
                g, dmeta = peer.download(self.root.addr, want, with_meta=True,
                                         down=down, acked_round=down_acked)
                if g is not None and down:
                    g = decode_download(g, dmeta, down_ref)
                    down_ref = g
                    down_acked = int(dmeta["round"])
                if g is not None:
                    base_round = int(dmeta["round"])
                    if comp is not None:   # next delta anchors to this pull
                        reference = g
                elif partial is not None:
                    # buffered root with nothing finalized yet: the pod
                    # continues from its OWN partial (FedBuff semantics —
                    # proceed with what you have) rather than leaving its
                    # sync-barrier sites blocked on an install that will
                    # never come this round
                    g = partial
                if g is not None:
                    chan.request("install_global", {"round": r + 1}, g)
        except Exception as e:  # noqa: BLE001 — surface to the driver
            self.leader_errors[pod_id] = f"{type(e).__name__}: {e}"
        finally:
            chan.close()
            peer.close()

    # -- byte accounting ----------------------------------------------------

    def comm(self, compression: str = "none",
             down_compression: str = "none") -> Dict[str, Any]:
        """Per-tier wire-byte split: intra = site↔pod-server traffic
        summed over pods, cross = leader↔root traffic (the WAN link)."""
        intra_up = intra_down = intra_count = down_count = 0
        for s in self.pod_servers:
            snap = s.stats.snapshot()
            intra_up += snap.get("upload", {}).get("in_bytes", 0)
            intra_down += snap.get("download", {}).get("out_bytes", 0)
            intra_count += snap.get("upload", {}).get("count", 0)
            down_count += snap.get("download", {}).get("count", 0)
        rsnap = self.root.stats.snapshot() if self.root else {}
        cross_up = rsnap.get("upload", {}).get("in_bytes", 0)
        cross_down = rsnap.get("download", {}).get("out_bytes", 0)
        return {"upload_bytes": intra_up + cross_up,
                "download_bytes": intra_down + cross_down,
                "total_bytes": intra_up + cross_up + intra_down + cross_down,
                "intra_pod_upload_bytes": intra_up,
                "intra_pod_download_bytes": intra_down,
                "cross_pod_upload_bytes": cross_up,
                "cross_pod_download_bytes": cross_down,
                "upload_count": intra_count,
                "download_count": down_count,
                "pods": self.topology.num_pods,
                "compression": compression,
                "down_compression": down_compression, "simulated": False}
