"""Site-side comms endpoint (paper Fig 4, Algorithm 1 "Site side").

A ``Peer`` owns a small server socket for receiving models from other
sites (the Sender→Receiver path of decentralized FL) and client channels
to the coordinator / aggregation server.  It exposes exactly the verbs
the paper's FL scripts use:

  centralized : upload(weights) / download(round)
  decentralized: get_assignment(round) → send_model(addr) or recv_model()

A ``wire`` config (see :class:`~repro.comms.transport.WireConfig`)
applies to both halves: the peer's own server enforces the handshake,
and every outgoing channel authenticates as ``site:{id}``, streams
oversized uploads, and retries dropped sockets.  ``close()`` drains the
inbox with a deadline and wakes any blocked ``recv_model`` with a typed
:class:`~repro.comms.transport.PeerClosed` so site scripts exit cleanly
on shutdown instead of leaking ``queue.Empty``.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Optional, Set, Tuple

from repro.comms.codec import encode_message
from repro.comms.transport import (Address, Channel, PeerClosed, Server,
                                   WireConfig, make_channel)

_CLOSED = object()   # inbox sentinel: wakes receivers blocked in close()


class Peer:
    def __init__(self, site_id: int, host: str = "127.0.0.1", port: int = 0,
                 wire: Optional[WireConfig] = None):
        self.site_id = site_id
        self.wire = wire
        self._inbox: "queue.Queue[Tuple[Dict, Any]]" = queue.Queue()
        self._closed = threading.Event()
        self._seen: Set[Tuple[int, int]] = set()
        self.server = Server(host, port, self._handle, wire=wire).start()
        self.addr: Address = self.server.addr
        self._channels: Dict[Address, Channel] = {}

    # -- incoming ----------------------------------------------------------
    def _handle(self, kind, meta, tree):
        if kind == "model":
            # a retried/duplicated send delivers the same (site, round)
            # model twice — ack it, enqueue it once
            key = (int(meta.get("site", -1)), int(meta.get("round", -1)))
            if key not in self._seen:
                self._seen.add(key)
                self._inbox.put((meta, tree))
            return encode_message("ack", {}, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def recv_model(self, timeout: float = 60.0) -> Tuple[Dict, Any]:
        """Block until a peer model arrives (Receiver role).  Raises
        :class:`PeerClosed` if the peer is shut down before/while
        waiting, ``TimeoutError`` if no model arrives in time."""
        if self._closed.is_set():
            raise PeerClosed(f"peer {self.site_id} is closed")
        try:
            item = self._inbox.get(timeout=timeout)
        except queue.Empty:
            if self._closed.is_set():
                raise PeerClosed(f"peer {self.site_id} closed while "
                                 f"waiting for a model") from None
            raise TimeoutError(f"peer {self.site_id}: no model within "
                               f"{timeout}s") from None
        if item is _CLOSED:
            self._inbox.put(_CLOSED)   # wake any other blocked receiver
            raise PeerClosed(f"peer {self.site_id} closed while "
                             f"waiting for a model")
        return item

    # -- outgoing ----------------------------------------------------------
    def _channel(self, addr: Address) -> Channel:
        addr = (addr[0], int(addr[1]))
        if addr not in self._channels:
            self._channels[addr] = make_channel(
                addr, wire=self.wire, identity=f"site:{self.site_id}")
        return self._channels[addr]

    def request(self, addr: Address, kind: str, meta: Dict,
                tree: Any = None) -> Tuple[str, Dict, Any]:
        """Raw rpc against ``addr`` (join/heartbeat/leave and friends)."""
        return self._channel(addr).request(kind, meta, tree)

    def send_model(self, addr: Address, weights: Any, round_index: int,
                   meta_extra: Optional[Dict] = None):
        """Sender role: push local weights directly to the receiver site.
        ``meta_extra`` rides along (e.g. the compression codec tags the
        receiver needs to dequantize — see ``repro.comms.compression``)."""
        self._channel(addr).request(
            "model",
            {"site": self.site_id, "round": round_index, **(meta_extra or {})},
            weights)

    # centralized-FL verbs
    def upload(self, server_addr: Address, weights: Any, round_index: int,
               active_sites: Optional[int] = None,
               meta_extra: Optional[Dict] = None) -> Dict:
        """Upload local weights; returns the server ack metadata (callers
        can check ``ack["stale"]`` — a rejected straggler upload).
        ``meta_extra`` carries the compression tags
        (``compression``/``delta``/``base_round``) the server's
        :func:`~repro.comms.compression.decode_upload` reads."""
        meta = {"site": self.site_id, "round": round_index,
                **(meta_extra or {})}
        if active_sites is not None:
            meta["active_sites"] = active_sites
        _, ack, _ = self._channel(server_addr).request("upload", meta, weights)
        return ack

    def download(self, server_addr: Address, round_index: int,
                 with_meta: bool = False, down: bool = False,
                 acked_round: Optional[int] = None) -> Any:
        """Block until the server completes ``round_index`` and return the
        global model; ``with_meta=True`` also returns the reply metadata
        (``meta["round"]`` = the server round actually served — under a
        buffered scheduler it may be ahead of the requested one).

        ``down=True`` opts into compressed downloads: the request then
        carries this site's identity and ``acked_round`` — the round of
        the last download it decoded — so a down-compressing server can
        serve a quantized delta against the site's held global (any
        disagreement, or ``acked_round=None``, gets a dense bootstrap
        reply; see ``compression.DownlinkCompressor``).  The reply meta
        then carries ``compression``/``delta`` tags for
        ``decode_download``."""
        meta: Dict[str, Any] = {"round": round_index, "site": self.site_id}
        if down:
            meta["down"] = True
            if acked_round is not None:
                meta["acked_round"] = int(acked_round)
        _, meta, tree = self._channel(server_addr).request(
            "download", meta, None)
        return (tree, meta) if with_meta else tree

    def register(self, coord_addr: Address):
        self._channel(coord_addr).request(
            "register", {"site": self.site_id, "addr": list(self.addr)}, None)

    def get_assignment(self, coord_addr: Address, round_index: int) -> Dict:
        _, meta, _ = self._channel(coord_addr).request(
            "get_assignment", {"round": round_index}, None)
        return meta

    def status_update(self, coord_addr: Address, active: bool):
        self._channel(coord_addr).request(
            "status_update", {"site": self.site_id, "active": active}, None)

    def close(self, deadline: float = 1.0):
        """Shut the peer down cleanly: mark closed (new/blocked
        ``recv_model`` calls raise :class:`PeerClosed`), give in-flight
        sender pushes up to ``deadline`` seconds to finish their ack
        round-trip, then close channels and the server socket."""
        if self._closed.is_set():
            return
        self._closed.set()
        self._inbox.put(_CLOSED)
        # drain the receiving half: models already queued (or acked right
        # now on a connection thread) are consumed, not stranded
        end = time.monotonic() + deadline
        while time.monotonic() < end:
            try:
                item = self._inbox.get_nowait()
            except queue.Empty:
                break
            if item is _CLOSED:
                self._inbox.put(_CLOSED)  # keep the sentinel for receivers
                break
        for ch in self._channels.values():
            ch.close()
        self.server.stop()
