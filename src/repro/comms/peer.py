"""Site-side comms endpoint (paper Fig 4, Algorithm 1 "Site side").

A ``Peer`` owns a small server socket for receiving models from other
sites (the Sender→Receiver path of decentralized FL) and client channels
to the coordinator / aggregation server.  It exposes exactly the verbs
the paper's FL scripts use:

  centralized : upload(weights) / download(round)
  decentralized: get_assignment(round) → send_model(addr) or recv_model()
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Optional, Tuple

from repro.comms.codec import encode_message
from repro.comms.transport import Address, Channel, Server


class Peer:
    def __init__(self, site_id: int, host: str = "127.0.0.1", port: int = 0):
        self.site_id = site_id
        self._inbox: "queue.Queue[Tuple[Dict, Any]]" = queue.Queue()
        self.server = Server(host, port, self._handle).start()
        self.addr: Address = self.server.addr
        self._channels: Dict[Address, Channel] = {}

    # -- incoming ----------------------------------------------------------
    def _handle(self, kind, meta, tree):
        if kind == "model":
            self._inbox.put((meta, tree))
            return encode_message("ack", {}, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def recv_model(self, timeout: float = 60.0) -> Tuple[Dict, Any]:
        """Block until a peer model arrives (Receiver role)."""
        return self._inbox.get(timeout=timeout)

    # -- outgoing ----------------------------------------------------------
    def _channel(self, addr: Address) -> Channel:
        addr = (addr[0], int(addr[1]))
        if addr not in self._channels:
            self._channels[addr] = Channel(addr)
        return self._channels[addr]

    def send_model(self, addr: Address, weights: Any, round_index: int,
                   meta_extra: Optional[Dict] = None):
        """Sender role: push local weights directly to the receiver site.
        ``meta_extra`` rides along (e.g. the compression codec tags the
        receiver needs to dequantize — see ``repro.comms.compression``)."""
        self._channel(addr).request(
            "model",
            {"site": self.site_id, "round": round_index, **(meta_extra or {})},
            weights)

    # centralized-FL verbs
    def upload(self, server_addr: Address, weights: Any, round_index: int,
               active_sites: Optional[int] = None,
               meta_extra: Optional[Dict] = None) -> Dict:
        """Upload local weights; returns the server ack metadata (callers
        can check ``ack["stale"]`` — a rejected straggler upload).
        ``meta_extra`` carries the compression tags
        (``compression``/``delta``/``base_round``) the server's
        :func:`~repro.comms.compression.decode_upload` reads."""
        meta = {"site": self.site_id, "round": round_index,
                **(meta_extra or {})}
        if active_sites is not None:
            meta["active_sites"] = active_sites
        _, ack, _ = self._channel(server_addr).request("upload", meta, weights)
        return ack

    def download(self, server_addr: Address, round_index: int,
                 with_meta: bool = False) -> Any:
        """Block until the server completes ``round_index`` and return the
        global model; ``with_meta=True`` also returns the reply metadata
        (``meta["round"]`` = the server round actually served — under a
        buffered scheduler it may be ahead of the requested one)."""
        _, meta, tree = self._channel(server_addr).request(
            "download", {"round": round_index}, None)
        return (tree, meta) if with_meta else tree

    def register(self, coord_addr: Address):
        self._channel(coord_addr).request(
            "register", {"site": self.site_id, "addr": list(self.addr)}, None)

    def get_assignment(self, coord_addr: Address, round_index: int) -> Dict:
        _, meta, _ = self._channel(coord_addr).request(
            "get_assignment", {"round": round_index}, None)
        return meta

    def status_update(self, coord_addr: Address, active: bool):
        self._channel(coord_addr).request(
            "status_update", {"site": self.site_id, "active": active}, None)

    def close(self):
        for ch in self._channels.values():
            ch.close()
        self.server.stop()
