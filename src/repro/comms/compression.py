"""Quantized model-delta uploads with client-side error feedback.

Communication volume is the binding constraint of cross-institution FL
(one full model per site per round through the paper's gRPC channel), so
this module compresses the *upload* direction — the site→server weight
push, and the sender→receiver model push of decentralized gossip —
behind one pluggable :class:`Codec` seam:

  ``none``         passthrough (wire-identical to the uncompressed stack)
  ``int8``         per-chunk absmax int8 (4× smaller than fp32)
  ``fp8``          per-chunk absmax float8_e4m3 (4× smaller, smoother)
  ``topk-sparse``  magnitude top-k per leaf (indices + exact values)

Quantization granularity is a contiguous *chunk* of the flattened leaf
(one fp32 scale per ``chunk`` elements), so a single outlier only
coarsens its own chunk.  On TPU/GPU the int8 path dispatches to the
Pallas kernel in :mod:`repro.kernels.quantize`; on CPU it runs the
equivalent vectorized numpy (both round half-to-even, tested to agree
exactly) — the same backend dispatch as the ``fedagg`` engine.

**Error feedback** (:class:`UploadCompressor`): biased compressors (all
of the above except ``none``) would systematically distort FedAvg /
FedProx / DCML convergence.  The standard fix (Seide et al. 2014;
Karimireddy et al. 2019) is a client-side residual carried across
rounds:

    u_t   = (w_t − ref_t) + e_{t−1}        # delta plus carried residual
    send    Q(u_t)
    e_t   = u_t − deQ(Q(u_t))              # what this round failed to say

The per-round errors telescope: the sum of everything the server decoded
equals the sum of everything the site meant to say, minus one bounded
residual — quantization error does not accumulate over rounds.

Site deltas are encoded against the *last pulled global* (``reference``);
the first upload of a run (before any global exists server-side) is
encoded as full weights (``delta=False``).  The server side is
:func:`decode_upload`, called by ``AggregationServer._handle("upload")``
before the :class:`~repro.core.agg_engine.StreamingAccumulator` fold —
the fp32 fold already handles mixed upload payloads.

The *download* direction rides the same codec seam in reverse:
:class:`DownlinkCompressor` keeps a per-site error-feedback reference on
the server and encodes every broadcast as a quantized delta against the
global that site last acknowledged (dense bootstrap for new or evicted
references), decoded site-side by :func:`decode_download`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.comms.codec import MaskedTensor, QuantizedTensor

# absmax-0 chunks quantize to 0 instead of dividing by 0.  This is THE
# scale floor: the Pallas kernels import it (repro/kernels/quantize.py),
# so the numpy and kernel encoders stay bit-exact by construction.
MIN_SCALE = np.float32(1e-12)

# how many recent globals the aggregation point keeps as delta decode
# references — shared by the AggregationServer, the stacked buffered
# simulator, and the site-side "has my reference been evicted yet?"
# guard, so client and server reason about the same window
KEEP_GLOBALS_DEFAULT = 16


def _accel_backend() -> bool:
    # the one backend-dispatch rule, shared with every kernel wrapper
    from repro.kernels.ops import _default_interpret
    return not _default_interpret()


# ---------------------------------------------------------------------------
# pytree helpers (numpy-only; jax is imported lazily for tree mapping)
# ---------------------------------------------------------------------------


def _tree_map(fn, *trees):
    import jax
    return jax.tree.map(fn, *trees,
                        is_leaf=lambda x: isinstance(x, QuantizedTensor))


def tree_payload_nbytes(tree: Any) -> int:
    """Wire payload bytes of a pytree whose leaves are arrays and/or
    :class:`QuantizedTensor` / :class:`MaskedTensor` (header/framing
    overhead excluded)."""
    import jax
    wire_leaf = (QuantizedTensor, MaskedTensor)
    return sum(
        x.nbytes if isinstance(x, wire_leaf) else np.asarray(x).nbytes
        for x in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, wire_leaf)))


def chunk_geom(n: int, chunk: int, align: int = 1) -> Tuple[int, int]:
    """(rows, width) of the quantization chunk matrix for an n-element
    leaf.  For leaves smaller than ``chunk`` the row width shrinks to
    the (``align``-rounded) leaf size, so small leaves (biases, norms)
    don't pay a full chunk of zero padding on the wire.  THE chunk-
    geometry rule: the wire codec here, the Pallas kernels (align=128,
    the TPU lane width) and the round engine's on-device codec all
    derive their layouts from it, so scales and byte accounting agree
    across backends by construction."""
    c = min(chunk, max(-(-n // align) * align, align))
    return (-(-n // c) if n else 0), c


def _as_chunks(flat: np.ndarray, chunk: int, align: int = 1) -> np.ndarray:
    """1-D fp32 → zero-padded [C, chunk] matrix via :func:`chunk_geom`."""
    size = flat.size
    rows, chunk = chunk_geom(size, chunk, align)
    if rows * chunk != size:
        flat = np.pad(flat, (0, rows * chunk - size))
    return flat.reshape(rows, chunk)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Codec:
    """One leaf-wise compression scheme.  ``encode_array`` maps an fp32
    array to a :class:`QuantizedTensor` (or passes it through);
    decoding is codec-instance-free — :func:`decode_array` dispatches on
    the wire type's ``codec`` tag so any receiver can decode."""

    name = "none"

    def encode_array(self, arr: np.ndarray):
        return np.asarray(arr)

    def encode_tree(self, tree: Any) -> Any:
        return _tree_map(self.encode_array, tree)


@dataclasses.dataclass
class NoneCodec(Codec):
    """Identity codec — the wire payload is exactly the PR-2 stack's."""

    name = "none"


@dataclasses.dataclass
class Int8Codec(Codec):
    """Per-chunk absmax int8: values q ∈ [−127, 127], one fp32 scale per
    ``chunk`` elements (absmax/127).  ``use_kernel=None`` dispatches to
    the Pallas kernel on TPU/GPU and numpy on CPU (fedagg pattern)."""

    chunk: int = 1024
    use_kernel: Optional[bool] = None

    name = "int8"

    def _kernel(self) -> bool:
        if self.use_kernel is not None:
            return self.use_kernel
        return _accel_backend()

    def encode_array(self, arr) -> QuantizedTensor:
        arr = np.asarray(arr, np.float32)
        kernel = self._kernel()
        mat = _as_chunks(arr.reshape(-1), self.chunk,
                         align=128 if kernel else 1)
        if kernel:
            from repro.kernels import ops
            q, s = ops.quantize_int8(mat)
            q, s = np.asarray(q), np.asarray(s)
        else:
            s = np.maximum(
                np.max(np.abs(mat), axis=1) / np.float32(127.0), MIN_SCALE
            ).astype(np.float32)
            q = np.clip(np.rint(mat / s[:, None]), -127, 127).astype(np.int8)
        return QuantizedTensor("int8", arr.shape, {"q": q, "scale": s})


@dataclasses.dataclass
class Fp8Codec(Codec):
    """Per-chunk absmax float8_e4m3: scaled to the e4m3 range (absmax →
    448), cast with round-to-nearest-even.  Same 4× ratio as int8 with a
    log-spaced grid (finer near zero, coarser at the chunk extremes)."""

    chunk: int = 1024

    name = "fp8"

    def encode_array(self, arr) -> QuantizedTensor:
        import ml_dtypes
        arr = np.asarray(arr, np.float32)
        mat = _as_chunks(arr.reshape(-1), self.chunk)
        s = np.maximum(
            np.max(np.abs(mat), axis=1) / np.float32(448.0), MIN_SCALE
        ).astype(np.float32)
        q = (mat / s[:, None]).astype(ml_dtypes.float8_e4m3fn)
        return QuantizedTensor("fp8", arr.shape, {"q": q, "scale": s})


@dataclasses.dataclass
class TopKCodec(Codec):
    """Magnitude top-k sparsification per leaf: the largest-|x| fraction
    of entries ride the wire exactly (uint32 index + fp32 value); the
    rest are zeroed — error feedback re-injects them in later rounds.

    Sparsification is a *delta* compressor: dropping 90% of a full model
    would hand the federation a mostly-zero global, so the bootstrap
    upload (no reference global yet) goes dense (``dense_bootstrap``)
    and sparsity kicks in once deltas exist."""

    fraction: float = 0.1

    name = "topk"
    dense_bootstrap = True

    def encode_array(self, arr) -> QuantizedTensor:
        arr = np.asarray(arr, np.float32)
        flat = arr.reshape(-1)
        size = flat.size
        k = max(1, int(np.ceil(self.fraction * size))) if size else 0
        if k >= size:
            idx = np.arange(size, dtype=np.uint32)
        else:
            idx = np.sort(np.argpartition(np.abs(flat), size - k)[size - k:]
                          ).astype(np.uint32)
        return QuantizedTensor("topk", arr.shape,
                               {"idx": idx, "val": flat[idx]})


def _decode_int8(qt: QuantizedTensor) -> np.ndarray:
    q = np.asarray(qt.data["q"])
    s = np.asarray(qt.data["scale"], np.float32)
    if q.size and _accel_backend():         # same dispatch as the encoder
        from repro.kernels import ops
        flat = np.asarray(ops.dequantize_int8(q, s)).reshape(-1)
    else:
        flat = (q.astype(np.float32) * s[:, None]).reshape(-1)
    size = int(np.prod(qt.shape, dtype=np.int64))
    return flat[:size].reshape(qt.shape)


def _decode_fp8(qt: QuantizedTensor) -> np.ndarray:
    q = np.asarray(qt.data["q"]).astype(np.float32)
    s = np.asarray(qt.data["scale"], np.float32)
    flat = (q * s[:, None]).reshape(-1)
    size = int(np.prod(qt.shape, dtype=np.int64))
    return flat[:size].reshape(qt.shape)


def _decode_topk(qt: QuantizedTensor) -> np.ndarray:
    size = int(np.prod(qt.shape, dtype=np.int64))
    out = np.zeros(size, np.float32)
    out[np.asarray(qt.data["idx"], np.int64)] = np.asarray(qt.data["val"],
                                                           np.float32)
    return out.reshape(qt.shape)


_DECODERS = {"int8": _decode_int8, "fp8": _decode_fp8, "topk": _decode_topk}


def decode_array(leaf) -> np.ndarray:
    """Dequantize one leaf (passthrough for plain arrays)."""
    if isinstance(leaf, QuantizedTensor):
        try:
            return _DECODERS[leaf.codec](leaf)
        except KeyError:
            raise ValueError(f"unknown quantized-tensor codec {leaf.codec!r}")
    return np.asarray(leaf)


def decode_tree(tree: Any) -> Any:
    """Dequantize every :class:`QuantizedTensor` leaf of a pytree."""
    return _tree_map(decode_array, tree)


@dataclasses.dataclass
class TopKFixedCodec(TopKCodec):
    """Top-k with a *statically shaped* payload: ``k = ceil(fraction·n)``
    per leaf is a function of the leaf shape alone, so every upload of a
    run carries identical index/value array shapes (the bootstrap still
    rides dense).  On the wire this encodes exactly like ``topk`` — the
    point of the name is the contract: constant shapes let the stacked
    round engine compile the sparsifier into its ``lax.scan`` instead of
    falling back to the retired per-round loop (``jax.lax.top_k`` twin
    in :mod:`repro.core.round_engine`)."""

    name = "topk-fixed"


_CODECS = {"none": NoneCodec, "int8": Int8Codec, "fp8": Fp8Codec,
           "topk": TopKCodec, "topk-sparse": TopKCodec,
           "topk-fixed": TopKFixedCodec}


def resolve_codec(spec: Union[str, Codec, None]) -> Codec:
    """``None``/name/instance → :class:`Codec` (mirrors the transport and
    scheduler resolvers on the same job surface)."""
    if spec is None:
        return NoneCodec()
    if isinstance(spec, Codec):
        return spec
    try:
        return _CODECS[spec]()
    except KeyError:
        raise KeyError(f"unknown compression codec {spec!r}; known: "
                       f"{sorted(_CODECS)}")


# ---------------------------------------------------------------------------
# Client-side upload path: delta + error feedback + codec
# ---------------------------------------------------------------------------


class UploadCompressor:
    """One site's upload encoder: delta vs the last pulled global, the
    error-feedback residual carried across rounds, and the codec.

    Stateful per site *and per stream* — a site that both uploads to the
    aggregation server and pushes to gossip peers keeps one compressor
    per stream, so the residuals compensate the right channel.
    ``raw_bytes``/``encoded_bytes`` count fp32-equivalent vs actual
    payload bytes for the bytes-on-the-wire benchmarks.
    """

    def __init__(self, codec: Codec, error_feedback: bool = True):
        self.codec = codec
        self.error_feedback = error_feedback
        self.residual: Any = None
        self.raw_bytes = 0
        self.encoded_bytes = 0
        self.encodes = 0

    def encode(self, params_tree: Any, reference: Any = None
               ) -> Tuple[Any, Dict[str, Any]]:
        """Encode one upload; returns ``(payload_tree, meta)``.  ``meta``
        (``compression``/``delta``) must ride the wire so the server can
        route the payload through :func:`decode_upload`."""
        if self.codec.name == "none":
            nb = tree_payload_nbytes(params_tree)
            self.raw_bytes += nb
            self.encoded_bytes += nb
            self.encodes += 1
            return params_tree, {"compression": "none", "delta": False}
        u = _tree_map(lambda x: np.asarray(x, np.float32), params_tree)
        delta = reference is not None
        if not delta and getattr(self.codec, "dense_bootstrap", False):
            # sparsifiers must not decimate the one full-model upload of
            # a run; send it dense and compress deltas from round 2 on
            self.raw_bytes += tree_payload_nbytes(u)
            self.encoded_bytes += tree_payload_nbytes(u)
            self.encodes += 1
            return u, {"compression": "none", "delta": False}
        if delta:
            u = _tree_map(lambda x, g: x - np.asarray(g, np.float32),
                          u, reference)
        if self.error_feedback and self.residual is not None:
            u = _tree_map(np.add, u, self.residual)
        enc = self.codec.encode_tree(u)
        if self.error_feedback:
            self.residual = _tree_map(np.subtract, u, decode_tree(enc))
        self.raw_bytes += tree_payload_nbytes(u)
        self.encoded_bytes += tree_payload_nbytes(enc)
        self.encodes += 1
        return enc, {"compression": self.codec.name, "delta": delta}


def is_compressed(meta: Dict[str, Any]) -> bool:
    return meta.get("compression", "none") != "none"


def decode_upload(tree: Any, meta: Dict[str, Any], reference: Any = None
                  ) -> Any:
    """Server/receiver side of :meth:`UploadCompressor.encode`: dequantize
    the payload and, for delta uploads, rebuild full weights against the
    same ``reference`` global the site encoded against.  A plain
    uncompressed upload passes through untouched."""
    if is_compressed(meta):
        tree = decode_tree(tree)
    if meta.get("delta"):
        if reference is None:
            raise ValueError("delta upload but no reference global to "
                             "decode against")
        tree = _tree_map(lambda d, g: d + np.asarray(g, np.float32),
                         tree, reference)
    return tree


# ---------------------------------------------------------------------------
# Server-side download path: per-site reference tracking + codec
# ---------------------------------------------------------------------------


class DownlinkCompressor:
    """Server-side download encoder: per-site error-feedback residuals
    for the broadcast direction, expressed as *reference tracking*.

    For every site the server keeps ``held`` — its record of the global
    the site actually holds after decoding everything sent so far — and
    encodes each download as ``Q(g − held)``.  After encoding it
    advances ``held += deQ(Q(g − held))``, i.e. to exactly what the site
    will decode, so next round's delta ``g' − held`` automatically
    contains this round's quantization error: the residual is implicit
    and telescopes, the downlink twin of :class:`UploadCompressor`'s
    ``e_t`` (``error_feedback=False`` instead pretends the site received
    ``g`` exactly, so per-round errors accumulate — kept only to
    demonstrate the divergence).

    Dense bootstrap mirrors the upload path's rejoin rule: a site with
    no server-side reference (new/joined), an evicted reference
    (:meth:`evict_stale`, the ``keep_globals`` window), or an
    ``acked_round`` that disagrees with the server record (lost reply,
    restarted site) gets the full fp32 global, which re-synchronizes
    both ends — stale references can never deadlock or corrupt a
    trajectory, they just cost one dense send.
    """

    def __init__(self, codec: Codec, error_feedback: bool = True):
        self.codec = codec
        self.error_feedback = error_feedback
        self._held: Dict[Any, list] = {}        # site -> [held_tree, round]
        self.raw_bytes = 0
        self.encoded_bytes = 0
        self.encodes = 0
        self.dense_sends = 0

    def encode(self, site: Any, global_tree: Any, round_index: int,
               acked_round: Optional[int] = None
               ) -> Tuple[Any, Dict[str, Any]]:
        """Encode the current global for ``site``; returns
        ``(payload_tree, meta)``.  ``acked_round`` is the round of the
        last download the *site* says it decoded (rides the download
        request) — any disagreement with the server record forces a
        dense re-sync."""
        g = _tree_map(lambda x: np.asarray(x, np.float32), global_tree)
        if self.codec.name == "none":
            self._held[site] = [g, int(round_index)]
            return g, {"compression": "none", "delta": False}
        st = self._held.get(site)
        dense = (st is None or acked_round is None
                 or int(acked_round) != st[1])
        raw = tree_payload_nbytes(g)
        if dense:
            self._held[site] = [g, int(round_index)]
            self.raw_bytes += raw
            self.encoded_bytes += raw
            self.encodes += 1
            self.dense_sends += 1
            return g, {"compression": "none", "delta": False}
        held = st[0]
        delta = _tree_map(np.subtract, g, held)
        enc = self.codec.encode_tree(delta)
        new_held = (_tree_map(np.add, held, decode_tree(enc))
                    if self.error_feedback else g)
        self._held[site] = [new_held, int(round_index)]
        self.raw_bytes += raw
        self.encoded_bytes += tree_payload_nbytes(enc)
        self.encodes += 1
        return enc, {"compression": self.codec.name, "delta": True}

    def evict_stale(self, current_round: int, keep: int) -> None:
        """Drop held references of sites that have not downloaded within
        the ``keep`` most recent rounds — the same bounded-window rule as
        the upload path's ``keep_globals`` ring.  An evicted site's next
        download is a dense bootstrap (never a deadlock)."""
        cutoff = int(current_round) - int(keep)
        for sid in [s for s, (_, hr) in self._held.items() if hr <= cutoff]:
            del self._held[sid]

    # -- checkpoint persistence hooks (crash-resumable jobs) ---------------

    def held_sites(self):
        return sorted(self._held)

    def held_state(self, site):
        """``[held_tree, held_round]`` for ``site`` (or None)."""
        return self._held.get(site)

    def restore(self, site, held_tree, held_round: int) -> None:
        self._held[site] = [
            _tree_map(lambda x: np.asarray(x, np.float32), held_tree),
            int(held_round)]


def decode_download(tree: Any, meta: Dict[str, Any], reference: Any = None
                    ) -> Any:
    """Site side of :meth:`DownlinkCompressor.encode`: dequantize the
    payload (tag dispatch — Pallas dequantize on accelerators) and, for
    delta downloads, rebuild the full global against the site's held
    copy of its last decoded download."""
    if is_compressed(meta):
        tree = decode_tree(tree)
    if meta.get("delta"):
        if reference is None:
            raise ValueError("delta download but no held global to decode "
                             "against")
        tree = _tree_map(lambda d, g: d + np.asarray(g, np.float32),
                         tree, reference)
    return tree
