"""Coordination / aggregation services (paper Figs 3 & 4, Algorithm 1).

``AggregationServer`` — centralized FL: receives site weight uploads,
computes the case-weighted average (Eq. 1) once all active sites report,
and hands the global model back on download.

``CoordinationServer`` — decentralized FL: never touches weights.  It
tracks site metadata (address, active/dropped status), pairs active
sites into (sender, receiver) roles each round, and broadcasts the
assignment — the sites then exchange models directly peer-to-peer.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.comms.codec import encode_message
from repro.comms.transport import Server
from repro.core.gossip import pair_sites


def _weighted_average(uploads: Dict[int, Any], weights: Dict[int, float]) -> Any:
    tot = sum(weights[i] for i in uploads)
    import jax
    acc = None
    for i, tree in uploads.items():
        w = weights[i] / tot
        scaled = jax.tree.map(lambda x: np.asarray(x, np.float32) * w, tree)
        acc = scaled if acc is None else jax.tree.map(np.add, acc, scaled)
    return acc


class AggregationServer:
    """Centralized FL server (FedAvg/FedProx upload→aggregate→broadcast)."""

    def __init__(self, host: str, port: int, num_sites: int,
                 case_weights: Optional[List[float]] = None):
        self.num_sites = num_sites
        self.weights = {i: (case_weights[i] if case_weights else 1.0)
                        for i in range(num_sites)}
        self._lock = threading.Condition()
        self._uploads: Dict[int, Any] = {}
        self._round = 0
        self._global: Any = None
        self.server = Server(host, port, self._handle).start()
        self.addr = self.server.addr

    def _handle(self, kind, meta, tree):
        if kind == "upload":
            with self._lock:
                self._uploads[int(meta["site"])] = tree
                expected = int(meta.get("active_sites", self.num_sites))
                if len(self._uploads) >= expected:
                    self._global = _weighted_average(self._uploads, self.weights)
                    self._uploads = {}
                    self._round += 1
                    self._lock.notify_all()
            return encode_message("ack", {"round": self._round}, None)
        if kind == "download":
            want_round = int(meta["round"])
            with self._lock:
                self._lock.wait_for(lambda: self._round >= want_round, timeout=60)
                return encode_message("global", {"round": self._round}, self._global)
        if kind == "status":
            return encode_message("status", {"round": self._round,
                                             "pending": len(self._uploads)}, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def stop(self):
        self.server.stop()


class CoordinationServer:
    """Decentralized FL coordinator: metadata + pairing only (Fig 4)."""

    def __init__(self, host: str, port: int, num_sites: int, seed: int = 0):
        self.num_sites = num_sites
        self.rng = np.random.default_rng(seed)
        self._lock = threading.Condition()
        self._sites: Dict[int, Dict[str, Any]] = {}       # site -> {addr, active}
        self._round = 0
        self._assignment: Optional[Dict[str, Any]] = None
        self.server = Server(host, port, self._handle).start()
        self.addr = self.server.addr

    def _handle(self, kind, meta, tree):
        if kind == "register":
            with self._lock:
                self._sites[int(meta["site"])] = {
                    "addr": tuple(meta["addr"]), "active": True}
                self._lock.notify_all()
            return encode_message("ack", {}, None)
        if kind == "status_update":            # Algorithm 1 "send status update"
            with self._lock:
                site = int(meta["site"])
                if site in self._sites:
                    self._sites[site]["active"] = bool(meta["active"])
                ready = (len(self._sites) == self.num_sites)
                if ready and all(m.get("reported_round", -1) is not None
                                 for m in self._sites.values()):
                    pass
            return encode_message("ack", {}, None)
        if kind == "get_assignment":           # Algorithm 1 coordinator side
            want_round = int(meta["round"])
            with self._lock:
                self._lock.wait_for(lambda: len(self._sites) == self.num_sites,
                                    timeout=60)
                if self._assignment is None or self._assignment["round"] < want_round:
                    active = np.array([self._sites[i]["active"]
                                       for i in range(self.num_sites)])
                    partner, is_recv, is_send = pair_sites(active, self.rng)
                    self._assignment = {
                        "round": want_round,
                        "partner": partner.tolist(),
                        "is_receiver": is_recv.tolist(),
                        "is_sender": is_send.tolist(),
                        "active": active.tolist(),
                        "addresses": {str(i): list(self._sites[i]["addr"])
                                      for i in range(self.num_sites)},
                    }
                return encode_message("assignment", self._assignment, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def stop(self):
        self.server.stop()
