"""Coordination / aggregation services (paper Figs 3 & 4, Algorithm 1).

``AggregationServer`` — centralized FL: folds each site weight upload
into a streaming Eq. 1 accumulator on arrival (O(N) server memory — one
fp32 model, not one decoded model per site), normalizes once all active
sites report, and hands the global model back on download.

``CoordinationServer`` — decentralized FL: never touches weights.  It
tracks site metadata (address, active/dropped status), pairs active
sites into (sender, receiver) roles each round, and broadcasts the
assignment — the sites then exchange models directly peer-to-peer.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.comms import compression
from repro.comms.codec import encode_message
from repro.comms.membership import LeaseRegistry
from repro.comms.transport import Server, WireConfig, WireStats
from repro.core.agg_engine import (StreamingAccumulator, clip_tree_norm,
                                   parse_aggregator, robust_combine_trees,
                                   tree_all_finite, tree_l2_norm)
from repro.core.gossip import pair_sites
from repro.core.session import RoundScheduler, SyncScheduler


class AggregationServer:
    """Centralized FL server (FedAvg/FedProx upload→aggregate→broadcast).

    Uploads stream through a :class:`StreamingAccumulator`: each arrival
    is scaled and added into one running fp32 sum (the server is O(N) in
    memory however many sites join — the scaling term Sheller et al. and
    APPFL identify as the server bottleneck).  Duplicate uploads for the
    same round are acknowledged but not folded twice.  A download that
    outwaits ``download_timeout`` gets an ``error`` reply (surfaced to
    the client as a ``RuntimeError``) instead of a ``None`` global model.

    Quantized uploads (see :mod:`repro.comms.compression`) decode here,
    *before* the accumulator fold: a payload tagged ``compression`` is
    dequantized, and a ``delta`` payload is rebuilt against the global
    the site last pulled (``base_round``, served from a bounded history
    of recent globals) — so all transports compress through the same
    server seam, and the fp32 fold itself never changes.

    The *when to aggregate / at what weight* decision is delegated to a
    :class:`~repro.core.session.RoundScheduler`.  The default
    :class:`SyncScheduler` keeps barrier semantics and rejects uploads
    whose round does not match the round being collected — a straggler's
    round-(r−1) upload is acked ``{"stale": true}`` and NOT folded into
    round r's accumulator.  A :class:`BufferedScheduler` instead admits
    late uploads at a staleness-discounted weight and finalizes after
    ``buffer_k`` arrivals (FedBuff-style buffered async).
    """

    def __init__(self, host: str, port: int, num_sites: int,
                 case_weights: Optional[List[float]] = None,
                 download_timeout: float = 60.0,
                 scheduler: Optional[RoundScheduler] = None,
                 keep_globals: int = compression.KEEP_GLOBALS_DEFAULT,
                 wire: Optional[WireConfig] = None,
                 lease_ttl: Optional[float] = None,
                 initial_round: int = 0, initial_global: Any = None,
                 ckpt_store=None, ckpt_every: int = 10,
                 secure_agg=None, aggregator=None,
                 max_upload_norm: Optional[float] = None,
                 down_compression=None, initial_down=None):
        self.num_sites = num_sites
        # robust combine rule for the site→global reduction.  Rank-based
        # rules (trimmed/median/krum) need the round's individual rows,
        # so they trade the O(N) streaming fold for an O(S·N) row buffer
        # — and they cannot see through secure-agg masks at all.
        self.aggregator = parse_aggregator(aggregator)
        if self.aggregator.rank_based and secure_agg is not None:
            raise ValueError(
                f"aggregator {self.aggregator.name!r} is rank-based: it "
                "must inspect individual site updates, which secure "
                "aggregation's pairwise masks hide by design — use "
                "normclip or fedavg with secure_agg")
        self._rows: Dict[int, Any] = {}
        # upload sanitation: non-finite uploads always reject;
        # max_upload_norm additionally rejects L2-norm outliers.  A
        # rejected site leaves the round's barrier (like dropout), so
        # sync rounds don't deadlock waiting on a poisoned upload.
        self.max_upload_norm = max_upload_norm
        self._rejected: Set[int] = set()
        self.rejected_uploads = 0
        # secure aggregation (repro.privacy.SecureAggState): masked
        # uploads fold as raw uint64 modular sums; finalize decodes the
        # fixed point AFTER recovering the pair seeds of any scheduled
        # site that never arrived (Bonawitz-style dropout repair)
        self.secure_agg = secure_agg
        self._masked_weight = 0.0
        self._masked_round: Optional[int] = None
        self.weights = {i: (case_weights[i] if case_weights else 1.0)
                        for i in range(num_sites)}
        self.download_timeout = download_timeout
        self.scheduler = scheduler or SyncScheduler()
        self.keep_globals = keep_globals
        self.stats = WireStats()
        self._lock = threading.Condition()
        self._acc = StreamingAccumulator()
        self._folded: Set[int] = set()
        # a resumed job re-enters mid-sequence: the server starts at the
        # checkpointed round and serves the checkpointed global (also the
        # delta decode reference sites re-anchor to after resume)
        self._round = int(initial_round)
        self._global: Any = initial_global
        # recent globals by round — the decode references for quantized
        # *delta* uploads (a site's delta is anchored to the global it
        # last pulled; under a buffered scheduler that can lag several
        # rounds, so a bounded history is kept, not just the latest)
        self._globals: Dict[int, Any] = {}
        if initial_global is not None:
            self._globals[self._round] = initial_global
        # downlink compression: per-site error-feedback references so
        # every broadcast is a quantized delta against the global that
        # site last acknowledged (dense bootstrap on join/evict/ack
        # mismatch); sites opt in per download request with meta["down"]
        down_codec = compression.resolve_codec(down_compression)
        self._down = (compression.DownlinkCompressor(down_codec)
                      if down_codec.name != "none" else None)
        if self._down is not None and initial_down:
            # crash resume: per-site held references persisted alongside
            # the global — a resumed server serves the same delta stream
            # the killed one would have (loss-identical trajectories)
            for sid, (held, held_round) in initial_down.items():
                self._down.restore(int(sid), held, held_round)
        # crash-resume hook: checkpoint the global server-side as rounds
        # complete (the driver only sees the FINAL global on the socket
        # transports, so mid-job persistence has to happen here)
        self._ckpt_store = ckpt_store
        self._ckpt_every = int(ckpt_every)
        # elastic membership: sites hold ttl leases renewed by heartbeat;
        # a reaper folds silent sites out of the barrier expectation
        self.lease_ttl = lease_ttl
        self.registry = LeaseRegistry(lease_ttl) if lease_ttl else None
        self._last_scheduled = num_sites   # active_sites from last upload
        self._reaper_stop = threading.Event()
        self._reaper: Optional[threading.Thread] = None
        if self.registry is not None:
            self._reaper = threading.Thread(target=self._reap, daemon=True)
            self._reaper.start()
        # sync round deadline (SyncScheduler.round_deadline_s): once the
        # first upload of a round has folded and the deadline elapses,
        # finalize with whoever reported — stragglers hit the ordinary
        # stale-ack path next round
        self.round_deadline_s = getattr(self.scheduler,
                                        "round_deadline_s", None)
        self._first_fold_t: Optional[float] = None
        self._deadline_stop = threading.Event()
        self._deadline_thread: Optional[threading.Thread] = None
        if self.round_deadline_s:
            self._deadline_thread = threading.Thread(
                target=self._deadline_watch, daemon=True)
            self._deadline_thread.start()
        # writable decode lets the accumulator scale fp32 uploads in place
        self.server = Server(host, port, self._handle, decode_writable=True,
                             stats=self.stats, wire=wire).start()
        self.addr = self.server.addr

    @property
    def down_counters(self) -> Optional[dict]:
        """Payload-level downlink codec counters, or None when downloads
        ride dense (``raw`` vs ``encoded`` bytes exclude wire framing —
        the ratio the benchmarks report)."""
        if self._down is None:
            return None
        return {"raw": self._down.raw_bytes,
                "encoded": self._down.encoded_bytes,
                "encodes": self._down.encodes,
                "dense_sends": self._down.dense_sends}

    def _discount(self, upload_round: int) -> Optional[float]:
        """Lock held.  The round currently being collected is
        ``self._round + 1``; staleness 0 = an upload for exactly that."""
        return self.scheduler.discount(self._round + 1 - upload_round)

    def _wait_for_upload_round(self, upload_round: int) -> None:
        """Lock held.  A site that sat out intermediate rounds (dropout)
        races ahead of the aggregation point and uploads a FUTURE-tagged
        payload; under barrier semantics it must wait for the point to
        catch up — dropping it as 'stale' would leave its round one
        upload short forever.  Bounded by ``download_timeout``; on
        timeout the normal staleness check rejects the upload."""
        self._lock.wait_for(lambda: upload_round <= self._round + 1,
                            timeout=self.download_timeout)

    def _finalize_buffer(self):
        """Lock held.  Finalize the accumulator → ``(tree, weight)``.
        A masked round takes the integer path: the raw modular sum,
        repaired for scheduled-but-missing participants, then decoded
        from fixed point at the plaintext weight total the uploads'
        meta carried.  A rank-based aggregator instead combines the
        round's row buffer (weight = the row count — rank rules are
        unweighted over their inputs)."""
        if self._masked_round is not None:
            tree = self.secure_agg.unmask(
                self._acc.finalize_int(), self._masked_round,
                set(self._folded), self._masked_weight)
            w = self._masked_weight
            self._masked_weight = 0.0
            self._masked_round = None
            return tree, w
        if self.aggregator.rank_based:
            rows = [self._rows[s] for s in sorted(self._rows)]
            self._rows = {}
            return robust_combine_trees(rows, self.aggregator), float(len(rows))
        w = self._acc.weight_total
        return self._acc.finalize(), w

    def _on_ready(self):
        """Lock held.  The buffer is complete: finalize into a new global
        and advance the round.  The pod-tier subclass
        (:class:`repro.comms.pods.PodAggregationServer`) overrides this to
        finalize into a *partial* for its leader instead — the round only
        advances when the leader installs the root global."""
        tree, _ = self._finalize_buffer()
        if tree is not None:
            self._global = tree
        # (tree is None when every upload of the round was rejected —
        # the current global is re-published and the round advances)
        self._folded = set()
        self._rejected = set()
        self._first_fold_t = None
        self._round += 1
        self._globals[self._round] = self._global
        for old in [k for k in self._globals
                    if k <= self._round - self.keep_globals]:
            del self._globals[old]
        if self._down is not None:
            # bound the per-site download references with the same
            # window as the upload ring: a site silent past it gets a
            # dense bootstrap on its next download, never a deadlock
            self._down.evict_stale(self._round, self.keep_globals)
        self._checkpoint_global()
        self._lock.notify_all()

    def _checkpoint_global(self):
        """Lock held.  Server round r is the global after 0-based loop
        round r-1 — persisted on the recorder's ``ckpt_every`` grid so a
        killed job resumes from it."""
        round_index = self._round - 1
        if self._ckpt_store is not None and round_index % self._ckpt_every == 0:
            self._ckpt_store.save("global", round_index, self._global,
                                  meta={"server_round": self._round})
            if self._down is not None:
                # the per-site held references ride the same grid: a
                # resumed server must encode deltas against exactly what
                # each resumed site holds, or trajectories diverge
                for sid in self._down.held_sites():
                    held, held_round = self._down.held_state(sid)
                    self._ckpt_store.save(
                        f"downref{sid}", round_index, held,
                        meta={"held_round": int(held_round)})

    # -- elastic membership -------------------------------------------------

    def _expected(self, scheduled: int) -> int:
        """Barrier expectation: the Algorithm-2 scheduled count, shrunk
        to the live lease count when leases are in play (a silent site
        folds into the dropout mask instead of deadlocking the round)."""
        if self.registry is None:
            return int(scheduled)
        return self.registry.expected(int(scheduled))

    def _barrier_expected(self) -> int:
        """Lock held.  The barrier expectation after every shrink:
        Algorithm-2 scheduled count, minus expired leases, minus the
        sites whose upload this round was REJECTED by sanitation (a
        rejected site cannot satisfy the barrier any more than a dead
        one — waiting on it would deadlock the round)."""
        return max(self._expected(self._last_scheduled)
                   - len(self._rejected), 0)

    def _maybe_finalize(self):
        """Lock held.  Re-check the barrier after membership shrank —
        the uploads already folded may now be everyone we can expect."""
        if self._folded and self.scheduler.ready(
                len(self._folded), self._barrier_expected()):
            self._on_ready()

    def _reap(self):
        period = max(self.registry.ttl / 4.0, 0.01)
        while not self._reaper_stop.wait(period):
            with self._lock:
                dead = self.registry.expire()
                if dead:
                    self.registry.expired_log.extend(
                        (self._round + 1, s) for s in dead)
                    self._maybe_finalize()
                    self._lock.notify_all()

    def _deadline_watch(self):
        period = max(float(self.round_deadline_s) / 4.0, 0.01)
        while not self._deadline_stop.wait(period):
            with self._lock:
                if (self._folded and self._first_fold_t is not None
                        and time.time() - self._first_fold_t
                        >= self.round_deadline_s):
                    self._on_ready()
                    self._lock.notify_all()

    def _reject_upload(self, site: int, reason: str) -> bytes:
        """Record a sanitation rejection and re-check the barrier (the
        rejected site just left the round's expectation — the uploads
        already folded may now complete it; an all-rejected round
        re-publishes the current global)."""
        with self._lock:
            if site not in self._folded and site not in self._rejected:
                self._rejected.add(site)
                self.rejected_uploads += 1
                if self.scheduler.ready(len(self._folded),
                                        self._barrier_expected()):
                    self._on_ready()
                self._lock.notify_all()
            rnd = self._round
        return encode_message(
            "ack", {"round": rnd, "stale": False, "rejected": True,
                    "reason": reason}, None)

    def _handle(self, kind, meta, tree):
        if kind == "upload":
            site = int(meta["site"])
            masked = bool(meta.get("masked"))
            if masked:
                if self.secure_agg is None:
                    return encode_message(
                        "error", {"message": "masked upload to a server "
                                             "without secure aggregation "
                                             "configured"}, None)
                from repro.privacy import masked_values
                # MaskedTensor wrappers → raw uint64 arrays; the server
                # never sees a plaintext model, only masked integers
                tree = masked_values(tree)
            if compression.is_compressed(meta) or meta.get("delta"):
                # dequantize OUTSIDE the lock — a full-model numpy decode
                # per upload would otherwise serialize all concurrent
                # sites.  Only the staleness pre-check and the reference
                # snapshot need the lock; staleness is re-checked before
                # the fold in case the round advanced during the decode.
                with self._lock:
                    upload_round = int(meta.get("round", self._round + 1))
                    self._wait_for_upload_round(upload_round)
                    if self._discount(upload_round) is None:
                        return encode_message(
                            "ack", {"round": self._round, "stale": True}, None)
                    base_round = int(meta.get("base_round", 0))
                    reference = None
                    if self._down is not None:
                        # under downlink compression the site anchored its
                        # delta to the *decoded* download it holds, not the
                        # exact global — decode against the server's held
                        # copy (bit-equal to the site's by construction)
                        st = self._down.held_state(site)
                        if st is not None and st[1] == base_round:
                            reference = st[0]
                    if reference is None:
                        reference = self._globals.get(base_round)
                if meta.get("delta") and reference is None:
                    # reference global already evicted: the site resyncs
                    # and re-uploads against a fresh one (or dense)
                    return encode_message(
                        "ack", {"round": self._round, "stale": True}, None)
                try:
                    tree = compression.decode_upload(tree, meta, reference)
                except Exception as exc:
                    # undecodable payload (e.g. wire corruption that got
                    # past the codec's framing) — rejected, not folded
                    return self._reject_upload(site, f"decode: {exc}")
            if not masked:
                # upload sanitation, outside the lock (the norm scan is
                # O(N)).  Only current-round-admissible uploads count as
                # rejections — a stale poisoned upload is just stale —
                # so pre-check staleness first; the fold re-checks it.
                with self._lock:
                    upload_round = int(meta.get("round", self._round + 1))
                    self._wait_for_upload_round(upload_round)
                    if self._discount(upload_round) is None:
                        return encode_message(
                            "ack", {"round": self._round, "stale": True},
                            None)
                if not tree_all_finite(tree):
                    return self._reject_upload(site, "non_finite")
                if self.max_upload_norm is not None and \
                        tree_l2_norm(tree) > self.max_upload_norm:
                    return self._reject_upload(site, "norm_outlier")
                if self.aggregator.name == "normclip":
                    # normclip stays streaming-compatible: clip the
                    # upload's global L2 norm BEFORE it folds
                    tree = clip_tree_norm(tree, self.aggregator.c)
            with self._lock:
                upload_round = int(meta.get("round", self._round + 1))
                self._wait_for_upload_round(upload_round)
                discount = self._discount(upload_round)
                if discount is None:
                    return encode_message(
                        "ack", {"round": self._round, "stale": True}, None)
                if site not in self._folded:
                    if self._folded and masked != (self._masked_round
                                                   is not None):
                        return encode_message(
                            "error", {"message": "mixed masked and "
                                                 "plaintext uploads in one "
                                                 "round"}, None)
                    if masked:
                        # masked integers fold at weight 1.0 — modular
                        # arithmetic, exact; the plaintext weight total
                        # rides the meta and divides out at finalize
                        self._acc.fold(tree, 1.0)
                        self._masked_weight += float(
                            meta.get("weight", self.weights[site]))
                        self._masked_round = int(
                            meta.get("mask_round", upload_round - 1))
                    elif self.aggregator.rank_based:
                        # rank rules need the round's individual rows —
                        # buffered, not streamed (weights don't apply)
                        self._rows[site] = tree
                    else:
                        # a pod leader re-uploading a pod partial carries
                        # the pod's folded (active-member) weight in the
                        # meta — per-site weights stay the static case
                        # weights
                        w = float(meta.get("weight", self.weights[site]))
                        self._acc.fold(tree, w * discount)
                    self._folded.add(site)
                    if self._first_fold_t is None:
                        self._first_fold_t = time.time()
                if self.registry is not None:       # an upload is a renewal
                    self.registry.renew(site)
                self._last_scheduled = int(meta.get("active_sites",
                                                    self.num_sites))
                if self.scheduler.ready(len(self._folded),
                                        self._barrier_expected()):
                    self._on_ready()
            return encode_message("ack", {"round": self._round,
                                          "stale": False}, None)
        if kind == "download":
            want_round = int(meta["round"])
            with self._lock:
                done = self._lock.wait_for(lambda: self._round >= want_round,
                                           timeout=self.download_timeout)
                if not done:
                    return encode_message(
                        "error",
                        {"message": f"timeout: round {want_round} not complete "
                                    f"(server at round {self._round}, "
                                    f"{len(self._folded)} uploads folded)"},
                        None)
                if self._down is not None and meta.get("down"):
                    site = int(meta["site"])
                    payload, dmeta = self._down.encode(
                        site, self._global, self._round,
                        acked_round=meta.get("acked_round"))
                    return encode_message(
                        "global", {"round": self._round, **dmeta}, payload)
                return encode_message("global", {"round": self._round}, self._global)
        if kind == "status":
            return encode_message(
                "status", {"round": self._round,
                           "pending": len(self._folded),
                           "rejected_uploads": self.rejected_uploads}, None)
        if kind == "join":
            # lease admission; the reply doubles as the late-joiner
            # bootstrap — current round + a dense copy of the current
            # global, so a site admitted mid-job starts from the live
            # model instead of round 0
            with self._lock:
                if self.registry is not None:
                    self.registry.join(int(meta["site"]))
                return encode_message(
                    "joined", {"round": self._round,
                               "ttl": float(self.lease_ttl or 0.0)},
                    self._global)
        if kind == "heartbeat":
            with self._lock:
                if self.registry is not None:
                    self.registry.renew(int(meta["site"]))
                return encode_message("ack", {"round": self._round}, None)
        if kind == "leave":
            # graceful exit: drop the lease now and re-check the barrier
            # so surviving sites do not wait out the ttl
            with self._lock:
                if self.registry is not None:
                    self.registry.leave(int(meta["site"]))
                    self._maybe_finalize()
                    self._lock.notify_all()
                return encode_message("ack", {"round": self._round}, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def stop(self):
        self._reaper_stop.set()
        self._deadline_stop.set()
        if self._reaper is not None:
            self._reaper.join(timeout=2)
        if self._deadline_thread is not None:
            self._deadline_thread.join(timeout=2)
        self.server.stop()


class CoordinationServer:
    """Decentralized FL coordinator: metadata + pairing only (Fig 4)."""

    def __init__(self, host: str, port: int, num_sites: int, seed: int = 0,
                 keep_assignments: int = 64,
                 wire: Optional[WireConfig] = None):
        self.num_sites = num_sites
        self.rng = np.random.default_rng(seed)
        self.keep_assignments = keep_assignments
        self._lock = threading.Condition()
        self._sites: Dict[int, Dict[str, Any]] = {}       # site -> {addr, active}
        self._assignments: Dict[int, Dict[str, Any]] = {} # round -> assignment
        self._next_round = 1
        self.server = Server(host, port, self._handle, wire=wire).start()
        self.addr = self.server.addr

    def _handle(self, kind, meta, tree):
        if kind == "register":
            with self._lock:
                self._sites[int(meta["site"])] = {
                    "addr": tuple(meta["addr"]), "active": True}
                self._lock.notify_all()
            return encode_message("ack", {}, None)
        if kind == "status_update":            # Algorithm 1 "send status update"
            with self._lock:
                site = int(meta["site"])
                if site in self._sites:
                    self._sites[site]["active"] = bool(meta["active"])
            return encode_message("ack", {}, None)
        if kind == "get_assignment":           # Algorithm 1 coordinator side
            want_round = int(meta["round"])
            with self._lock:
                self._lock.wait_for(lambda: len(self._sites) == self.num_sites,
                                    timeout=60)
                # assignments are generated once per round, in round order,
                # and kept so a lagging site asking for round r never
                # receives the pairing already generated for round r+1
                while self._next_round <= want_round:
                    active = np.array([self._sites[i]["active"]
                                       for i in range(self.num_sites)])
                    partner, is_recv, is_send = pair_sites(active, self.rng)
                    self._assignments[self._next_round] = {
                        "round": self._next_round,
                        "partner": partner.tolist(),
                        "is_receiver": is_recv.tolist(),
                        "is_sender": is_send.tolist(),
                        "active": active.tolist(),
                        "addresses": {str(i): list(self._sites[i]["addr"])
                                      for i in range(self.num_sites)},
                    }
                    self._next_round += 1
                for old in [k for k in self._assignments
                            if k < self._next_round - self.keep_assignments]:
                    del self._assignments[old]
                asg = self._assignments.get(want_round)
                if asg is None:
                    return encode_message(
                        "error",
                        {"message": f"assignment for round {want_round} "
                                    f"already pruned"}, None)
                return encode_message("assignment", asg, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def stop(self):
        self.server.stop()
