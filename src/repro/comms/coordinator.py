"""Coordination / aggregation services (paper Figs 3 & 4, Algorithm 1).

``AggregationServer`` — centralized FL: folds each site weight upload
into a streaming Eq. 1 accumulator on arrival (O(N) server memory — one
fp32 model, not one decoded model per site), normalizes once all active
sites report, and hands the global model back on download.

``CoordinationServer`` — decentralized FL: never touches weights.  It
tracks site metadata (address, active/dropped status), pairs active
sites into (sender, receiver) roles each round, and broadcasts the
assignment — the sites then exchange models directly peer-to-peer.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.comms.codec import encode_message
from repro.comms.transport import Server
from repro.core.agg_engine import StreamingAccumulator
from repro.core.gossip import pair_sites


class AggregationServer:
    """Centralized FL server (FedAvg/FedProx upload→aggregate→broadcast).

    Uploads stream through a :class:`StreamingAccumulator`: each arrival
    is scaled and added into one running fp32 sum (the server is O(N) in
    memory however many sites join — the scaling term Sheller et al. and
    APPFL identify as the server bottleneck).  Duplicate uploads for the
    same round are acknowledged but not folded twice.  A download that
    outwaits ``download_timeout`` gets an ``error`` reply (surfaced to
    the client as a ``RuntimeError``) instead of a ``None`` global model.
    """

    def __init__(self, host: str, port: int, num_sites: int,
                 case_weights: Optional[List[float]] = None,
                 download_timeout: float = 60.0):
        self.num_sites = num_sites
        self.weights = {i: (case_weights[i] if case_weights else 1.0)
                        for i in range(num_sites)}
        self.download_timeout = download_timeout
        self._lock = threading.Condition()
        self._acc = StreamingAccumulator()
        self._folded: Set[int] = set()
        self._round = 0
        self._global: Any = None
        # writable decode lets the accumulator scale fp32 uploads in place
        self.server = Server(host, port, self._handle,
                             decode_writable=True).start()
        self.addr = self.server.addr

    def _handle(self, kind, meta, tree):
        if kind == "upload":
            with self._lock:
                site = int(meta["site"])
                if site not in self._folded:
                    self._acc.fold(tree, self.weights[site])
                    self._folded.add(site)
                expected = int(meta.get("active_sites", self.num_sites))
                if len(self._folded) >= expected:
                    self._global = self._acc.finalize()
                    self._folded = set()
                    self._round += 1
                    self._lock.notify_all()
            return encode_message("ack", {"round": self._round}, None)
        if kind == "download":
            want_round = int(meta["round"])
            with self._lock:
                done = self._lock.wait_for(lambda: self._round >= want_round,
                                           timeout=self.download_timeout)
                if not done:
                    return encode_message(
                        "error",
                        {"message": f"timeout: round {want_round} not complete "
                                    f"(server at round {self._round}, "
                                    f"{len(self._folded)} uploads folded)"},
                        None)
                return encode_message("global", {"round": self._round}, self._global)
        if kind == "status":
            return encode_message("status", {"round": self._round,
                                             "pending": len(self._folded)}, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def stop(self):
        self.server.stop()


class CoordinationServer:
    """Decentralized FL coordinator: metadata + pairing only (Fig 4)."""

    def __init__(self, host: str, port: int, num_sites: int, seed: int = 0):
        self.num_sites = num_sites
        self.rng = np.random.default_rng(seed)
        self._lock = threading.Condition()
        self._sites: Dict[int, Dict[str, Any]] = {}       # site -> {addr, active}
        self._round = 0
        self._assignment: Optional[Dict[str, Any]] = None
        self.server = Server(host, port, self._handle).start()
        self.addr = self.server.addr

    def _handle(self, kind, meta, tree):
        if kind == "register":
            with self._lock:
                self._sites[int(meta["site"])] = {
                    "addr": tuple(meta["addr"]), "active": True}
                self._lock.notify_all()
            return encode_message("ack", {}, None)
        if kind == "status_update":            # Algorithm 1 "send status update"
            with self._lock:
                site = int(meta["site"])
                if site in self._sites:
                    self._sites[site]["active"] = bool(meta["active"])
            return encode_message("ack", {}, None)
        if kind == "get_assignment":           # Algorithm 1 coordinator side
            want_round = int(meta["round"])
            with self._lock:
                self._lock.wait_for(lambda: len(self._sites) == self.num_sites,
                                    timeout=60)
                if self._assignment is None or self._assignment["round"] < want_round:
                    active = np.array([self._sites[i]["active"]
                                       for i in range(self.num_sites)])
                    partner, is_recv, is_send = pair_sites(active, self.rng)
                    self._assignment = {
                        "round": want_round,
                        "partner": partner.tolist(),
                        "is_receiver": is_recv.tolist(),
                        "is_sender": is_send.tolist(),
                        "active": active.tolist(),
                        "addresses": {str(i): list(self._sites[i]["addr"])
                                      for i in range(self.num_sites)},
                    }
                return encode_message("assignment", self._assignment, None)
        raise ValueError(f"unknown rpc {kind!r}")

    def stop(self):
        self.server.stop()
