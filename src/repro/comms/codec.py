"""Tensor / pytree wire codec with length-prefixed framing.

The paper's communication stack is gRPC (protobuf over HTTP/2).  This
module implements the equivalent wire layer on stdlib primitives so the
framework runs offline: a compact binary header (msgpack-less, struct
packed) + raw little-endian tensor payloads, framed as

    [4B magic][4B header_len][header json][payload...]

Model-weight messages serialize a flattened pytree: the treedef is
encoded as a JSON skeleton, leaves as (dtype, shape, offset) records
into one contiguous payload (single syscall per send; zero-copy numpy
views on receive) — same design point as gRPC's binary frames.

Quantized-tensor wire type: a pytree leaf may be a
:class:`QuantizedTensor` — a codec name, the logical (dequantized)
shape, and a dict of component arrays (e.g. ``int8`` values plus
per-chunk ``fp32`` scales).  It is serialized as a ``__quant__``
skeleton node whose component arrays ride in the same contiguous
payload as ordinary leaves, and decodes back to a ``QuantizedTensor``
— the transport layer never needs to know how to dequantize (that is
:mod:`repro.comms.compression`'s job).
"""
from __future__ import annotations

import dataclasses
import io
import json
import struct
from typing import Any, Dict, List, Tuple

import numpy as np

MAGIC = b"FKBP"
_HDR = struct.Struct("<4sI")

#: Wire protocol generation.  Sent in the ``hello`` handshake by every
#: :class:`~repro.comms.transport.Channel`; a server speaking a different
#: generation rejects the connection with a typed
#: :class:`~repro.comms.transport.ProtocolVersionError` instead of
#: mis-decoding frames.  Bump on any incompatible framing/header change.
PROTOCOL_VERSION = 1


def chunk_spans(total: int, size: int) -> List[Tuple[int, int]]:
    """(start, end) byte spans that cut ``total`` bytes into ``size``-byte
    chunks — the split used by streaming uploads so an encoded message
    larger than ``max_message_size`` never crosses the wire as one frame."""
    if size <= 0:
        raise ValueError(f"chunk size must be positive, got {size}")
    return [(a, min(a + size, total)) for a in range(0, max(total, 1), size)]


@dataclasses.dataclass
class QuantizedTensor:
    """A compressed pytree leaf on the wire.

    ``codec`` names the compression scheme (see
    ``repro.comms.compression.resolve_codec``), ``shape`` is the logical
    shape the tensor dequantizes back to, and ``data`` holds the codec's
    component arrays (quantized values, scales, indices, …).  ``meta``
    carries small codec-specific scalars (chunk size, k, …).
    """

    codec: str
    shape: Tuple[int, ...]
    data: Dict[str, np.ndarray]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        """Payload bytes this leaf contributes to the wire."""
        return sum(np.asarray(a).nbytes for a in self.data.values())


@dataclasses.dataclass
class MaskedTensor:
    """A secure-aggregation pytree leaf on the wire.

    ``shape`` is the logical tensor shape; ``data["v"]`` holds the
    fixed-point masked words (int64, two's complement — uniformly
    random to anyone without the pairwise seeds).  Serialized as a
    ``__masked__`` skeleton node beside ``__quant__``; the transport
    layer never unmasks (that is :mod:`repro.privacy.secure_agg`'s
    job, and only the sum ever is).  ``meta`` carries small per-leaf
    scalars (currently none — frac_bits rides the upload meta).
    """

    shape: Tuple[int, ...]
    data: Dict[str, np.ndarray]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        """Payload bytes this leaf contributes to the wire."""
        return sum(np.asarray(a).nbytes for a in self.data.values())


def _flatten(obj: Any, prefix: str, leaves: List[Tuple[str, np.ndarray]], skeleton: Any):
    if isinstance(obj, MaskedTensor):
        data_sk = {k: _flatten(obj.data[k], f"{prefix}/{k}", leaves, skeleton)
                   for k in sorted(obj.data)}
        node = {"shape": list(obj.shape), "data": data_sk}
        if obj.meta:
            node["meta"] = obj.meta
        return {"__masked__": node}
    if isinstance(obj, QuantizedTensor):
        data_sk = {k: _flatten(obj.data[k], f"{prefix}/{k}", leaves, skeleton)
                   for k in sorted(obj.data)}
        node = {"codec": obj.codec, "shape": list(obj.shape), "data": data_sk}
        if obj.meta:
            node["meta"] = obj.meta
        return {"__quant__": node}
    if isinstance(obj, dict):
        sk = {}
        for k in sorted(obj):
            sk[k] = _flatten(obj[k], f"{prefix}/{k}", leaves, skeleton)
        return sk
    if isinstance(obj, (list, tuple)):
        sk = [
            _flatten(v, f"{prefix}/{i}", leaves, skeleton) for i, v in enumerate(obj)
        ]
        return {"__list__": sk} if isinstance(obj, list) else {"__tuple__": sk}
    arr = np.asarray(obj)
    leaves.append((prefix, arr))
    return {"__leaf__": len(leaves) - 1}


def _np_dtype(name: str) -> np.dtype:
    """``np.dtype(name)``, falling back to the ml_dtypes extension types
    (``float8_e4m3fn`` etc.) that numpy only resolves once registered."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _unflatten(sk: Any, leaves: List[np.ndarray]) -> Any:
    if isinstance(sk, dict):
        if "__leaf__" in sk:
            return leaves[sk["__leaf__"]]
        if "__quant__" in sk:
            q = sk["__quant__"]
            return QuantizedTensor(
                codec=q["codec"], shape=tuple(q["shape"]),
                data={k: _unflatten(v, leaves) for k, v in q["data"].items()},
                meta=q.get("meta", {}))
        if "__masked__" in sk:
            m = sk["__masked__"]
            return MaskedTensor(
                shape=tuple(m["shape"]),
                data={k: _unflatten(v, leaves) for k, v in m["data"].items()},
                meta=m.get("meta", {}))
        if "__list__" in sk:
            return [_unflatten(v, leaves) for v in sk["__list__"]]
        if "__tuple__" in sk:
            return tuple(_unflatten(v, leaves) for v in sk["__tuple__"])
        return {k: _unflatten(v, leaves) for k, v in sk.items()}
    raise ValueError(f"bad skeleton node: {sk!r}")


def encode_message(kind: str, meta: Dict[str, Any], tree: Any = None) -> bytes:
    """Serialize (kind, metadata, optional pytree-of-arrays) to wire bytes."""
    leaves: List[Tuple[str, np.ndarray]] = []
    skeleton = _flatten(tree, "", leaves, None) if tree is not None else None
    records = []
    payload = io.BytesIO()
    offset = 0
    for _name, arr in leaves:
        buf = np.ascontiguousarray(arr)   # NB: promotes 0-d to 1-d; keep arr.shape
        # records are positional and minimal — leaf names and derivable
        # byte counts stay off the wire (at small model scales per-leaf
        # header strings rival the quantized payload itself)
        records.append({"dtype": str(buf.dtype),
                        "shape": list(arr.shape), "offset": offset})
        payload.write(buf.tobytes())
        offset += buf.nbytes
    header = json.dumps({"kind": kind, "meta": meta, "skeleton": skeleton,
                         "records": records}).encode()
    return _HDR.pack(MAGIC, len(header)) + header + payload.getvalue()


def decode_message(data: bytes, *, writable: bool = False
                   ) -> Tuple[str, Dict[str, Any], Any]:
    """Decode wire bytes back to (kind, metadata, pytree).

    By default leaves are zero-copy read-only ``np.frombuffer`` views
    into ``data``.  Pass ``writable=True`` to get owned, writable copies
    — required by in-place consumers such as the aggregation server's
    streaming accumulator (assignment into a read-only view raises
    ``ValueError``).
    """
    magic, hlen = _HDR.unpack_from(data, 0)
    if magic != MAGIC:
        raise ValueError("bad magic — not a FedKBP+ frame")
    header = json.loads(data[_HDR.size: _HDR.size + hlen].decode())
    base = _HDR.size + hlen
    leaves = []
    for rec in header["records"]:
        start = base + rec["offset"]
        count = 1
        for d in rec["shape"]:
            count *= d
        arr = np.frombuffer(data, dtype=_np_dtype(rec["dtype"]),
                            count=count, offset=start).reshape(tuple(rec["shape"]))
        if writable:
            arr = arr.copy()
        leaves.append(arr)
    tree = _unflatten(header["skeleton"], leaves) if header["skeleton"] is not None else None
    return header["kind"], header["meta"], tree


def frame(data: bytes) -> bytes:
    """Length-prefix a message for the TCP stream."""
    return struct.pack("<Q", len(data)) + data


def read_frame(sock) -> bytes:
    """Read one length-prefixed message from a socket (blocking)."""
    hdr = _read_exact(sock, 8)
    (n,) = struct.unpack("<Q", hdr)
    return _read_exact(sock, n)


def _read_exact(sock, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed while reading frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)
