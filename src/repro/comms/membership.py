"""Elastic membership: site leases, heartbeats, and late joiners.

FedKBP+ assumes a fixed site roster; a deployable coordinator cannot.
This module gives the aggregation point a lease table — a site is *live*
while its lease is fresh, and a site that goes silent for ``ttl``
seconds is expired and folds into the same Algorithm-2 dropout
accounting as a scheduled disconnect: the round's barrier expectation
shrinks to the live membership (never below one survivor), the
remaining uploads renormalize through the Eq. 1 weighted fold, and the
round finalizes instead of deadlocking.

The client half is :class:`HeartbeatClient`: a daemon thread that joins
the lease table, renews on a ``ttl/3`` cadence, and (on graceful stop)
leaves explicitly so the barrier does not have to wait out the ttl.
The join reply doubles as the late-joiner bootstrap: it carries the
server's current round and a dense copy of the current global, so a
site admitted mid-job starts from the live model (the same dense-resend
path quantized uploads use when their decode reference is evicted).

Server integration lives in ``repro.comms.coordinator`` — the registry
itself is transport-free and lock-free (callers hold the server lock).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional


class LeaseRegistry:
    """Lease table for elastic membership at an aggregation point.

    Not thread-safe by itself — the owning server calls every method
    under its own condition lock, so expiry decisions and barrier
    re-checks are atomic with the fold state.
    """

    def __init__(self, ttl: float):
        if ttl <= 0:
            raise ValueError(f"lease ttl must be positive, got {ttl}")
        self.ttl = float(ttl)
        self._deadline: Dict[int, float] = {}
        #: sites ever admitted — distinguishes "nobody uses leases" from
        #: "everyone expired" when computing barrier expectations
        self.ever: int = 0
        #: (round, site) log of expiries, for diagnostics/tests
        self.expired_log: List[Any] = []

    def join(self, site: int) -> None:
        """Admit (or re-admit) a site; also the renew operation."""
        if site not in self._deadline:
            self.ever += 1
        self._deadline[site] = time.monotonic() + self.ttl

    renew = join

    def leave(self, site: int) -> None:
        self._deadline.pop(site, None)

    def live(self) -> List[int]:
        now = time.monotonic()
        return sorted(s for s, d in self._deadline.items() if d > now)

    def live_count(self) -> int:
        return len(self.live())

    def is_live(self, site: int) -> bool:
        d = self._deadline.get(site)
        return d is not None and d > time.monotonic()

    def expire(self) -> List[int]:
        """Drop every overdue lease; returns the sites expired now."""
        now = time.monotonic()
        dead = sorted(s for s, d in self._deadline.items() if d <= now)
        for s in dead:
            del self._deadline[s]
        return dead

    def expected(self, scheduled: int) -> int:
        """Barrier expectation for a round that *scheduled* ``scheduled``
        active sites (from the Algorithm-2 masks).  Elastic rule: never
        wait for more sites than are actually live, never shrink below
        one survivor.  Before any site has joined the table the
        scheduled count stands (leases not in use on that path)."""
        if self.ever == 0:
            return scheduled
        return max(1, min(int(scheduled), self.live_count()))


class HeartbeatClient:
    """Daemon-thread lease renewal for one site against one server.

    ``request(kind, meta)`` is the transport hook (a bound
    ``Peer``/``Channel`` request); the client stays transport-agnostic.
    """

    def __init__(self, site_id: int, request: Callable[..., Any],
                 ttl: float, identity: Optional[str] = None):
        self.site_id = site_id
        self.request = request
        self.ttl = float(ttl)
        self.identity = identity or f"site:{site_id}"
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._beat, daemon=True)
        self.join_meta: Dict[str, Any] = {}
        self.bootstrap: Any = None

    def start(self) -> "HeartbeatClient":
        """Join the lease table (blocking), then renew in the background.
        The join reply's round + global are kept for late-joiner
        bootstrap (``join_meta`` / ``bootstrap``)."""
        _, meta, tree = self.request(
            "join", {"site": self.site_id, "peer": self.identity})
        self.join_meta = meta
        self.bootstrap = tree
        self._thread.start()
        return self

    def _beat(self):
        while not self._stop.wait(self.ttl / 3.0):
            try:
                self.request("heartbeat", {"site": self.site_id})
            except Exception:  # noqa: BLE001 — channel retries already ran
                # a dead server ends the job through the main rpc path;
                # the heartbeat thread must not crash the site process
                pass

    def stop(self, leave: bool = True):
        """Stop renewing; with ``leave`` (graceful shutdown) also drop
        the lease immediately so barriers do not wait out the ttl."""
        self._stop.set()
        if leave:
            try:
                self.request("leave", {"site": self.site_id})
            except Exception:  # noqa: BLE001
                pass
        self._thread.join(timeout=2)
