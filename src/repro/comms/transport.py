"""TCP transport — the offline stand-in for the paper's gRPC channel.

Sites are addressed by (host, port) exactly as in FedKBP+ ("each site is
uniquely identified by a combination of its IP address and port number",
§III.A.3), so sites can share one workstation (same IP, distinct ports)
or be spread across machines.  One OS thread per accepted connection;
every message is a framed codec blob (see codec.py).
"""
from __future__ import annotations

import socket
import threading
from typing import Any, Callable, Dict, Optional, Tuple

from repro.comms.codec import decode_message, encode_message, frame, read_frame

Address = Tuple[str, int]

Handler = Callable[[str, Dict[str, Any], Any], Optional[bytes]]


class WireStats:
    """Thread-safe per-message-kind byte counters for a :class:`Server`.

    Counts the framed request/reply bytes that actually cross the wire
    (payload + header; the 8-byte frame prefix excluded), keyed by rpc
    kind — so an ``AggregationServer`` can report exactly how many
    upload bytes it received and download bytes it served, with or
    without compression (see ``benchmarks/comm_bytes.py``).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._by_kind: Dict[str, list] = {}

    def add(self, kind: str, bytes_in: int, bytes_out: int) -> None:
        with self._lock:
            row = self._by_kind.setdefault(kind, [0, 0, 0])
            row[0] += int(bytes_in)
            row[1] += int(bytes_out)
            row[2] += 1

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {k: {"in_bytes": v[0], "out_bytes": v[1], "count": v[2]}
                    for k, v in self._by_kind.items()}


class Server:
    """Threaded request/response TCP server.

    ``handler(kind, meta, tree) -> reply bytes | None`` runs on the
    connection thread; exceptions are returned to the caller as an
    ``error`` message (mirroring gRPC status codes).

    ``decode_writable=True`` hands the handler writable array leaves
    (copies) instead of zero-copy read-only views — for handlers that
    mutate payloads in place (e.g. the streaming aggregation server).
    """

    def __init__(self, host: str, port: int, handler: Handler,
                 decode_writable: bool = False,
                 stats: Optional[WireStats] = None):
        self.addr: Address = (host, port)
        self.handler = handler
        self.decode_writable = decode_writable
        self.stats = stats
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(self.addr)
        self.addr = self._sock.getsockname()       # resolve port 0
        self._sock.listen(64)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._serve, daemon=True)

    def start(self) -> "Server":
        self._thread.start()
        return self

    def _serve(self):
        while not self._stop.is_set():
            try:
                self._sock.settimeout(0.2)
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()

    def _handle_conn(self, conn: socket.socket):
        with conn:
            while not self._stop.is_set():
                try:
                    data = read_frame(conn)
                except (ConnectionError, OSError):
                    return
                kind = "?"
                try:
                    kind, meta, tree = decode_message(
                        data, writable=self.decode_writable)
                    reply = self.handler(kind, meta, tree)
                    if reply is None:
                        reply = encode_message("ok", {}, None)
                except Exception as e:  # noqa: BLE001 — wire errors to caller
                    reply = encode_message("error", {"message": repr(e)}, None)
                if self.stats is not None:
                    self.stats.add(kind, len(data), len(reply))
                try:
                    conn.sendall(frame(reply))
                except OSError:
                    return

    def stop(self):
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=2)


class Channel:
    """Client connection to a peer/coordinator (request → response).

    ``timeout`` bounds the socket wait for a reply and must exceed any
    server-side ``wait_for`` window (the aggregation server blocks a
    download up to ``download_timeout=60`` s before replying with an
    ``error``) — otherwise the client dies on a raw ``socket.timeout``
    instead of receiving the server's actionable error reply.
    """

    def __init__(self, addr: Address, timeout: float = 120.0):
        self.addr = addr
        self._sock = socket.create_connection(addr, timeout=timeout)
        self._lock = threading.Lock()

    def request(self, kind: str, meta: Dict[str, Any], tree: Any = None
                ) -> Tuple[str, Dict[str, Any], Any]:
        data = encode_message(kind, meta, tree)
        with self._lock:
            self._sock.sendall(frame(data))
            reply = read_frame(self._sock)
        rkind, rmeta, rtree = decode_message(reply)
        if rkind == "error":
            raise RuntimeError(f"remote error from {self.addr}: {rmeta['message']}")
        return rkind, rmeta, rtree

    def close(self):
        try:
            self._sock.close()
        except OSError:
            pass
