"""TCP transport — the offline stand-in for the paper's gRPC channel.

Sites are addressed by (host, port) exactly as in FedKBP+ ("each site is
uniquely identified by a combination of its IP address and port number",
§III.A.3), so sites can share one workstation (same IP, distinct ports)
or be spread across machines.  One OS thread per accepted connection;
every message is a framed codec blob (see codec.py).

The wire is *sessioned*: every connection opens with a ``hello``
handshake carrying the protocol version and (when the job has a shared
secret) an HMAC-SHA256 auth token, mirroring the deployment configs of
production FL stacks (``use_tls`` / ``api_key`` / ``max_message_size``).
Three deployability concerns live at this layer, all configured through
one :class:`WireConfig`:

  * **auth + TLS** — ``secret`` gates every rpc behind the handshake
    (bad/missing token → typed :class:`AuthError`); ``tls_cert``/
    ``tls_key`` wrap both ends of the socket in TLS via
    :mod:`ssl.SSLContext` (self-signed cert pinned by the client).
  * **streaming uploads** — a message larger than ``max_message_size``
    crosses the wire as ``__stream_begin__`` / ``__stream_chunk__`` /
    ``__stream_commit__`` frames and is reassembled server-side into the
    byte-identical single-frame encoding before dispatch, so 100MB+
    models never materialize as one frame.  Chunk bytes are accounted to
    the *inner* rpc kind in :class:`WireStats` (an upload streamed in 8
    chunks still counts as one upload of the summed bytes).
  * **retry/reconnect** — a dropped socket is a retriable event, not a
    dead peer: :class:`Channel` reconnects with capped exponential
    backoff and replays the request (servers dedup replayed uploads and
    stream chunks, so a replay is safe).

:class:`FlakyChannel` injects drop/dup/delay faults for tests; see
``docs/architecture.md`` ("Wire protocol") for the full lifecycle.
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import socket
import ssl
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.comms.codec import (PROTOCOL_VERSION, chunk_spans, decode_message,
                               encode_message, frame, read_frame)

Address = Tuple[str, int]

Handler = Callable[[str, Dict[str, Any], Any], Optional[bytes]]

# connection-layer message kinds, handled before the app handler sees them
HELLO = "__hello__"
STREAM_BEGIN = "__stream_begin__"
STREAM_CHUNK = "__stream_chunk__"
STREAM_COMMIT = "__stream_commit__"


# ---------------------------------------------------------------------------
# Typed wire errors
# ---------------------------------------------------------------------------


class WireError(RuntimeError):
    """Base class for typed transport failures.  Subclasses RuntimeError
    so pre-protocol callers that catch/assert RuntimeError keep working;
    the ``code`` rides the error reply so the *client* re-raises the
    same type the server raised."""

    code = "wire"


class AuthError(WireError):
    """Missing/bad auth token in ``hello``, or an rpc before handshake."""

    code = "auth"


class ProtocolVersionError(WireError):
    """Peer speaks a different PROTOCOL_VERSION."""

    code = "version"


class ChannelError(WireError):
    """Channel exhausted its reconnect budget."""

    code = "channel"


class PeerClosed(WireError):
    """The local peer was closed while a receive was pending."""

    code = "closed"


class CorruptFrameError(WireError):
    """A frame arrived but its payload would not decode (byte-level wire
    corruption).  Retriable client-side: the sender's copy is intact, so
    the request is simply resent — only an exhausted retry budget turns
    corruption into a terminal :class:`ChannelError`."""

    code = "corrupt"


_ERROR_CODES = {cls.code: cls for cls in
                (WireError, AuthError, ProtocolVersionError, ChannelError,
                 PeerClosed, CorruptFrameError)}


def raise_remote_error(addr: Address, rmeta: Dict[str, Any]):
    """Re-raise a server error reply client-side, typed via its code."""
    cls = _ERROR_CODES.get(rmeta.get("code"), RuntimeError)
    raise cls(f"remote error from {addr}: {rmeta['message']}")


# ---------------------------------------------------------------------------
# Wire configuration (shared by servers and channels)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WireConfig:
    """Deployable-wire settings: auth, TLS, streaming, retry, faults.

    One instance is threaded from :class:`repro.api.FederatedJob` through
    every server and channel of a job (it is picklable, so tcp site
    processes inherit it).  All fields default to the permissive
    test-rig behavior — a default ``WireConfig()`` speaks the same
    protocol but requires no secret, no TLS and never streams.

    ``secret``            — shared job secret; when set, every channel
                            sends ``HMAC-SHA256(secret, "{version}:{identity}")``
                            in its hello and the server verifies it.
    ``tls_cert``/``tls_key`` — PEM paths; cert alone on clients (pinned
                            trust anchor), cert+key on servers.
    ``max_message_size``  — encoded messages above this many bytes are
                            chunk-streamed instead of sent as one frame.
    ``connect_retries``   — reconnect attempts per request on socket
                            failure (capped exponential backoff between
                            attempts: ``backoff_base * 2**k``, at most
                            ``backoff_cap`` seconds).
    ``flaky``             — fault-injection spec for tests, e.g.
                            ``"drop=0.2,dup=0.1,delay=0.005,corrupt=0.02,
                            seed=3"`` (see :class:`FlakyChannel`).
    """

    secret: Optional[str] = None
    tls_cert: Optional[str] = None
    tls_key: Optional[str] = None
    max_message_size: Optional[int] = None
    connect_retries: int = 4
    backoff_base: float = 0.05
    backoff_cap: float = 2.0
    flaky: Optional[str] = None

    @property
    def tls(self) -> bool:
        return bool(self.tls_cert)

    def token(self, identity: str) -> Optional[str]:
        """Per-identity auth token: HMAC over the protocol version and
        the peer identity, keyed by the shared job secret."""
        if self.secret is None:
            return None
        msg = f"{PROTOCOL_VERSION}:{identity}".encode()
        return hmac.new(self.secret.encode(), msg, hashlib.sha256).hexdigest()

    def check_token(self, identity: str, token: Optional[str]) -> bool:
        want = self.token(identity)
        if want is None or token is None:
            return False
        return hmac.compare_digest(want, str(token))

    def server_ssl(self) -> ssl.SSLContext:
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
        ctx.load_cert_chain(self.tls_cert, self.tls_key)
        return ctx

    def client_ssl(self) -> ssl.SSLContext:
        # self-signed deployment: the client pins the server cert as its
        # trust anchor and skips hostname checks (sites dial by IP)
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_CLIENT)
        ctx.check_hostname = False
        ctx.verify_mode = ssl.CERT_REQUIRED
        ctx.load_verify_locations(self.tls_cert)
        return ctx


def backoff_delay(attempt: int, base: float, cap: float) -> float:
    """Capped exponential backoff before reconnect ``attempt`` (1-based)."""
    return min(cap, base * (2.0 ** (attempt - 1)))


def _decode_checked(data: bytes, writable: bool = False):
    """Decode a frame, surfacing garbled bytes as the typed
    :class:`CorruptFrameError` (its code rides the error reply, so the
    client retries instead of treating the wire as dead — and a barrier
    never hangs on an upload whose bytes were mangled in flight)."""
    try:
        return decode_message(data, writable=writable)
    except Exception as e:  # noqa: BLE001 — any codec failure is corruption
        raise CorruptFrameError(
            f"undecodable frame ({len(data)} bytes): {e!r}") from e


class WireStats:
    """Thread-safe per-message-kind byte counters for a :class:`Server`.

    Counts the framed request/reply bytes that actually cross the wire
    (payload + header; the 8-byte frame prefix excluded), keyed by rpc
    kind — so an ``AggregationServer`` can report exactly how many
    upload bytes it received and download bytes it served, with or
    without compression (see ``benchmarks/comm_bytes.py``).  Streamed
    chunks add their bytes under the inner rpc kind with ``count=0``;
    only the commit increments the rpc count, so a chunked upload still
    counts as one upload.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._by_kind: Dict[str, list] = {}

    def add(self, kind: str, bytes_in: int, bytes_out: int,
            count: int = 1) -> None:
        with self._lock:
            row = self._by_kind.setdefault(kind, [0, 0, 0])
            row[0] += int(bytes_in)
            row[1] += int(bytes_out)
            row[2] += int(count)

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {k: {"in_bytes": v[0], "out_bytes": v[1], "count": v[2]}
                    for k, v in self._by_kind.items()}


class Server:
    """Threaded request/response TCP server.

    ``handler(kind, meta, tree) -> reply bytes | None`` runs on the
    connection thread; exceptions are returned to the caller as an
    ``error`` message (mirroring gRPC status codes) carrying the typed
    error ``code`` when the exception is a :class:`WireError`.

    ``decode_writable=True`` hands the handler writable array leaves
    (copies) instead of zero-copy read-only views — for handlers that
    mutate payloads in place (e.g. the streaming aggregation server).

    With a ``wire`` config the connection layer enforces the protocol:
    TLS wrap on accept, ``hello`` version/token verification before any
    rpc is dispatched, and reassembly of chunk-streamed messages — app
    handlers never see handshake or stream frames.
    """

    def __init__(self, host: str, port: int, handler: Handler,
                 decode_writable: bool = False,
                 stats: Optional[WireStats] = None,
                 wire: Optional[WireConfig] = None):
        self.addr: Address = (host, port)
        self.handler = handler
        self.decode_writable = decode_writable
        self.stats = stats
        self.wire = wire
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(self.addr)
        self.addr = self._sock.getsockname()       # resolve port 0
        # cross-device scale: hundreds of sites dial in within the same
        # round tick (each Peer holds ONE pooled Channel per address, but
        # all of them connect at job start) — a backlog of 64 refused the
        # burst past ~64 concurrent connects.  The kernel clamps this to
        # net.core.somaxconn, so asking high is safe everywhere.
        self._sock.listen(1024)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._serve, daemon=True)

    def start(self) -> "Server":
        self._thread.start()
        return self

    def _serve(self):
        while not self._stop.is_set():
            try:
                self._sock.settimeout(0.2)
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._handle_conn, args=(conn,),
                             daemon=True).start()

    def _hello_reply(self, meta: Dict[str, Any]) -> bytes:
        proto = int(meta.get("proto", -1))
        if proto != PROTOCOL_VERSION:
            raise ProtocolVersionError(
                f"protocol version mismatch: peer speaks v{proto}, "
                f"server speaks v{PROTOCOL_VERSION}")
        if self.wire is not None and self.wire.secret is not None:
            identity = str(meta.get("peer", ""))
            if not self.wire.check_token(identity, meta.get("token")):
                raise AuthError(
                    f"bad or missing auth token for peer {identity!r}")
        return encode_message("welcome", {"proto": PROTOCOL_VERSION}, None)

    def _handle_conn(self, conn: socket.socket):
        if self.wire is not None and self.wire.tls:
            try:
                conn = self.wire.server_ssl().wrap_socket(conn,
                                                          server_side=True)
            except (ssl.SSLError, ConnectionError, OSError):
                return
        # per-connection session state: handshake flag + stream buffers
        need_auth = self.wire is not None and self.wire.secret is not None
        authed = not need_auth
        streams: Dict[str, Dict[str, Any]] = {}
        with conn:
            while not self._stop.is_set():
                try:
                    data = read_frame(conn)
                except (ConnectionError, OSError):
                    return
                stat_kind, n_rpc = "?", 1
                try:
                    kind, meta, tree = _decode_checked(
                        data, writable=self.decode_writable)
                    stat_kind = kind
                    if kind == HELLO:
                        reply = self._hello_reply(meta)
                        authed = True
                    elif not authed:
                        raise AuthError("hello handshake required before rpcs")
                    elif kind == STREAM_BEGIN:
                        streams[meta["stream"]] = {"kind": meta["kind"],
                                                   "parts": []}
                        stat_kind, n_rpc = meta["kind"], 0
                        reply = encode_message("ok", {}, None)
                    elif kind == STREAM_CHUNK:
                        st = streams[meta["stream"]]
                        stat_kind, n_rpc = st["kind"], 0
                        # replayed/duplicated chunks are idempotent: only
                        # the next expected seq extends the buffer
                        if int(meta["seq"]) == len(st["parts"]):
                            st["parts"].append(np.asarray(tree["b"]).tobytes())
                        reply = encode_message("ok", {}, None)
                    elif kind == STREAM_COMMIT:
                        st = streams.pop(meta["stream"])
                        stat_kind = st["kind"]
                        whole = b"".join(st["parts"])
                        if len(whole) != int(meta["total"]):
                            raise WireError(
                                f"stream reassembly mismatch: got "
                                f"{len(whole)} bytes, expected {meta['total']}")
                        ikind, imeta, itree = _decode_checked(
                            whole, writable=self.decode_writable)
                        reply = self.handler(ikind, imeta, itree)
                        if reply is None:
                            reply = encode_message("ok", {}, None)
                    else:
                        reply = self.handler(kind, meta, tree)
                        if reply is None:
                            reply = encode_message("ok", {}, None)
                except Exception as e:  # noqa: BLE001 — wire errors to caller
                    emeta = {"message": repr(e)}
                    if isinstance(e, WireError):
                        emeta["code"] = e.code
                    reply = encode_message("error", emeta, None)
                if self.stats is not None:
                    self.stats.add(stat_kind, len(data), len(reply),
                                   count=n_rpc)
                try:
                    conn.sendall(frame(reply))
                except OSError:
                    return

    def stop(self):
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=2)


class Channel:
    """Client connection to a peer/coordinator (request → response).

    ``timeout`` bounds the socket wait for a reply and must exceed any
    server-side ``wait_for`` window (the aggregation server blocks a
    download up to ``download_timeout=60`` s before replying with an
    ``error``) — otherwise the client dies on a raw ``socket.timeout``
    instead of receiving the server's actionable error reply.

    Every (re)connect replays the ``hello`` handshake.  A socket failure
    mid-request reconnects with capped exponential backoff and replays
    the request from the start (for a streamed request: the whole
    begin/chunk/commit sequence, which resets the server-side buffer).
    Auth/version rejections are terminal — they raise immediately and
    are never retried.
    """

    #: overridable for tests that need to speak a wrong version
    proto_version = PROTOCOL_VERSION

    def __init__(self, addr: Address, timeout: float = 120.0,
                 wire: Optional[WireConfig] = None, identity: str = ""):
        self.addr = addr
        self.timeout = timeout
        self.wire = wire or WireConfig()
        self.identity = identity
        self._lock = threading.Lock()
        self._sock: Optional[socket.socket] = None
        self._stream_seq = 0
        last = None
        for attempt in range(self.wire.connect_retries + 1):
            if attempt:
                time.sleep(backoff_delay(attempt, self.wire.backoff_base,
                                         self.wire.backoff_cap))
            try:
                self._connect()
                return
            except CorruptFrameError as e:
                last = e                       # garbled hello: resend it
                self._close_sock()
            except WireError:
                raise                          # auth/version: not retriable
            except (ConnectionError, OSError) as e:
                last = e
                self._close_sock()
        raise ChannelError(f"could not connect to {self.addr} after "
                           f"{self.wire.connect_retries + 1} attempts: {last!r}")

    # -- connection lifecycle ------------------------------------------------

    def _connect(self):
        sock = socket.create_connection(self.addr, timeout=self.timeout)
        sock.settimeout(self.timeout)
        if self.wire.tls:
            sock = self.wire.client_ssl().wrap_socket(
                sock, server_hostname=self.addr[0])
        self._sock = sock
        try:
            self._hello()
        except BaseException:
            self._close_sock()
            raise

    def _hello(self):
        meta: Dict[str, Any] = {"proto": self.proto_version,
                                "peer": self.identity}
        token = self.wire.token(self.identity)
        if token is not None:
            meta["token"] = token
        self._send_frame(frame(encode_message(HELLO, meta, None)))
        rkind, rmeta, _ = decode_message(self._recv_frame())
        if rkind == "error":
            raise_remote_error(self.addr, rmeta)

    def _close_sock(self):
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    # frame primitives — FlakyChannel overrides these to inject faults
    def _send_frame(self, framed: bytes):
        self._sock.sendall(framed)

    def _recv_frame(self) -> bytes:
        return read_frame(self._sock)

    # -- requests ------------------------------------------------------------

    def request(self, kind: str, meta: Dict[str, Any], tree: Any = None
                ) -> Tuple[str, Dict[str, Any], Any]:
        data = encode_message(kind, meta, tree)
        mms = self.wire.max_message_size
        last = None
        # wire corruption of the FINAL frame surfaces here (the server's
        # typed "corrupt" error reply); our copy of the request is
        # intact, so resend it — like the reconnect replay, but without
        # tearing down the connection
        for attempt in range(self.wire.connect_retries + 1):
            if attempt:
                time.sleep(backoff_delay(attempt, self.wire.backoff_base,
                                         self.wire.backoff_cap))
            with self._lock:
                if mms is not None and len(data) > mms:
                    reply = self._roundtrip(
                        self._stream_frames(kind, data, mms))
                else:
                    reply = self._roundtrip([frame(data)])
            rkind, rmeta, rtree = decode_message(reply)
            if rkind == "error":
                if rmeta.get("code") == CorruptFrameError.code:
                    last = rmeta.get("message")
                    continue
                raise_remote_error(self.addr, rmeta)
            return rkind, rmeta, rtree
        raise ChannelError(
            f"request {kind!r} to {self.addr} still corrupt after "
            f"{self.wire.connect_retries + 1} attempts: {last}")

    def _stream_frames(self, kind: str, data: bytes, mms: int) -> List[bytes]:
        """Cut one encoded message into begin/chunk/commit frames."""
        sid = f"{self.identity or 'chan'}-{self._stream_seq}"
        self._stream_seq += 1
        frames = [frame(encode_message(STREAM_BEGIN,
                                       {"stream": sid, "kind": kind}, None))]
        for seq, (a, b) in enumerate(chunk_spans(len(data), mms)):
            chunk = np.frombuffer(data[a:b], dtype=np.uint8)
            frames.append(frame(encode_message(
                STREAM_CHUNK, {"stream": sid, "seq": seq}, {"b": chunk})))
        frames.append(frame(encode_message(
            STREAM_COMMIT, {"stream": sid, "total": len(data)}, None)))
        return frames

    def _roundtrip(self, frames: List[bytes]) -> bytes:
        """Send a frame sequence, reading one reply per frame; return the
        final reply.  Socket failures reconnect + replay the sequence."""
        last = None
        for attempt in range(self.wire.connect_retries + 1):
            if attempt:
                time.sleep(backoff_delay(attempt, self.wire.backoff_base,
                                         self.wire.backoff_cap))
            try:
                if self._sock is None:
                    self._connect()
                reply = b""
                for i, framed in enumerate(frames):
                    self._send_frame(framed)
                    reply = self._recv_frame()
                    if i < len(frames) - 1:
                        rkind, rmeta, _ = decode_message(reply)
                        if rkind == "error":
                            raise_remote_error(self.addr, rmeta)
                return reply
            except CorruptFrameError as e:
                # a mid-stream frame was garbled on the wire: resend the
                # whole sequence (STREAM_BEGIN resets the server buffer;
                # the connection itself is healthy, so keep it)
                last = e
            except WireError:
                raise                          # typed rejections: terminal
            except (ConnectionError, OSError) as e:
                last = e
                self._close_sock()
        raise ChannelError(f"request to {self.addr} failed after "
                           f"{self.wire.connect_retries + 1} attempts: {last!r}")

    def close(self):
        self._close_sock()


class FlakyChannel(Channel):
    """Fault-injection wrapper over :class:`Channel` for wire tests.

    ``drop``    — probability a frame send kills the connection instead
                  (exercises reconnect + replay).
    ``dup``     — probability a frame is sent twice (exercises server-side
                  dedup of replayed uploads / stream chunks; the duplicate
                  reply is drained so the stream stays in sync).
    ``delay``   — uniform[0, delay) seconds of extra latency per send.
    ``corrupt`` — probability one payload byte of a frame is flipped in
                  flight (the 8-byte length prefix stays intact, so the
                  server reads a whole frame whose decode then fails —
                  the typed ``corrupt`` reply drives the client's resend
                  path).  A flip can land where the codec still decodes:
                  a valid-but-wrong model that only the server-side
                  upload sanitation catches — which is exactly the
                  layering under test (wire-level corruption vs
                  model-level attack).

    Deterministic per ``seed``; activated end-to-end via
    ``WireConfig.flaky = "drop=0.2,dup=0.1,corrupt=0.02,seed=3"`` (see
    :func:`make_channel`).
    """

    def __init__(self, addr: Address, *, drop: float = 0.0, dup: float = 0.0,
                 delay: float = 0.0, corrupt: float = 0.0, seed: int = 0,
                 **kw):
        self.drop, self.dup, self.delay = drop, dup, delay
        self.corrupt = corrupt
        self._frng = np.random.default_rng(seed)
        self._dup_pending = 0
        super().__init__(addr, **kw)

    @staticmethod
    def parse_spec(spec: str) -> Dict[str, Any]:
        params: Dict[str, Any] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            key, _, val = part.partition("=")
            params[key.strip()] = (int(val) if key.strip() == "seed"
                                   else float(val))
        return params

    def _connect(self):
        self._dup_pending = 0                  # replies die with the socket
        super()._connect()

    def _send_frame(self, framed: bytes):
        if self.delay:
            time.sleep(float(self._frng.uniform(0.0, self.delay)))
        if self._frng.random() < self.drop:
            self._close_sock()
            raise ConnectionError("flaky wire: frame dropped")
        if (self.corrupt and len(framed) > 9
                and self._frng.random() < self.corrupt):
            # flip one payload byte past the 8-byte length prefix: the
            # frame still arrives whole, its contents are garbage
            pos = int(self._frng.integers(8, len(framed)))
            mangled = bytearray(framed)
            mangled[pos] ^= 0xFF
            framed = bytes(mangled)
        if self._frng.random() < self.dup:
            super()._send_frame(framed)
            self._dup_pending += 1
        super()._send_frame(framed)

    def _recv_frame(self) -> bytes:
        reply = super()._recv_frame()
        while self._dup_pending:               # discard duplicates' replies
            super()._recv_frame()
            self._dup_pending -= 1
        return reply


def make_channel(addr: Address, timeout: float = 120.0,
                 wire: Optional[WireConfig] = None,
                 identity: str = "") -> Channel:
    """The one Channel constructor call sites use: honors the wire
    config's fault-injection spec so flaky-wire tests exercise the very
    same peer/coordinator code paths as the clean wire."""
    if wire is not None and wire.flaky:
        return FlakyChannel(addr, **FlakyChannel.parse_spec(wire.flaky),
                            timeout=timeout, wire=wire, identity=identity)
    return Channel(addr, timeout=timeout, wire=wire, identity=identity)
