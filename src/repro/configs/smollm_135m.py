"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M].

30L, d_model 576, GQA 9 heads / 3 KV, SwiGLU d_ff 1536, vocab 49152.
Llama-architecture small model.
"""
from repro.configs.base import ModelConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke", arch_type="dense",
        num_layers=2, d_model=96, num_heads=3, num_kv_heads=1,
        d_ff=256, vocab_size=256, tie_embeddings=True,
        source="hf:HuggingFaceTB/SmolLM-135M",
    )


mesh_for = simple_mesh_for(sites_per_pod=16, fsdp=1)
precision_for = simple_precision_for(PrecisionConfig.mixed())
