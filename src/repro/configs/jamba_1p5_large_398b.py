"""Jamba-1.5-Large 398B [arXiv:2403.19887].

72L, d_model 8192, hybrid Mamba+attention with a 1:7 attention:Mamba
interleave (one attention layer per 8-layer period), GQA 64 heads / 8 KV,
MoE 16 experts top-2 on every other layer, FFN/expert hidden 24576,
vocab 65536.
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    mixer="mamba",                 # default mixer; attention every 8th layer
    attn_layer_period=8,
    attn_layer_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    moe_layer_period=2,
    moe_layer_offset=1,
    tie_embeddings=False,
    source="arXiv:2403.19887",
)


def reduced() -> ModelConfig:
    """2-layer smoke: one Mamba+dense layer, one attention+MoE layer."""
    return ModelConfig(
        name="jamba-smoke", arch_type="hybrid",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256,
        mixer="mamba", attn_layer_period=2, attn_layer_offset=1,
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk_size=8),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=256),
        moe_layer_period=2, moe_layer_offset=1,
        tie_embeddings=False,
        source="arXiv:2403.19887",
    )


# 398B: full pod per FL site
mesh_for = simple_mesh_for(sites_per_pod=1, fsdp=16)
precision_for = simple_precision_for(PrecisionConfig.bf16_train())
