"""Configuration dataclasses for the FedKBP+ reproduction framework.

Every architecture in ``src/repro/configs/<id>.py`` instantiates a
:class:`ModelConfig`; every launchable job combines it with a
:class:`FederationConfig` (the paper's FL hyper-parameters), a
:class:`MeshConfig` (how FL sites map onto the TPU mesh) and an
:class:`InputShape` (one of the four assigned workload shapes).

All configs are frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs for specific mixer / ffn families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (DeepSeek-V2 / Qwen3-MoE / Jamba)."""

    num_experts: int
    top_k: int
    d_expert: int                      # hidden size of each routed expert
    num_shared_experts: int = 0        # DeepSeek-V2 style always-on experts
    d_shared: int = 0                  # hidden size of the shared expert(s)
    router_aux_coef: float = 0.01      # load-balance auxiliary loss weight
    router_jitter: float = 0.0
    normalize_router_weights: bool = True

    @property
    def d_shared_total(self) -> int:
        return self.num_shared_experts * self.d_shared


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention configuration."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class Rwkv6Config:
    """RWKV-6 "Finch" mixer configuration (data-dependent decay)."""

    head_dim: int = 64
    decay_lora_rank: int = 64
    tokenshift_lora_rank: int = 32
    gate_lora_rank: int = 64
    chunk_size: int = 128              # chunked-recurrence block length


@dataclass(frozen=True)
class MambaConfig:
    """Mamba (S6) selective-scan mixer configuration (Jamba layers)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None      # default: ceil(d_model / 16)
    chunk_size: int = 128


# ---------------------------------------------------------------------------
# The model config
# ---------------------------------------------------------------------------

MIXER_KINDS = ("attn", "mla", "rwkv6", "mamba")
FFN_KINDS = ("dense", "moe")


@dataclass(frozen=True)
class LayerSpec:
    """Resolved per-layer block structure."""

    mixer: str                         # one of MIXER_KINDS
    ffn: str                           # one of FFN_KINDS
    sliding_window: Optional[int] = None   # None = global attention

    def __post_init__(self):
        assert self.mixer in MIXER_KINDS, self.mixer
        assert self.ffn in FFN_KINDS, self.ffn


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-only (or SA-Net, see ``sanet.py``) model definition.

    ``layer_pattern`` hooks let hybrid architectures (Jamba's 1:7
    attention:Mamba interleave, Gemma-3's 5:1 local:global windows,
    DeepSeek-V2's dense-first-layer MoE) be expressed declaratively.
    """

    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default: d_model // num_heads
    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None        # window size for local layers
    global_attn_every: Optional[int] = None     # e.g. 6 => layers 5,11,.. global
    mla: Optional[MLAConfig] = None
    # --- mixer family ------------------------------------------------------
    mixer: str = "attn"                # default mixer for all layers
    attn_layer_period: Optional[int] = None     # hybrid: 1 attn layer per period
    attn_layer_offset: int = 0
    rwkv: Optional[Rwkv6Config] = None
    mamba: Optional[MambaConfig] = None
    # --- FFN family ---------------------------------------------------------
    moe: Optional[MoEConfig] = None
    moe_layer_period: int = 1          # MoE on layers where i % period == offset
    moe_layer_offset: int = 0
    first_layer_dense_ff: Optional[int] = None  # DeepSeek-V2 dense layer 0
    ffn_activation: str = "swiglu"     # swiglu | geglu | gelu | relu_sq
    # --- embeddings / heads --------------------------------------------------
    tie_embeddings: bool = True
    num_codebooks: int = 1             # musicgen: parallel EnCodec streams
    pos_embedding: str = "rope"        # rope | sinusoidal | none
    norm_eps: float = 1e-6
    pad_vocab_multiple: int = 128      # pad embeddings/logits so the vocab
                                       # dim shards (granite: 49155 -> 49280)
    # --- citations -----------------------------------------------------------
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        if m <= 1 or self.vocab_size == 0:
            return self.vocab_size
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.mla is not None:
            return self.mla.v_head_dim
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def layer_spec(self, i: int) -> LayerSpec:
        """Resolve the block structure of layer ``i``."""
        # mixer
        if self.attn_layer_period is not None:
            mixer = "attn" if (i % self.attn_layer_period == self.attn_layer_offset) else self.mixer
        else:
            mixer = self.mixer
        if mixer == "attn" and self.mla is not None:
            mixer = "mla"
        # ffn
        ffn = "dense"
        if self.moe is not None and (i % self.moe_layer_period == self.moe_layer_offset):
            ffn = "moe"
        if i == 0 and self.first_layer_dense_ff is not None:
            ffn = "dense"
        # sliding window (gemma3: 5 local then 1 global)
        window = None
        if mixer in ("attn",) and self.sliding_window is not None:
            if self.global_attn_every is None:
                window = self.sliding_window
            elif (i + 1) % self.global_attn_every != 0:
                window = self.sliding_window
        return LayerSpec(mixer=mixer, ffn=ffn, sliding_window=window)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        return tuple(self.layer_spec(i) for i in range(self.num_layers))

    def dense_ff_for_layer(self, i: int) -> int:
        if i == 0 and self.first_layer_dense_ff is not None:
            return self.first_layer_dense_ff
        return self.d_ff

    # -- parameter counting (exact, mirrors init) ------------------------------
    def param_count(self) -> int:
        from repro.models.transformer import count_params  # lazy import
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Federation / mesh / workload configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FederationConfig:
    """Paper §II hyper-parameters.

    ``strategy`` ∈ {fedavg, fedprox, gcml, individual, pooled}.
    ``local_steps`` is the number of SGD steps per FL round (the paper
    exchanges each epoch; we parameterize).  ``site_case_counts`` are the
    m_i of Eq. 1 (defaults: uniform).  ``max_dropout_sites`` is N_max of
    Algorithm 2.
    """

    num_sites: int = 8
    strategy: str = "fedavg"
    local_steps: int = 1
    rounds: int = 100
    # FedProx (Eq. 2)
    prox_mu: float = 0.01
    # GCML (Eq. 3)
    gcml_lambda: float = 0.5
    gcml_contrast_beta: float = 1.0
    # Algorithm 2
    max_dropout_sites: int = 0
    dropout_scenario: str = "disconnect"   # disconnect | shutdown
    site_case_counts: Optional[Tuple[int, ...]] = None

    def case_weights(self):
        import numpy as np
        if self.site_case_counts is None:
            w = np.ones((self.num_sites,), dtype=np.float32)
        else:
            assert len(self.site_case_counts) == self.num_sites
            w = np.asarray(self.site_case_counts, dtype=np.float32)
        return w / w.sum()


@dataclass(frozen=True)
class MeshConfig:
    """How FL sites map onto the pod mesh.

    The FL view refactors the pod's 256 chips freely:
    ``sites_per_pod * fsdp * model_parallel == 256`` — the default keeps
    the production (data=16, model=16) split (sites*fsdp == 16, model == 16),
    but e.g. rwkv6's hillclimb uses (16, 4, 4): less tensor parallel, more
    in-site data parallel (see EXPERIMENTS.md §Perf).
    """

    sites_per_pod: int = 16
    fsdp: int = 1
    model_parallel: int = 16
    multi_pod: bool = False
    data_axis_size: int = 16
    num_pods: int = 2

    @classmethod
    def for_sites(cls, sites: int, chip_budget: int = 16) -> "MeshConfig":
        """Nominal FL mesh for ``sites`` sites over a ``chip_budget``-chip
        data axis: leftover chips become in-site fsdp when the budget
        divides evenly, else each site runs unsharded (fsdp=1)."""
        fsdp = chip_budget // sites if sites and chip_budget % sites == 0 else 1
        return cls(sites_per_pod=sites, fsdp=fsdp,
                   data_axis_size=sites * fsdp)

    def validate_for_pod(self, chips_per_pod: int = 256) -> None:
        """Checked when an actual device mesh is built (make_fl_mesh);
        CPU-simulation contexts may carry nominal layouts."""
        got = self.sites_per_pod * self.fsdp * self.model_parallel
        assert got == chips_per_pod, (
            f"sites({self.sites_per_pod}) * fsdp({self.fsdp}) * "
            f"model({self.model_parallel}) = {got} != chips/pod ({chips_per_pod})")

    @property
    def total_sites(self) -> int:
        return self.sites_per_pod * (self.num_pods if self.multi_pod else 1)

    @property
    def total_devices(self) -> int:
        per_pod = self.data_axis_size * self.model_parallel
        return per_pod * (self.num_pods if self.multi_pod else 1)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class PrecisionConfig:
    """Dtype policy. Giant archs drop optimizer state to bf16 to fit HBM."""

    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    logits_fp32: bool = True

    @staticmethod
    def bf16_train() -> "PrecisionConfig":
        return PrecisionConfig("bfloat16", "bfloat16", "bfloat16")

    @staticmethod
    def mixed() -> "PrecisionConfig":
        return PrecisionConfig("bfloat16", "bfloat16", "float32")


@dataclass(frozen=True)
class JobConfig:
    """A fully-specified launchable job."""

    model: ModelConfig
    federation: FederationConfig
    mesh: MeshConfig
    shape: InputShape
    precision: PrecisionConfig = field(default_factory=PrecisionConfig)
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    seed: int = 0
    remat: bool = False
    microbatch: Optional[int] = None   # microbatch size per site (None = whole)

    def replace(self, **kw) -> "JobConfig":
        return dataclasses.replace(self, **kw)
