"""DeepSeek-V2 236B [arXiv:2405.04434].

60L, d_model 5120, 128 heads (MLA: kv_lora_rank 512), MoE: 2 shared + 160
routed experts top-6, expert hidden 1536, first layer dense FFN (12288),
vocab 102400.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536,
                  num_shared_experts=2, d_shared=1536),
    first_layer_dense_ff=12288,
    tie_embeddings=False,
    rope_theta=10000.0,
    source="arXiv:2405.04434",
)


def reduced() -> ModelConfig:
    """2-layer CPU smoke variant of the same family (MLA + shared/routed MoE)."""
    return ModelConfig(
        name="deepseek-v2-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=256,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      num_shared_experts=1, d_shared=64),
        first_layer_dense_ff=128,
        tie_embeddings=False,
        source="arXiv:2405.04434",
    )


# 236B: a full pod is one FL site (hierarchical FL: each hospital owns a pod)
mesh_for = simple_mesh_for(sites_per_pod=1, fsdp=16)
precision_for = simple_precision_for(PrecisionConfig.bf16_train())
