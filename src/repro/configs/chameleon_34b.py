"""Chameleon-34B [arXiv:2405.09818].

48L, d_model 8192, GQA 64 heads / 8 KV, d_ff 22016, vocab 65536 (joint
text + VQ image tokens — early fusion).  The VQ-VAE image tokenizer is a
STUB per the assignment carve-out: ``input_specs()`` supplies token ids
drawn from the joint vocabulary (image patches are just tokens to the
decoder — that IS the early-fusion design).  Chameleon uses qk-norm for
training stability.
"""
from repro.configs.base import ModelConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    tie_embeddings=False,
    source="arXiv:2405.09818",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", arch_type="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=256, qk_norm=True, tie_embeddings=False,
        source="arXiv:2405.09818",
    )


mesh_for = simple_mesh_for(sites_per_pod=4, fsdp=4)
precision_for = simple_precision_for(PrecisionConfig.mixed())
