"""Helpers shared by the per-arch config modules."""
from __future__ import annotations

from repro.configs.base import InputShape, MeshConfig, PrecisionConfig


def simple_mesh_for(sites_per_pod: int, fsdp: int):
    def mesh_for(shape: InputShape, multi_pod: bool = False) -> MeshConfig:
        if shape.kind != "train":
            # serving uses the aggregated global model on the raw production
            # mesh; site layout is irrelevant but keep fsdp for weight sharding
            return MeshConfig(sites_per_pod=1, fsdp=16, multi_pod=multi_pod)
        return MeshConfig(sites_per_pod=sites_per_pod, fsdp=fsdp, multi_pod=multi_pod)
    return mesh_for


def simple_precision_for(train: PrecisionConfig, serve_param_dtype: str = "bfloat16"):
    def precision_for(shape: InputShape) -> PrecisionConfig:
        if shape.kind == "train":
            return train
        return PrecisionConfig(param_dtype=serve_param_dtype,
                               compute_dtype="bfloat16",
                               opt_state_dtype="bfloat16")
    return precision_for
