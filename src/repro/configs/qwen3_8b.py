"""Qwen3-8B [hf:Qwen/Qwen3-8B].

36L, d_model 4096, GQA 32 heads / 8 KV (head_dim 128), qk-norm,
SwiGLU d_ff 12288, vocab 151936.
"""
from repro.configs.base import ModelConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-8B",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256, qk_norm=True, tie_embeddings=False,
        source="hf:Qwen/Qwen3-8B",
    )


mesh_for = simple_mesh_for(sites_per_pod=16, fsdp=1)
precision_for = simple_precision_for(PrecisionConfig.mixed())
