"""Granite-3.0 2B base [hf:ibm-granite/granite-3.0-2b-base].

40L, d_model 2048, GQA 32 heads / 8 KV, SwiGLU d_ff 8192, vocab 49155.
"""
from repro.configs.base import ModelConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="granite-3-2b",
    arch_type="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=256, tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


mesh_for = simple_mesh_for(sites_per_pod=16, fsdp=1)
precision_for = simple_precision_for(PrecisionConfig.mixed())
