"""RWKV-6 "Finch" 7B [arXiv:2404.05892].

32L, d_model 4096 (attention-free: data-dependent-decay linear recurrence),
channel-mix hidden 14336, vocab 65536.
"""
from repro.configs.base import ModelConfig, PrecisionConfig, Rwkv6Config
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,              # d_model / head_dim(64)
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    mixer="rwkv6",
    rwkv=Rwkv6Config(head_dim=64),
    pos_embedding="none",
    tie_embeddings=False,
    source="arXiv:2404.05892",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", arch_type="ssm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256,
        mixer="rwkv6", rwkv=Rwkv6Config(head_dim=32, decay_lora_rank=16,
                                        tokenshift_lora_rank=8, gate_lora_rank=16,
                                        chunk_size=8),
        pos_embedding="none", tie_embeddings=False,
        source="arXiv:2404.05892",
    )


mesh_for = simple_mesh_for(sites_per_pod=16, fsdp=1)
precision_for = simple_precision_for(PrecisionConfig.mixed())
