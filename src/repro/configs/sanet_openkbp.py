"""SA-Net on OpenKBP-shaped dose prediction — the paper's own configuration.

Backbone per Figure 5 (ResSE encoder/decoder + scale attention + deep
supervision); input = CT + PTV/OAR masks (11 channels), output = 3D dose.
This config participates in the FL benchmarks (Fig 7/8/9) rather than the
LLM dry-run shapes (see ``registry.SHAPE_SKIPS``).
"""
from repro.configs.base import MeshConfig, ModelConfig, PrecisionConfig
from repro.models.sanet import SANetConfig

# The assigned-architecture machinery expects a ModelConfig; SA-Net's true
# config is SANET below. This stanza records the volumetric task metadata.
CONFIG = ModelConfig(
    name="sanet-openkbp",
    arch_type="conv3d",
    num_layers=4,                # encoder levels
    d_model=24,                  # base filters
    num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=0,
    source="OpenKBP (Babier et al. 2021), SA-Net (Yuan 2021)",
)

SANET = SANetConfig(in_channels=11, out_channels=1, base_filters=24,
                    num_levels=4, task="dose")

SANET_SEG = SANetConfig(in_channels=4, out_channels=4, base_filters=24,
                        num_levels=4, task="segmentation")   # BraTS: 4 MRI mods, 4 classes

SANET_OAR = SANetConfig(in_channels=1, out_channels=2, base_filters=24,
                        num_levels=4, task="segmentation")   # PanSeg: T1 MRI, pancreas/bg


def reduced() -> SANetConfig:
    return SANetConfig(in_channels=3, out_channels=1, base_filters=8,
                       num_levels=2, task="dose")


def reduced_seg() -> SANetConfig:
    return SANetConfig(in_channels=2, out_channels=3, base_filters=8,
                       num_levels=2, task="segmentation")


def mesh_for(shape, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(sites_per_pod=16, fsdp=1, multi_pod=multi_pod)


def precision_for(shape) -> PrecisionConfig:
    return PrecisionConfig()
