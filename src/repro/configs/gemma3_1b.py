"""Gemma-3 1B [hf:google/gemma-3-1b-pt].

26L, d_model 1152, GQA 4 heads / 1 KV (head_dim 256), GeGLU d_ff 6912,
vocab 262144, 5:1 local:global attention (sliding window 512, every 6th
layer global), qk-norm, 128k context (run at long_500k via the
sliding-window ring-buffer cache).
"""
from repro.configs.base import ModelConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    qk_norm=True,
    sliding_window=512,
    global_attn_every=6,
    rope_theta=1_000_000.0,
    ffn_activation="geglu",
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=256, qk_norm=True,
        sliding_window=16, global_attn_every=2,
        ffn_activation="geglu", tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    )


mesh_for = simple_mesh_for(sites_per_pod=16, fsdp=1)
precision_for = simple_precision_for(PrecisionConfig.mixed())
