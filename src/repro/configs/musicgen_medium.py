"""MusicGen-medium [arXiv:2306.05284].

48L, d_model 1536, MHA 24 heads (kv 24), GELU d_ff 6144, decoder-only over
4 parallel EnCodec codebooks of vocab 2048 each (embeddings summed, one
output head per codebook), sinusoidal positions.

The EnCodec conv audio codec is a STUB per the assignment carve-out:
``input_specs()`` supplies the [B, L, 4] token streams (the "delay
pattern" interleave is a data-layout choice upstream of the decoder).
"""
from repro.configs.base import ModelConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    num_codebooks=4,
    pos_embedding="sinusoidal",
    ffn_activation="gelu",
    tie_embeddings=False,
    source="arXiv:2306.05284",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", arch_type="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=64, num_codebooks=2,
        pos_embedding="sinusoidal", ffn_activation="gelu",
        tie_embeddings=False,
        source="arXiv:2306.05284",
    )


mesh_for = simple_mesh_for(sites_per_pod=16, fsdp=1)
precision_for = simple_precision_for(PrecisionConfig.mixed())
