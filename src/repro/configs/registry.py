"""Architecture registry: maps ``--arch <id>`` to its config module.

Each ``src/repro/configs/<id>.py`` exports:
  * ``CONFIG``      — the exact assigned :class:`ModelConfig` (source cited)
  * ``reduced()``   — a CPU-smoke variant (≤2 layers, d_model ≤ 512, ≤4 experts)
  * ``mesh_for(shape, multi_pod)``   — FL site layout on the pod mesh
  * ``precision_for(shape)``         — dtype policy
"""
from __future__ import annotations

import importlib
from typing import List

ARCH_IDS: List[str] = [
    "deepseek_v2_236b",
    "rwkv6_7b",
    "jamba_1p5_large_398b",
    "qwen3_8b",
    "qwen3_moe_30b_a3b",
    "chameleon_34b",
    "gemma3_1b",
    "smollm_135m",
    "granite_3_2b",
    "musicgen_medium",
    "sanet_openkbp",          # the paper's own backbone (dose prediction)
]

# user-facing aliases (the assignment spelling)
ALIASES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "qwen3-8b": "qwen3_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "chameleon-34b": "chameleon_34b",
    "gemma3-1b": "gemma3_1b",
    "smollm-135m": "smollm_135m",
    "granite-3-2b": "granite_3_2b",
    "musicgen-medium": "musicgen_medium",
    "sanet-openkbp": "sanet_openkbp",
}


def get_arch(name: str):
    """Load a config module by id or alias."""
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod_name}")


# (arch, shape) pairs skipped in the dry-run, with reasons (DESIGN.md §5).
LONG_500K_SKIPS = {
    "deepseek_v2_236b": "MLA compresses the KV cache but attention is full; no sub-quadratic variant",
    "qwen3_8b": "pure full attention",
    "qwen3_moe_30b_a3b": "pure full attention",
    "chameleon_34b": "pure full attention (early-fusion decoder)",
    "smollm_135m": "pure full attention",
    "granite_3_2b": "pure full attention",
    "musicgen_medium": "pure full attention",
    "sanet_openkbp": "SA-Net is a 3D conv net; sequence shapes do not apply (dose volumes only)",
}

# SA-Net is the paper's conv backbone: token-sequence shapes other than its own
# volumetric task do not apply.
SHAPE_SKIPS = {
    "sanet_openkbp": {
        "prefill_32k": "conv model: no autoregressive serving",
        "decode_32k": "conv model: no autoregressive serving",
        "long_500k": "conv model: no autoregressive serving",
    },
}


def is_skipped(arch_id: str, shape_name: str):
    """Returns a reason string if (arch, shape) is skipped, else None."""
    if shape_name == "long_500k" and arch_id in LONG_500K_SKIPS:
        return LONG_500K_SKIPS[arch_id]
    return SHAPE_SKIPS.get(arch_id, {}).get(shape_name)
