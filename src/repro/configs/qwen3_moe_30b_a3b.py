"""Qwen3-30B-A3B MoE [hf:Qwen/Qwen3-30B-A3B].

48L, d_model 2048, GQA 32 heads / 4 KV (head_dim 128), qk-norm,
MoE 128 experts top-8 with expert hidden 768, vocab 151936.
"""
from repro.configs.base import ModelConfig, MoEConfig, PrecisionConfig
from repro.configs.common import simple_mesh_for, simple_precision_for

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768),
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=256, qk_norm=True,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


mesh_for = simple_mesh_for(sites_per_pod=8, fsdp=2)
precision_for = simple_precision_for(PrecisionConfig.mixed())
