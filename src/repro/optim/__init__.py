from repro.optim.optimizers import (adamw, sgd, Optimizer, apply_updates,
                                    global_norm, clip_by_global_norm)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine

__all__ = ["adamw", "sgd", "Optimizer", "apply_updates", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup_cosine"]
