"""Minimal-but-production optimizer library (optax-style pure functions).

Implemented in-repo (no optax dependency) so the optimizer state dtype
policy (fp32 vs bf16 moments for the ≥236B archs) and the site-stacked
vmap path are fully under our control.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params) -> (updates, state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def sgd(lr, momentum: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """SGD with optional (heavy-ball) momentum. ``lr`` may be a schedule fn."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: (momentum * m.astype(jnp.float32)
                              + g.astype(jnp.float32)).astype(state_dtype),
                state["mom"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m.astype(jnp.float32), mom)
            return updates, {"step": step, "mom": mom}
        updates = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return updates, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """AdamW with bias correction and configurable moment dtype."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m32 / c1
            vhat = v32 / c2
            u = -(lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p.astype(jnp.float32)))
            return u, m32.astype(state_dtype), v32.astype(state_dtype)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["mu"])
        flat_v = tdef.flatten_up_to(state["nu"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)
