from repro.checkpoint.store import CheckpointStore, load_pytree, save_pytree

__all__ = ["CheckpointStore", "save_pytree", "load_pytree"]
