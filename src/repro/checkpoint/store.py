"""Checkpointing for federated state (per-site + global models).

npz-based with a JSON manifest; atomic writes (tmp + rename); retains
the last ``keep`` round checkpoints per tag.  Site checkpoints store the
stacked tree once (not S copies of the global model) — exactly what the
FL round state is.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: Path, tree: Any):
    """Atomic npz save of a pytree (flat path-keyed arrays + treedef)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, __treedef__=np.frombuffer(str(treedef).encode(), dtype=np.uint8),
                 **flat)
        os.replace(tmp + ".npz" if not tmp.endswith(".npz") and os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)


def load_pytree(path: Path, like: Any) -> Any:
    """Load into the structure of ``like`` (leaf order = like's paths)."""
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = list(_flatten_with_paths(like).keys())
    leaves = [data[p] for p in flat_paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    """Round-indexed checkpoint directory with a manifest."""

    def __init__(self, root: Path, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self.root.mkdir(parents=True, exist_ok=True)
        self.manifest_path = self.root / "manifest.json"
        self.manifest: Dict[str, Any] = {"rounds": {}}
        if self.manifest_path.exists():
            self.manifest = json.loads(self.manifest_path.read_text())

    def save(self, tag: str, round_index: int, tree: Any, meta: Optional[dict] = None):
        fn = self.root / f"{tag}_round{round_index:06d}.npz"
        save_pytree(fn, tree)
        rounds = self.manifest["rounds"].setdefault(tag, [])
        rounds.append({"round": round_index, "file": fn.name, "meta": meta or {}})
        # retention
        while len(rounds) > self.keep:
            old = rounds.pop(0)
            old_fn = self.root / old["file"]
            if old_fn.exists():
                old_fn.unlink()
        self.manifest_path.write_text(json.dumps(self.manifest, indent=2))

    def latest(self, tag: str, like: Any):
        rounds = self.manifest["rounds"].get(tag, [])
        if not rounds:
            return None, -1
        rec = rounds[-1]
        return load_pytree(self.root / rec["file"], like), rec["round"]
