"""Checkpointing for federated state (per-site + global models).

npz-based with a JSON manifest; atomic writes (tmp + rename) for BOTH
payloads and the manifest, so a crash at any instant leaves the store
loadable: a partial tmp file is ignored on read, and a manifest entry
whose payload never landed is skipped by :meth:`CheckpointStore.latest`.
Retains the last ``keep`` round checkpoints per tag.  Site checkpoints
store the stacked tree once (not S copies of the global model) — exactly
what the FL round state is.

Crash-resumable jobs (``FederatedJob.run(resume=True)``) layer on top:
the driver keeps a store at ``checkpoint_dir`` ("global" +
"driver_state" tags) and each socket-transport site process keeps its
own sub-store at ``checkpoint_dir/site{i}`` — independent manifests, so
concurrently-crashing writers never corrupt each other.  The resume
round is the newest round present in *every* participating store (see
``repro.api``); :meth:`load` fetches an exact round, :meth:`saved_rounds`
enumerates what survived.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: Path, tree: Any):
    """Atomic npz save of a pytree (flat path-keyed arrays + treedef).

    Writes through an explicit file handle — ``np.savez`` given a *name*
    appends ``.npz``, which previously forced rename juggling that could
    pick the wrong candidate; a handle writes exactly where told.  The
    tmp file lands in the target directory so ``os.replace`` is a
    same-filesystem atomic rename; a crash inside the write window
    leaves only a ``*.tmp`` dropping that readers never look at.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __treedef__=np.frombuffer(str(treedef).encode(),
                                                  dtype=np.uint8), **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_pytree(path: Path, like: Any) -> Any:
    """Load into the structure of ``like`` (leaf order = like's paths)."""
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = list(_flatten_with_paths(like).keys())
    leaves = [data[p] for p in flat_paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _atomic_write_text(path: Path, text: str):
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


class CheckpointStore:
    """Round-indexed checkpoint directory with a manifest.

    Thread-safe for concurrent saves from one process (the aggregation
    server checkpoints from a connection thread while the driver owns
    the same store).  Cross-process writers must use distinct roots —
    see the per-site sub-stores in ``repro.api``.
    """

    def __init__(self, root: Path, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self.root.mkdir(parents=True, exist_ok=True)
        self.manifest_path = self.root / "manifest.json"
        self.manifest: Dict[str, Any] = {"rounds": {}}
        self._lock = threading.Lock()
        if self.manifest_path.exists():
            self.manifest = json.loads(self.manifest_path.read_text())

    def save(self, tag: str, round_index: int, tree: Any,
             meta: Optional[dict] = None):
        with self._lock:
            fn = self.root / f"{tag}_round{round_index:06d}.npz"
            save_pytree(fn, tree)
            rounds = self.manifest["rounds"].setdefault(tag, [])
            # a re-save of the same round (server ckpt grid meeting the
            # final explicit save) replaces its entry instead of growing
            rounds[:] = [r for r in rounds if r["round"] != round_index]
            rounds.append({"round": round_index, "file": fn.name,
                           "meta": meta or {}})
            # retention
            while len(rounds) > self.keep:
                old = rounds.pop(0)
                old_fn = self.root / old["file"]
                if old_fn.exists():
                    old_fn.unlink()
            _atomic_write_text(self.manifest_path,
                               json.dumps(self.manifest, indent=2))

    def _records(self, tag: str) -> List[dict]:
        """Manifest records whose payload actually exists on disk — an
        entry whose file was lost to a crash window is skipped, not
        raised on."""
        return [rec for rec in self.manifest["rounds"].get(tag, [])
                if (self.root / rec["file"]).exists()]

    def saved_rounds(self, tag: str) -> List[int]:
        return sorted(rec["round"] for rec in self._records(tag))

    def latest(self, tag: str, like: Any):
        recs = self._records(tag)
        if not recs:
            return None, -1
        rec = max(recs, key=lambda r: r["round"])
        return load_pytree(self.root / rec["file"], like), rec["round"]

    def meta(self, tag: str, round_index: int) -> dict:
        """A checkpoint's manifest metadata without loading its payload —
        resume paths validate the ``engine`` tag here before committing
        to a structure-shaped load."""
        for rec in self._records(tag):
            if rec["round"] == round_index:
                return rec.get("meta", {})
        raise KeyError(f"no checkpoint for tag {tag!r} round {round_index} "
                       f"in {self.root}")

    def load(self, tag: str, round_index: int, like: Any
             ) -> Tuple[Any, dict]:
        """Load the checkpoint for an exact round; returns (tree, meta)."""
        for rec in self._records(tag):
            if rec["round"] == round_index:
                return (load_pytree(self.root / rec["file"], like),
                        rec.get("meta", {}))
        raise KeyError(f"no checkpoint for tag {tag!r} round {round_index} "
                       f"in {self.root}")
