"""Shared bits for the paper-figure benchmarks (CPU-scale synthetic).

The FL execution itself lives behind :class:`repro.api.FederatedJob` —
each benchmark declares jobs and reads their :class:`JobResult`; no
benchmark hand-rolls a round loop.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)
