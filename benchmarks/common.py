"""Shared harness for the paper-figure benchmarks (CPU-scale synthetic)."""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig, MeshConfig
from repro.core import federation as F
from repro.core.dropout import SiteAvailability
from repro.models import sanet as sanet_mod
from repro.optim import adamw

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"
ARTIFACTS.mkdir(exist_ok=True)


def make_sanet_ctx(strategy, sites, case_weights=None, lr=3e-3, task="dose",
                   scenario="disconnect"):
    scfg = (sanet_mod.SANetConfig(in_channels=4, out_channels=1, base_filters=8,
                                  num_levels=2, task="dose") if task == "dose"
            else sanet_mod.SANetConfig(in_channels=2, out_channels=3,
                                       base_filters=8, num_levels=2,
                                       task="segmentation"))
    if task == "dose":
        loss = lambda p, b: sanet_mod.dose_loss(p, b, scfg)

        def logits_fn(params, batch):
            pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
            # dose regression viewed as binary high/low for DCML regions
            logits = jnp.concatenate([pred, -pred], axis=-1)
            labels = (batch["dose"][..., 0] > 0.5).astype(jnp.int32)
            return logits, labels
    else:
        loss = lambda p, b: sanet_mod.segmentation_loss(p, b, scfg)

        def logits_fn(params, batch):
            pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
            return pred, batch["labels"]

    fed = FederationConfig(num_sites=sites, strategy=strategy,
                           site_case_counts=case_weights,
                           dropout_scenario=scenario)
    ctx = F.FLContext(
        fed=fed, mesh=MeshConfig(sites_per_pod=sites, fsdp=1,
                                 data_axis_size=sites),
        case_weights=jnp.asarray(fed.case_weights()),
        loss_fn=loss, logits_fn=logits_fn, optimizer=adamw(lr),
        grad_clip=1.0, dcml_lr=lr)
    return ctx, scfg


def run_fl(ctx, scfg, gen, rounds, batch=2, local_steps=1, max_dropout=0,
           seed=0, eval_fn=None, pool_sites=False):
    """Generic FL loop; returns (loss history, final state, eval results).

    ``pool_sites=True`` implements the paper's Pooled baseline faithfully:
    the SAME per-site heterogeneous data is generated, then concatenated
    into one site's batch (centralized aggregation of all site data).
    """
    init_fn = lambda k: sanet_mod.sanet_init(k, scfg)
    state = F.init_fl_state(ctx, init_fn, jax.random.PRNGKey(seed))
    rnd = jax.jit(F.build_fl_round(ctx))
    avail = SiteAvailability(ctx.fed.num_sites, max_dropout, seed=seed + 7)
    rng = np.random.default_rng(seed)
    history = []
    for r in range(rounds):
        b = jax.tree.map(jnp.asarray, gen.stacked_batches(r, local_steps, batch))
        if pool_sites:
            # [S, K, B, ...] -> [1, K, S*B, ...]
            b = jax.tree.map(
                lambda x: jnp.reshape(jnp.swapaxes(x, 0, 1),
                                      (1, x.shape[1], -1) + x.shape[3:]), b)
        ri = F.make_round_inputs(ctx, avail, rng, r)
        if ctx.fed.strategy == "gcml":
            ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
            ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
        state, m = rnd(state, b, ri)
        history.append(float(jnp.mean(m["loss"])))
    evals = eval_fn(state, ctx) if eval_fn else None
    return history, state, evals
