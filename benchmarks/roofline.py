"""§Roofline — aggregate the dry-run artifacts into the roofline table.

Reads ``benchmarks/artifacts/dryrun_*.json`` produced by
``repro.launch.dryrun`` and emits, per (arch × shape × mesh):
compute/memory/collective seconds, the dominant term, MODEL_FLOPS
(6·N·D train, 2·N_active·D serve), and the useful-compute ratio.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ARTIFACTS


def model_flops_for(rec) -> float:
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import get_arch
    from repro.models.transformer import count_params
    cfg = get_arch(rec["arch"]).CONFIG
    shape = INPUT_SHAPES[rec["shape"]]
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                       # decode: ONE token/seq
    return 2.0 * n_active * tokens


def run(quick: bool = False):
    rows = []
    for fn in sorted(ARTIFACTS.glob("dryrun_*.json")):
        rec = json.loads(fn.read_text())
        r = rec["roofline"]
        mf = model_flops_for(rec)
        hlo_total = rec["flops"] * rec["devices"]
        rows.append({
            "name": rec["name"],
            "devices": rec["devices"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bound": r["bound"],
            "model_flops": mf,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "hbm_gib_per_dev": (rec["memory"]["argument_bytes"]
                                + rec["memory"]["temp_bytes"]
                                + rec["memory"]["output_bytes"]) / 2 ** 30,
        })
    out = {"table": "roofline", "rows": rows}
    (ARTIFACTS / "roofline_table.json").write_text(json.dumps(out, indent=2))
    if rows:
        worst = min(rows, key=lambda x: x["useful_ratio"])
        derived = f"rows={len(rows)};worst_useful={worst['name']}:{worst['useful_ratio']:.3f}"
    else:
        derived = "rows=0 (run repro.launch.dryrun first)"
    return derived, out


if __name__ == "__main__":
    d, out = run()
    print(d)
    for r in out["rows"]:
        print(f"{r['name']:48s} {r['bound']:10s} "
              f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
              f"x={r['collective_s']:.3f}s useful={r['useful_ratio']:.3f}")
