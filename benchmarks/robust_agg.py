"""Byzantine robustness — attack × aggregator grid on the stacked engine.

f = ⌊S/4⌋ malicious sites (the classical trimmed-mean breakdown regime)
attack the site-update seam while the server aggregates with plain
fedavg vs the robust rules.  The headline numbers (``checks``):

* the robust rule ends within 10% of the clean fedavg reference under
  EVERY attack in the grid, and
* plain fedavg degrades ≥ 2× under the worst attack.

Attack phenomenology on the synthetic tasks (worth knowing before
reading the table): ``noise:s:f`` and ``scale:c:f`` push the global
AWAY from the data manifold and blow plain fedavg up within a couple of
rounds.  ``sign_flip:f`` instead shrinks the global toward the zero
model by (S−2f)/S per round — catastrophic for a well-trained model,
but on short synthetic-token runs the zero model (uniform logits) is
close to the achievable loss, so sign_flip separates the rules only at
convergence scale.  The grid keeps sign_flip anyway to pin down that
asymmetry; the degradation check is taken over the worst attack.

Writes ``BENCH_robustness.json``; the tcp chaos smoke
(examples/chaos_smoke.py) reproduces the trimmed-vs-clean tolerance
over real sockets with a flaky channel and a SIGKILLed site.
"""
from __future__ import annotations

import json
import sys

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig

SITES = 8
F = SITES // 4          # 2 — the acceptance regime f = floor(S/4)

ATTACKS = ["none", f"sign_flip:{F}", f"scale:10:{F}", f"noise:1:{F}"]
AGGREGATORS = ["fedavg", f"trimmed:{F}", "median"]


def _loss(job: FederatedJob) -> float:
    return float(job.run().history[-1]["loss"])


def run(quick: bool = False):
    rounds = 3 if quick else 4
    local_steps = 4 if quick else 6
    task = TaskConfig(kind="tokens", arch="smollm-135m", sites=SITES,
                      batch=2, seq=16, heterogeneity=0.3, seed=0)
    base = dict(task=task, strategy="fedavg", rounds=rounds,
                local_steps=local_steps, lr=1e-2, seed=0, verbose=False)

    grid = {}
    clean = _loss(FederatedJob(**base))
    grid["none"] = {"fedavg": clean}
    for attack in ATTACKS[1:]:
        row = {}
        for agg in AGGREGATORS:
            row[agg] = _loss(FederatedJob(**base, adversary=attack,
                                          aggregator=agg))
        grid[attack] = row

    trimmed = f"trimmed:{F}"
    worst_fedavg = max(grid[a]["fedavg"] for a in ATTACKS[1:])
    worst_trimmed = max(grid[a][trimmed] for a in ATTACKS[1:])
    worst_median = max(grid[a]["median"] for a in ATTACKS[1:])
    checks = {
        "trimmed_within_10pct_of_clean_all_attacks":
            worst_trimmed <= 1.10 * clean,
        "median_within_10pct_of_clean_all_attacks":
            worst_median <= 1.10 * clean,
        "fedavg_degrades_2x_worst_attack": worst_fedavg >= 2.0 * clean,
    }
    out = {"sites": SITES, "f": F, "rounds": rounds,
           "local_steps": local_steps, "clean_loss": clean,
           "grid": grid, "checks": checks}
    (ARTIFACTS / "BENCH_robustness.json").write_text(json.dumps(out, indent=2))
    derived = (f"clean={clean:.3f};worst_fedavg={worst_fedavg:.3f};"
               f"worst_trimmed={worst_trimmed:.3f};"
               + ";".join(f"{k}={v}" for k, v in checks.items()))
    return derived, out


if __name__ == "__main__":
    print(run(quick="--quick" in sys.argv)[0])
