"""Compiled round engine vs the retired per-round loop (ISSUE 4).

The tentpole perf claim: fusing K federated rounds into one donated
``lax.scan`` (with on-device compression on the int8 path) removes the
per-round host surface — Python dispatch, host RNG + batch conversion,
the per-site device→host copy + numpy quantize/fold of the compressed
loop — that gated the stacked simulator.

Protocol: every timed variant is ONE fresh ``repro.launch.train``
process (the way a user actually runs a 20-round job), so each engine
pays its own real host-side cost profile; timing comes from the job's
own artifact (``wall_s`` spans the round loop only, ``compile_s`` is
the one-time jit compile measured separately since the ISSUE-4 timing
fix).  Speedups compare ``wall_s − compile_s``:

  * ``loop``        — the retired per-round driver (``--round-engine loop``)
  * ``scan``        — the compiled engine, host batches (one H2D per chunk)
  * ``scan+device`` — batches/masks from the threaded on-device PRNG
  * ``loop/scan int8`` — the compressed stacked path before/after

  * ``loop/scan buffered`` — the FedBuff arrival loop, host vs traced

plus an in-process chunk-size sweep.  Writes ``BENCH_round_engine.json``
with rounds/s, per-round host↔device byte estimates, and the speedup
checks.  On this 2-core CPU container the sync-barrier path is bounded
by the XLA compute floor (both engines execute the identical per-round
program, so the scan's win there is only the removed host surface ≈
no-regression); the wall-clock multiples show on the paths with a real
per-round host surface: int8 (per-site D2H copy + numpy codec + fold,
≥3×) and buffered (per-arrival host loop).  On an accelerator the
dispatch/PCIe-bound regime the ISSUE targets applies to every path.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.common import ARTIFACTS

SRC = Path(__file__).resolve().parents[1] / "src"


SITES, BATCH, SEQ = 8, 1, 8      # small config: overhead-dominated rounds


def _run_cli(tmp: Path, name: str, rounds: int, extra) -> dict:
    """One fresh training process; returns the job's own result JSON."""
    out = tmp / name
    argv = [sys.executable, "-m", "repro.launch.train",
            "--arch", "smollm-135m", "--reduced", "--sites", str(SITES),
            "--batch", str(BATCH), "--seq", str(SEQ), "--het", "0.3",
            "--rounds", str(rounds), "--quiet", "--out", str(out)] + extra
    env = {**os.environ,
           "PYTHONPATH": str(SRC) + os.pathsep + os.environ.get(
               "PYTHONPATH", "")}
    subprocess.run(argv, check=True, env=env)
    rec = json.loads((out / "train_fedavg.json").read_text())
    exec_s = max(rec["wall_s"] - rec["compile_s"], 1e-9)
    return {"wall_s": rec["wall_s"], "compile_s": rec["compile_s"],
            "exec_s": exec_s,
            "step_s_sum": float(sum(h.get("step_s", 0.0)
                                    for h in rec["history"])),
            "rounds_per_s": len(rec["history"]) / exec_s,
            "final_loss": float(rec["final_loss"]),
            "upload_bytes": (rec.get("comm") or {}).get("upload_bytes")}


def _chunk_sweep(rounds: int) -> dict:
    """In-process chunk-size sweep (informational: chunking is an
    execution knob; parity across K is tier-1 tested)."""
    from repro.api import FederatedJob, TaskConfig
    task = TaskConfig(kind="tokens", arch="smollm-135m", reduced=True,
                      sites=SITES, batch=BATCH, seq=SEQ, heterogeneity=0.3,
                      seed=0)
    base = FederatedJob(task=task, strategy="fedavg", rounds=rounds,
                        lr=1e-3, seed=0)
    base.run()                                   # warm the process once
    sweep = {}
    for ck in sorted({1, 2, 5, rounds // 2, rounds}):
        if 0 < ck <= rounds:
            t0 = time.perf_counter()
            res = base.replace(chunk_rounds=ck).run()
            exec_s = max(time.perf_counter() - t0 - res.compile_s, 1e-9)
            sweep[str(ck)] = rounds / exec_s
    return sweep


def run(quick: bool = False):
    import tempfile
    rounds = 6 if quick else 20
    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)
        loop = _run_cli(tmp, "loop", rounds, ["--round-engine", "loop"])
        scan = _run_cli(tmp, "scan", rounds, ["--round-engine", "scan"])
        scan_dev = _run_cli(tmp, "scan_dev", rounds,
                            ["--round-engine", "scan", "--device-data"])
        loop8 = _run_cli(tmp, "loop8", rounds,
                         ["--round-engine", "loop", "--compression", "int8"])
        scan8 = _run_cli(tmp, "scan8", rounds,
                         ["--round-engine", "scan", "--compression", "int8"])
        loop_buf = _run_cli(tmp, "loop_buf", rounds,
                            ["--round-engine", "loop", "--scheduler",
                             "buffered", "--buffer-k", "2"])
        scan_buf = _run_cli(tmp, "scan_buf", rounds,
                            ["--round-engine", "scan", "--scheduler",
                             "buffered", "--buffer-k", "2"])
    sweep = _chunk_sweep(rounds)

    speedup_sync = loop["exec_s"] / min(scan["exec_s"], scan_dev["exec_s"])
    speedup_int8 = loop8["exec_s"] / scan8["exec_s"]
    speedup_buf = loop_buf["exec_s"] / scan_buf["exec_s"]
    loss_ok = bool(abs(scan["final_loss"] - loop["final_loss"])
                   <= 0.02 * abs(loop["final_loss"]))

    batch_h2d = SITES * BATCH * SEQ * 4           # int32 tokens, S·B·L
    out = {
        "bench": f"round_engine scan-vs-loop ({rounds}-round stacked "
                 "fedavg, fresh process per variant)",
        "rounds": rounds, "sites": SITES,
        "note": "Speedups are wall−compile, each variant a fresh process. "
                "The sync-barrier path is bounded by this container's "
                "2-core XLA compute floor (the loop and the scan run the "
                "identical per-round program, so fusing rounds mostly "
                "removes the per-round HOST surface); the paths with a "
                "real host surface — int8's per-site device→host copy + "
                "numpy codec + accumulator fold, buffered's per-arrival "
                "host loop — show the engine's wall-clock win.",
        "loop": loop, "scan": scan, "scan_device_data": scan_dev,
        "loop_int8": loop8, "scan_int8": scan8,
        "loop_buffered": loop_buf, "scan_buffered": scan_buf,
        "chunk_sweep_rounds_per_s": sweep,
        "host_device_bytes_per_round": {
            "loop_batches_h2d": batch_h2d,
            "scan_batches_h2d": batch_h2d,       # chunk-batched, same volume
            "scan_device_data_h2d": 0,           # PRNG-threaded on device
            # the legacy int8 loop pulls every site's fp32 model off the
            # device each round to quantize on the host; the scan pulls 0
            # (int8 payload ≈ N bytes, so ×4 ≈ the fp32 volume copied)
            "loop_int8_model_d2h": (loop8["upload_bytes"] or 0) * 4
                // max(rounds, 1),
            "scan_int8_model_d2h": 0,
        },
        "speedup": {"sync_exec": speedup_sync, "int8_exec": speedup_int8,
                    "buffered_exec": speedup_buf,
                    "sync_wall": loop["wall_s"] / min(scan["wall_s"],
                                                      scan_dev["wall_s"]),
                    "int8_wall": loop8["wall_s"] / scan8["wall_s"]},
        "checks": {"scan_int8_speedup_ge_3": bool(speedup_int8 >= 3.0),
                   "scan_buffered_faster": bool(speedup_buf >= 1.2),
                   "scan_sync_no_regression": bool(speedup_sync >= 0.85),
                   "same_final_loss": loss_ok},
    }
    (ARTIFACTS / "BENCH_round_engine.json").write_text(
        json.dumps(out, indent=2))
    derived = (f"int8_speedup={speedup_int8:.1f}x;"
               f"buffered_speedup={speedup_buf:.1f}x;"
               f"sync_speedup={speedup_sync:.1f}x;"
               f"scan_rounds_per_s={scan['rounds_per_s']:.1f}")
    return derived, out


if __name__ == "__main__":
    print(run(quick="--quick" in sys.argv)[0])
