"""Aggregate-latency + server-memory: legacy paths vs AggregationEngine.

"Before" is the seed's three disjoint Eq. 1 implementations:

  * per-leaf ``jnp.einsum`` tree_map (the old ``fedavg_aggregate``),
  * the aggregation server's pure-Python scaled-copy loop, which
    materializes one fp32 model per site (O(S·N) server memory).

"After" is the engine's single padded [S, N] reduction (jnp fallback on
this CPU container; the Pallas kernel path is timed under the
interpreter only for a small N so CI stays fast) and the server's O(N)
streaming accumulator.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS
from repro.core.agg_engine import AggregationEngine, StreamingAccumulator
from repro.core.stacking import weighted_mean
from repro.models.sanet import SANetConfig, sanet_init


def _legacy_server_average(uploads, weights):
    """The seed's O(S·N) server loop (kept here as the 'before' baseline)."""
    tot = sum(weights[i] for i in uploads)
    acc = None
    for i, tree in uploads.items():
        w = weights[i] / tot
        scaled = jax.tree.map(lambda x: np.asarray(x, np.float32) * w, tree)
        acc = scaled if acc is None else jax.tree.map(np.add, acc, scaled)
    return acc


def _time(fn, iters):
    fn()                                             # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6   # µs


def run(quick: bool = False):
    s = 8
    scfg = SANetConfig(in_channels=4, out_channels=1, base_filters=8,
                      num_levels=2)
    params = sanet_init(jax.random.PRNGKey(0), scfg)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (s,) + x.shape) *
        (1.0 + 0.01 * jnp.arange(s).reshape((s,) + (1,) * x.ndim)), params)
    n = sum(x.size for x in jax.tree.leaves(params))
    cw = jnp.asarray(np.random.default_rng(0).uniform(0.5, 2.0, s), jnp.float32)
    w = cw / jnp.sum(cw)
    iters = 3 if quick else 10

    # -- device-side latency: per-leaf einsum vs one flat reduction ---------
    legacy = jax.jit(lambda t: weighted_mean(t, w))
    engine = AggregationEngine(use_pallas=False)
    eng_fn = jax.jit(lambda t: engine.global_mean(t, w))
    us_legacy = _time(lambda: jax.block_until_ready(legacy(stacked)), iters)
    us_engine = _time(lambda: jax.block_until_ready(eng_fn(stacked)), iters)

    # Pallas path correctness + latency on a small buffer (interpret mode on
    # CPU is faithful-but-slow, so keep N modest and call it out in the JSON)
    pal = AggregationEngine(use_pallas=True, interpret=True, block_n=4096)
    small = {"w": jax.random.normal(jax.random.PRNGKey(1), (s, 10_000))}
    pal_fn = jax.jit(lambda t: pal.global_mean(t, w))
    ref_small = weighted_mean(small, w)
    np.testing.assert_allclose(
        np.asarray(pal_fn(small)["w"]), np.asarray(ref_small["w"]),
        rtol=1e-5, atol=1e-5)
    us_pallas_small = _time(lambda: jax.block_until_ready(pal_fn(small)),
                            max(1, iters // 3))

    # -- server-side: O(S·N) loop vs O(N) streaming accumulator -------------
    host = jax.tree.map(np.asarray, stacked)
    uploads = {i: jax.tree.map(lambda x: np.array(x[i], np.float32), host)
               for i in range(s)}
    weights = {i: float(cw[i]) for i in range(s)}
    us_srv_legacy = _time(lambda: _legacy_server_average(uploads, weights), iters)

    def _stream():
        acc = StreamingAccumulator()
        for i in range(s):
            # copy models the way decode_writable hands them to the server
            acc.fold(jax.tree.map(np.copy, uploads[i]), weights[i])
        return acc.finalize()
    us_srv_stream = _time(_stream, iters)
    acc = StreamingAccumulator()
    acc.fold(jax.tree.map(np.copy, uploads[0]), 1.0)
    stream_bytes = acc.nbytes
    legacy_bytes = s * sum(x.nbytes for x in jax.tree.leaves(uploads[0]))

    out = {
        "bench": "agg_engine Eq.1 before/after",
        "sites": s, "params": int(n),
        "device_us": {"legacy_per_leaf_einsum": us_legacy,
                      "engine_flat_jnp": us_engine,
                      "engine_pallas_interpret_small_n": us_pallas_small,
                      "pallas_note": "interpret mode (CPU container); "
                                     "compiled on TPU/GPU"},
        "server_us": {"legacy_scaled_copies": us_srv_legacy,
                      "streaming_accumulator": us_srv_stream},
        "server_resident_bytes": {"before_o_sn": legacy_bytes,
                                  "after_o_n": stream_bytes,
                                  "ratio": legacy_bytes / stream_bytes},
    }
    (ARTIFACTS / "agg_engine.json").write_text(json.dumps(out, indent=2))
    derived = (f"engine_us={us_engine:.0f};legacy_us={us_legacy:.0f};"
               f"server_mem_ratio={legacy_bytes / stream_bytes:.1f}x")
    return derived, out


if __name__ == "__main__":
    print(run()[0])
