"""Paper Figs 11/12 — FedAvg vs FedProx vs Individual vs Pooled on the
tumor-segmentation task (BraTS-shaped, real per-site case skew), plus the
communication/time model replacing the NVFlare wall-clock comparison
(no GPUs here): per-round exchanged bytes and a parallel-vs-sequential
round-time model.
"""
from __future__ import annotations

import json

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig
from repro.data.partition import BRATS_SITE_CASES

SITES = 8
VOL = (16, 16, 16)


def run(quick: bool = False):
    rounds = 6 if quick else 14
    results = {}
    task = TaskConfig(kind="seg", volume=VOL, sites=SITES, heterogeneity=0.2,
                      seed=2, batch=2,
                      site_pools=tuple(max(c // 6, 1) for c in BRATS_SITE_CASES))
    for strategy in ["fedavg", "fedprox", "individual", "pooled"]:
        pooled = strategy == "pooled"
        job = FederatedJob(
            task=task, strategy=strategy, rounds=rounds, lr=5e-3,
            case_counts=None if pooled else tuple(BRATS_SITE_CASES))
        res = job.run()
        results[strategy] = {"loss_curve": res.losses,
                             "final_loss": res.final_loss,
                             "wall_s": res.wall_s}

    # model-exchange bytes per round (the NVFlare-efficiency axis we CAN
    # measure): FedAvg/FedProx move 2*N_params per site per round
    # (upload+download); GCML moves N_params per pair.
    import jax
    from repro.models.sanet import sanet_init
    params = sanet_init(jax.random.PRNGKey(0), task.model_config())
    n_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    comm = {
        "param_bytes": int(n_bytes),
        "fedavg_bytes_per_round": int(2 * SITES * n_bytes),
        "fedprox_bytes_per_round": int(2 * SITES * n_bytes),
        "gcml_bytes_per_round": int((SITES // 2) * n_bytes),
    }
    out = {"figure": "Fig 11/12", "results": results, "comm": comm}
    checks = {
        "fedavg_beats_individual":
            results["fedavg"]["final_loss"] < results["individual"]["final_loss"],
        "fedprox_beats_individual":
            results["fedprox"]["final_loss"] < results["individual"]["final_loss"],
        "fedprox_converges_slower_or_equal":
            results["fedprox"]["loss_curve"][rounds // 2]
            >= results["fedavg"]["loss_curve"][rounds // 2] - 0.05,
    }
    out["checks"] = checks
    (ARTIFACTS / "strategy_compare.json").write_text(json.dumps(out, indent=2))
    derived = ";".join(f"{k}={v['final_loss']:.4f}" for k, v in results.items())
    return derived, out


if __name__ == "__main__":
    print(run()[0])
