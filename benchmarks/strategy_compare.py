"""Paper Figs 11/12 — FedAvg vs FedProx vs Individual vs Pooled on the
tumor-segmentation task (BraTS-shaped, real per-site case skew), plus the
communication/time model replacing the NVFlare wall-clock comparison
(no GPUs here): per-round exchanged bytes and a parallel-vs-sequential
round-time model.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import ARTIFACTS, make_sanet_ctx, run_fl
from repro.data.partition import BRATS_SITE_CASES
from repro.data.synthetic import SegTaskGenerator

SITES = 8
VOL = (16, 16, 16)


def run(quick: bool = False):
    rounds = 6 if quick else 14
    results = {}
    for strategy in ["fedavg", "fedprox", "individual", "pooled"]:
        pooled = strategy == "pooled"
        sites = 1 if pooled else SITES
        cw = None if pooled else tuple(BRATS_SITE_CASES)
        ctx, scfg = make_sanet_ctx(strategy, sites, case_weights=cw,
                                   task="seg", lr=5e-3)
        gen = SegTaskGenerator(volume=VOL, in_channels=2, num_classes=3,
                               num_sites=SITES, heterogeneity=0.2, seed=2,
                               site_pools=tuple(max(c // 6, 1)
                                                for c in BRATS_SITE_CASES))
        t0 = time.time()
        hist, state, _ = run_fl(ctx, scfg, gen, rounds, batch=2,
                                pool_sites=pooled)
        wall = time.time() - t0
        results[strategy] = {"loss_curve": hist, "final_loss": hist[-1],
                             "wall_s": wall}

    # model-exchange bytes per round (the NVFlare-efficiency axis we CAN
    # measure): FedAvg/FedProx move 2*N_params per site per round
    # (upload+download); GCML moves N_params per pair.
    import jax
    from repro.models.sanet import sanet_init
    params = sanet_init(jax.random.PRNGKey(0), make_sanet_ctx("fedavg", 2)[1])
    n_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    comm = {
        "param_bytes": int(n_bytes),
        "fedavg_bytes_per_round": int(2 * SITES * n_bytes),
        "fedprox_bytes_per_round": int(2 * SITES * n_bytes),
        "gcml_bytes_per_round": int((SITES // 2) * n_bytes),
    }
    out = {"figure": "Fig 11/12", "results": results, "comm": comm}
    (ARTIFACTS / "strategy_compare.json").write_text(json.dumps(out, indent=2))
    checks = {
        "fedavg_beats_individual":
            results["fedavg"]["final_loss"] < results["individual"]["final_loss"],
        "fedprox_beats_individual":
            results["fedprox"]["final_loss"] < results["individual"]["final_loss"],
        "fedprox_converges_slower_or_equal":
            results["fedprox"]["loss_curve"][rounds // 2]
            >= results["fedavg"]["loss_curve"][rounds // 2] - 0.05,
    }
    out["checks"] = checks
    (ARTIFACTS / "strategy_compare.json").write_text(json.dumps(out, indent=2))
    derived = ";".join(f"{k}={v['final_loss']:.4f}" for k, v in results.items())
    return derived, out


if __name__ == "__main__":
    print(run()[0])
