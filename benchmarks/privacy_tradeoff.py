"""Privacy/utility trade-off: convergence vs (ε, δ), mask overhead.

Two measurements behind ``BENCH_privacy.json``:

  * **DP sweep** — the same stacked-scan FedAvg token job at increasing
    noise multipliers σ (clip fixed).  Each run reports its accountant ε
    from ``JobResult.privacy``; the sweep is the paper-style
    convergence-vs-ε curve: ε falls monotonically in σ while the final
    loss drifts up from the noise-free baseline.
  * **Secure-agg overhead** — one thread-transport job plain and one
    masked, same seed.  Masked uploads are fixed-point int64 (2× the
    fp32 payload — the price of exact modular cancellation), and the
    trajectory must still match the plaintext run to fixed-point
    precision (~2⁻³² relative): privacy costs bytes, not accuracy.

Checks: ε monotone in σ and matching the analytic closed form, masked
trajectory ≡ plain trajectory, masked byte ratio ≈ 2×.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import ARTIFACTS

SITES, BATCH, SEQ = 4, 2, 16
CLIP = 1.0
SIGMAS = (0.3, 0.6, 1.2)


def _job(**kw):
    from repro.api import FederatedJob, TaskConfig
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=SITES,
                        batch=BATCH, seq=SEQ, heterogeneity=0.3, seed=0),
        strategy="fedavg", lr=1e-3, seed=0, verbose=False)
    base.update(kw)
    return FederatedJob(**base)


def _run(job):
    t0 = time.perf_counter()
    res = job.run()
    return res, time.perf_counter() - t0


def run(quick: bool = False):
    from repro.privacy import analytic_gaussian_epsilon
    rounds = 3 if quick else 6

    # -- DP sweep: convergence vs ε ------------------------------------
    base_res, _ = _run(_job(rounds=rounds))
    sweep = [{"sigma": 0.0, "epsilon": None,
              "final_loss": float(base_res.final_loss),
              "losses": [float(x) for x in base_res.losses]}]
    eps_ok = True
    for sigma in SIGMAS:
        res, _ = _run(_job(rounds=rounds, dp_clip=CLIP,
                           dp_noise_multiplier=sigma))
        p = res.privacy
        ref = analytic_gaussian_epsilon(sigma, p["steps"], p["delta"])
        eps_ok &= ref - 1e-9 <= p["epsilon"] <= ref * 1.01
        sweep.append({"sigma": sigma, "epsilon": p["epsilon"],
                      "delta": p["delta"], "steps": p["steps"],
                      "final_loss": float(res.final_loss),
                      "losses": [float(x) for x in res.losses]})
    eps_vals = [r["epsilon"] for r in sweep[1:]]
    monotone = all(a > b for a, b in zip(eps_vals, eps_vals[1:]))

    # -- secure-agg overhead: bytes vs fidelity ------------------------
    plain_res, plain_wall = _run(_job(rounds=rounds, transport="thread"))
    mask_res, mask_wall = _run(_job(rounds=rounds, transport="thread",
                                    secure_agg=True))
    parity = bool(np.allclose(mask_res.losses, plain_res.losses, rtol=1e-4))
    pb = plain_res.comm["upload_bytes"]
    mb = mask_res.comm["upload_bytes"]
    ratio = mb / max(pb, 1)

    out = {
        "bench": f"privacy_tradeoff ({rounds}-round fedavg, {SITES} sites; "
                 "convergence vs epsilon + mask overhead)",
        "rounds": rounds, "sites": SITES, "clip": CLIP,
        "dp_sweep": sweep,
        "secure_agg": {
            "plain": {"wall_s": plain_wall, "upload_bytes": pb,
                      "final_loss": float(plain_res.final_loss)},
            "masked": {"wall_s": mask_wall, "upload_bytes": mb,
                       "final_loss": float(mask_res.final_loss)},
            "byte_ratio": ratio,
        },
        "note": "epsilon is per site at the accountant's delta, full-batch "
                "Gaussian composition over rounds x local_steps; masked "
                "uploads are int64 fixed point (2x fp32) and reproduce the "
                "plaintext trajectory to ~2^-32 relative.",
        "checks": {
            "epsilon_monotone_in_sigma": bool(monotone),
            "epsilon_matches_analytic": bool(eps_ok),
            "dp_losses_finite": bool(all(
                np.isfinite(r["final_loss"]) for r in sweep)),
            "masked_matches_plain": parity,
            "masked_byte_ratio_is_2x": bool(1.5 < ratio < 2.6),
        },
    }
    (ARTIFACTS / "BENCH_privacy.json").write_text(json.dumps(out, indent=2))
    derived = (f"eps={','.join(f'{e:.1f}' for e in eps_vals)};"
               f"mask_ratio={ratio:.2f};parity={parity}")
    return derived, out


if __name__ == "__main__":
    print(run(quick="--quick" in sys.argv)[0])
