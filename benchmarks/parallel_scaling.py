"""Paper §III.A.4's efficiency claim — "FedAvg completed in 13.37 h with
FedKBP+ versus 86.21 h sequential site-by-site training".

We measure the same quantity on CPU through the unified job API: mean
per-round *compute* time (the job history's ``step_s``, which excludes
host-side synthetic data generation) of a federation whose sites all
execute as one vmapped/jitted program (FedKBP+'s parallel execution)
versus the same local steps driven one site at a time — and report the
speedup alongside the paper's 6.45x.  (Round 0 is dropped as the
compile round.)

``--cross-device`` (``cross_device()``, registered separately in the
harness) measures the ISSUE-8 site-count axis instead: the sharded
stacked simulator (``shard_sites=True``) at 1% uniform client sampling
across S ∈ {32, 1k, 10k} sites on a tiny dose task, against the dense
engine at the middle S — the claim being that round cost follows the
*participant* count while the dense engine pays for all S rows.
Writes ``BENCH_cross_device.json`` (rendered by ``benchmarks.report``).
"""
from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig

SITES = 8
VOL = (16, 16, 16)


def run(quick: bool = False):
    reps = 2 if quick else 4
    parallel = FederatedJob(
        task=TaskConfig(kind="dose", volume=VOL, sites=SITES, seed=5, batch=2),
        strategy="fedavg", rounds=reps + 1, lr=3e-3).run()
    parallel_s = float(np.mean([h["step_s"] for h in parallel.history[1:]]))

    # sequential: one site at a time through a single-site federation
    sequential = FederatedJob(
        task=TaskConfig(kind="dose", volume=VOL, sites=1, seed=5, batch=2),
        strategy="individual", rounds=SITES * reps + 1, lr=3e-3).run()
    per_site_s = float(np.mean([h["step_s"] for h in sequential.history[1:]]))
    sequential_s = per_site_s * SITES

    # On this 1-core CPU container sites cannot physically parallelize —
    # the honest quantity is the measured batching ratio plus the
    # STRUCTURAL speedup on the mesh (sites are disjoint device blocks,
    # so the mesh round time is the max over sites, not the sum).
    batching_ratio = sequential_s / parallel_s
    out = {"claim": "13.37h parallel vs 86.21h sequential (6.45x, 8 sites)",
           "parallel_round_s": parallel_s,
           "sequential_round_s": sequential_s,
           "measured_batching_ratio_1core": batching_ratio,
           "mesh_structural_speedup": float(SITES),
           "paper_speedup": 86.21 / 13.37,
           "note": "single CPU core: vmapped sites serialize, so the measured "
                   "ratio reflects batching overhead only; on the TPU FL mesh each site "
                   "owns a disjoint device block, so the round time is "
                   "max-over-sites -> structural speedup = S = 8 (paper "
                   "measured 6.45x of the ideal 8x on real GPUs)."}
    (ARTIFACTS / "parallel_scaling.json").write_text(json.dumps(out, indent=2))
    return (f"structural={SITES}x;paper=6.45x;"
            f"cpu_batching={batching_ratio:.2f}x"), out


def _tiny_dose_job(sites: int, rounds: int, **kw) -> FederatedJob:
    """The smallest SA-Net dose task that still trains (the decoder
    needs 2 levels) — deliberately tiny so the site *count* is the only
    axis."""
    return FederatedJob(
        task=TaskConfig(kind="dose", volume=(8, 8, 8), base_filters=2,
                        num_levels=2, sites=sites, batch=1, seed=0),
        strategy="fedavg", rounds=rounds, lr=3e-3, seed=0, **kw)


def cross_device(quick: bool = False):
    sites_axis = (32, 200) if quick else (32, 1000, 10000)
    rounds = 2 if quick else 3
    rows = {}
    for s in sites_axis:
        k = max(1, s // 100)                       # 1% uniform sampling
        res = _tiny_dose_job(
            s, rounds, sample=f"uniform:{k}", shard_sites=True,
            dropout_scenario="shutdown").run()
        rows[s] = {
            "participants_per_round": k,
            "wall_s": res.wall_s, "compile_s": res.compile_s,
            "step_s": float(np.mean([h["step_s"] for h in res.history])),
            "upload_bytes": res.comm["upload_bytes"],
            "final_loss": float(res.final_loss),
            "finite": bool(np.isfinite(np.asarray(res.losses)).all()),
        }

    # dense contrast at the middle S: every site trains every round, so
    # the round pays for S rows instead of the 1% participant slab
    s_mid = sites_axis[1]
    dense = _tiny_dose_job(s_mid, rounds).run()
    dense_step = float(np.mean([h["step_s"] for h in dense.history]))

    s_max = sites_axis[-1]
    ratio = rows[s_max]["step_s"] / max(rows[sites_axis[0]]["step_s"], 1e-9)
    out = {
        "task": "dose(8,8,8) base_filters=2 num_levels=2",
        "rounds": rounds, "sampling": "uniform:1%", "sites": rows,
        "dense_contrast": {"sites": s_mid, "step_s": dense_step},
        "checks": {
            # the headline: a 10,000-site job (quick: 200) completes on
            # one box with finite losses
            "largest_run_completes": rows[s_max]["finite"],
            # uploads follow the participant count: bytes per round per
            # participant are constant across the whole axis
            "upload_bytes_follow_participants": bool(np.allclose(
                [rows[s]["upload_bytes"]
                 / (rounds * rows[s]["participants_per_round"])
                 for s in sites_axis],
                rows[sites_axis[0]]["upload_bytes"] / rounds, rtol=1e-6)),
            # round cost grows sublinearly in S (the per-device slab is
            # the participant rows, not the full buffer)
            "step_cost_sublinear_in_sites": bool(
                ratio < (s_max / sites_axis[0])),
            # sampling beats training everyone at equal S
            "sampled_cheaper_than_dense": bool(
                rows[s_mid]["step_s"] < dense_step),
        },
    }
    (ARTIFACTS / "BENCH_cross_device.json").write_text(
        json.dumps(out, indent=2))
    derived = (f"S_max={s_max};step_ratio={ratio:.1f}x;"
               f"sampled_vs_dense={rows[s_mid]['step_s'] / dense_step:.2f}")
    return derived, out


if __name__ == "__main__":
    if "--cross-device" in sys.argv:
        print(cross_device(quick="--quick" in sys.argv)[0])
    else:
        print(run(quick="--quick" in sys.argv)[0])
