"""Paper §III.A.4's efficiency claim — "FedAvg completed in 13.37 h with
FedKBP+ versus 86.21 h sequential site-by-site training".

We measure the same quantity on CPU: wall time of one federated round
with all sites executing as one vmapped/jitted program (FedKBP+'s
parallel execution) versus the same local steps run sequentially per
site — and report the speedup alongside the paper's 6.45x.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import ARTIFACTS, make_sanet_ctx
from repro.core import federation as F
from repro.data.synthetic import DoseTaskGenerator
from repro.models import sanet as sanet_mod

SITES = 8
VOL = (16, 16, 16)


def run(quick: bool = False):
    reps = 2 if quick else 4
    ctx, scfg = make_sanet_ctx("fedavg", SITES)
    gen = DoseTaskGenerator(volume=VOL, num_oars=2, num_sites=SITES, seed=5)
    state = F.init_fl_state(ctx, lambda k: sanet_mod.sanet_init(k, scfg),
                            jax.random.PRNGKey(0))
    rnd = jax.jit(F.build_fl_round(ctx))
    b = jax.tree.map(jnp.asarray, gen.stacked_batches(0, 1, 2))
    ri = F.make_round_inputs(ctx)
    state, _ = rnd(state, b, ri)                      # compile
    t0 = time.time()
    for _ in range(reps):
        state, _ = jax.block_until_ready(rnd(state, b, ri))
    parallel_s = (time.time() - t0) / reps

    # sequential: one site at a time through a single-site jit
    ctx1, _ = make_sanet_ctx("individual", 1)
    state1 = F.init_fl_state(ctx1, lambda k: sanet_mod.sanet_init(k, scfg),
                             jax.random.PRNGKey(0))
    rnd1 = jax.jit(F.build_fl_round(ctx1))
    b1 = jax.tree.map(lambda x: x[:1], b)
    ri1 = F.make_round_inputs(ctx1)
    state1, _ = rnd1(state1, b1, ri1)                 # compile
    t0 = time.time()
    for _ in range(reps):
        for s in range(SITES):
            bs = jax.tree.map(lambda x: x[s: s + 1], b)
            state1, _ = jax.block_until_ready(rnd1(state1, bs, ri1))
    sequential_s = (time.time() - t0) / reps

    # On this 1-core CPU container sites cannot physically parallelize —
    # the honest quantity is the measured batching ratio plus the
    # STRUCTURAL speedup on the mesh (sites are disjoint device blocks,
    # so the mesh round time is the max over sites, not the sum).
    batching_ratio = sequential_s / parallel_s
    out = {"claim": "13.37h parallel vs 86.21h sequential (6.45x, 8 sites)",
           "parallel_round_s": parallel_s,
           "sequential_round_s": sequential_s,
           "measured_batching_ratio_1core": batching_ratio,
           "mesh_structural_speedup": float(SITES),
           "paper_speedup": 86.21 / 13.37,
           "note": "single CPU core: vmapped sites serialize, so the measured "
                   "ratio reflects batching overhead only; on the TPU FL mesh "
                   "each site owns a disjoint device block, so the round time "
                   "is max-over-sites -> structural speedup = S = 8 (paper "
                   "measured 6.45x of the ideal 8x on real GPUs)."}
    (ARTIFACTS / "parallel_scaling.json").write_text(json.dumps(out, indent=2))
    return (f"structural={SITES}x;paper=6.45x;"
            f"cpu_batching={batching_ratio:.2f}x"), out


if __name__ == "__main__":
    print(run()[0])
