"""Paper §III.A.4's efficiency claim — "FedAvg completed in 13.37 h with
FedKBP+ versus 86.21 h sequential site-by-site training".

We measure the same quantity on CPU through the unified job API: mean
per-round *compute* time (the job history's ``step_s``, which excludes
host-side synthetic data generation) of a federation whose sites all
execute as one vmapped/jitted program (FedKBP+'s parallel execution)
versus the same local steps driven one site at a time — and report the
speedup alongside the paper's 6.45x.  (Round 0 is dropped as the
compile round.)
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig

SITES = 8
VOL = (16, 16, 16)


def run(quick: bool = False):
    reps = 2 if quick else 4
    parallel = FederatedJob(
        task=TaskConfig(kind="dose", volume=VOL, sites=SITES, seed=5, batch=2),
        strategy="fedavg", rounds=reps + 1, lr=3e-3).run()
    parallel_s = float(np.mean([h["step_s"] for h in parallel.history[1:]]))

    # sequential: one site at a time through a single-site federation
    sequential = FederatedJob(
        task=TaskConfig(kind="dose", volume=VOL, sites=1, seed=5, batch=2),
        strategy="individual", rounds=SITES * reps + 1, lr=3e-3).run()
    per_site_s = float(np.mean([h["step_s"] for h in sequential.history[1:]]))
    sequential_s = per_site_s * SITES

    # On this 1-core CPU container sites cannot physically parallelize —
    # the honest quantity is the measured batching ratio plus the
    # STRUCTURAL speedup on the mesh (sites are disjoint device blocks,
    # so the mesh round time is the max over sites, not the sum).
    batching_ratio = sequential_s / parallel_s
    out = {"claim": "13.37h parallel vs 86.21h sequential (6.45x, 8 sites)",
           "parallel_round_s": parallel_s,
           "sequential_round_s": sequential_s,
           "measured_batching_ratio_1core": batching_ratio,
           "mesh_structural_speedup": float(SITES),
           "paper_speedup": 86.21 / 13.37,
           "note": "single CPU core: vmapped sites serialize, so the measured "
                   "ratio reflects batching overhead only; on the TPU FL mesh each site "
                   "owns a disjoint device block, so the round time is "
                   "max-over-sites -> structural speedup = S = 8 (paper "
                   "measured 6.45x of the ideal 8x on real GPUs)."}
    (ARTIFACTS / "parallel_scaling.json").write_text(json.dumps(out, indent=2))
    return (f"structural={SITES}x;paper=6.45x;"
            f"cpu_batching={batching_ratio:.2f}x"), out


if __name__ == "__main__":
    print(run()[0])
