"""Paper Fig 15 — GCML robustness to random site drop-in/out (Algorithm 2).

PanSeg-shaped OAR segmentation, 5 sites, N_max ∈ {0, 1, 2} (0/20/40%
drop-out), both dropout scenarios; per-case DSC distributions compared
with one-way ANOVA (the paper reports p = 0.9097 — no significant loss).

An adversary axis extends the figure beyond the paper: one sign-flipping
site attacks the P2P exchange (gossip has no server to sanitize
uploads), with and without the decentralized defence —
``aggregator="normclip:c"`` clips each incoming peer delta to L2 ≤ c at
the receiving site (core/strategies/gcml.py), bounding the damage any
single peer can inject per round.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig
from repro.data.synthetic import SegTaskGenerator
from repro.metrics import dice_coefficient, one_way_anova
from repro.models import sanet as sanet_mod

SITES = 5
VOL = (16, 16, 16)


def _dsc_per_case(params, scfg, batch):
    pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
    labels = np.asarray(jnp.argmax(pred, axis=-1))
    true = np.asarray(batch["labels"])
    return [dice_coefficient(labels[i], true[i], scfg.out_channels)
            for i in range(labels.shape[0])]


def run(quick: bool = False):
    rounds = 8 if quick else 16
    test_gen = SegTaskGenerator(volume=VOL, in_channels=2, num_classes=3,
                                num_sites=1, seed=777)
    test = jax.tree.map(jnp.asarray, test_gen.sample(0, 0, 10))
    task = TaskConfig(kind="seg", volume=VOL, sites=SITES, heterogeneity=0.2,
                      seed=4, batch=2, site_pools=(18, 15, 12, 10, 8))
    groups = {}
    for scenario in ["disconnect", "shutdown"]:
        for n_max in [0, 1, 2]:
            if n_max == 0 and scenario == "shutdown":
                continue                       # identical to disconnect
            job = FederatedJob(task=task, strategy="gcml", rounds=rounds,
                               lr=5e-3, max_dropout=n_max,
                               dropout_scenario=scenario, seed=11)
            res = job.run()
            scfg = job.task.model_config()
            dscs = _dsc_per_case(res.global_params, scfg, test)
            key = f"{scenario}:{n_max * 20}%"
            groups[key] = {"dsc": dscs, "mean_dsc": float(np.mean(dscs)),
                           "final_loss": res.final_loss}
    f, p = one_way_anova([np.array(v["dsc"]) for v in groups.values()])

    # -- adversary axis: one sign-flipping peer, with/without normclip.
    # Compared within the axis (same shortened run), so half rounds keep
    # the added wall-clock modest.
    adv_rounds = max(rounds // 2, 4)
    adversary = {}
    for label, extra in [
            ("clean", {}),
            ("sign_flip:1", {"adversary": "sign_flip:1"}),
            ("sign_flip:1+normclip", {"adversary": "sign_flip:1",
                                      "aggregator": "normclip:1.0"})]:
        job = FederatedJob(task=task, strategy="gcml", rounds=adv_rounds,
                           lr=5e-3, seed=11, **extra)
        res = job.run()
        dscs = _dsc_per_case(res.global_params, job.task.model_config(), test)
        adversary[label] = {"mean_dsc": float(np.mean(dscs)),
                            "final_loss": res.final_loss}

    out = {"figure": "Fig 15", "groups": {k: {kk: vv for kk, vv in v.items()
                                              if kk != "dsc"}
                                          for k, v in groups.items()},
           "anova_F": f, "anova_p": p,
           "paper_p": 0.9097,
           "claim_no_significant_loss": p > 0.05,
           "adversary": adversary,
           "checks": {"normclip_recovers_gossip":
                      adversary["sign_flip:1+normclip"]["mean_dsc"]
                      >= adversary["sign_flip:1"]["mean_dsc"]}}
    (ARTIFACTS / "gossip_robustness.json").write_text(json.dumps(out, indent=2))
    derived = ";".join(f"{k}={v['mean_dsc']:.4f}" for k, v in groups.items()) \
        + f";anova_p={p:.4f}" \
        + ";" + ";".join(f"adv[{k}]={v['mean_dsc']:.4f}"
                         for k, v in adversary.items())
    return derived, out


if __name__ == "__main__":
    print(run()[0])
