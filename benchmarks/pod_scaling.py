"""Cross-pod bytes + wall time vs pod count (ISSUE 5 tentpole).

The point of the two-tier topology is the byte split: the cross-pod
(WAN/DCN) link carries one partial up + one global down per *pod* per
round, independent of how many sites sit inside each pod — so growing a
federation by filling pods leaves the slow link flat, while the flat
star's central link scales with the site count.

Protocol: one 8-site FedAvg token job on the ``thread`` transport (real
``Peer``/server round trips and measured ``WireStats``, cheap enough
for CI) at ``--topology flat`` and ``pods:{2,4}``, same seed.  For each
variant we record wall time and the per-tier byte split from
``JobResult.comm``, plus a stacked ``pods:2`` run to confirm the
simulated split predicts the measured one.  Writes
``BENCH_pod_scaling.json`` (rendered by ``benchmarks.report``); checks:

  * cross-pod upload bytes ≈ pods × rounds × model_size (within framing
    overhead) — the WAN term scales with P, not S;
  * cross-pod bytes stay below the flat star's central-link bytes;
  * the pods global matches the flat global (identity settings ⇒
    allclose, the tier-1 law measured here end to end).
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import ARTIFACTS

SITES, BATCH, SEQ = 8, 1, 16


def _job(**kw):
    from repro.api import FederatedJob, TaskConfig
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=SITES,
                        batch=BATCH, seq=SEQ, heterogeneity=0.3, seed=0),
        strategy="fedavg", lr=1e-3, seed=0, transport="thread")
    base.update(kw)
    return FederatedJob(**base)


def _run(job):
    t0 = time.perf_counter()
    res = job.run()
    wall = time.perf_counter() - t0
    comm = dict(res.comm or {})
    return {"wall_s": wall, "final_loss": float(res.final_loss),
            "comm": comm}, res


def run(quick: bool = False):
    rounds = 3 if quick else 6
    import jax

    flat_rec, flat_res = _run(_job(rounds=rounds))
    per_pods = {}
    pods_res2 = None
    for p in (2, 4):
        rec, res = _run(_job(rounds=rounds, topology=f"pods:{p}"))
        per_pods[p] = rec
        if p == 2:
            pods_res2 = res
    sim_rec, _ = _run(_job(rounds=rounds, topology="pods:2",
                           transport="stacked"))
    # leaders re-upload partials through the same codec as the sites, so
    # --compression also shrinks the WAN link (int8 deltas ≈ 4× fewer
    # payload bytes; framing + first-round dense upload dilute that)
    int8_rec, _ = _run(_job(rounds=rounds, topology="pods:2",
                            compression="int8"))

    model_nbytes = sum(np.asarray(x).nbytes
                       for x in jax.tree.leaves(flat_res.global_params))
    # parity: identity settings ⇒ the 2-tier global equals the flat one
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(flat_res.global_params),
                             jax.tree.leaves(pods_res2.global_params))]
    parity_ok = max(diffs) < 1e-2

    cross2 = per_pods[2]["comm"]["cross_pod_upload_bytes"]
    cross4 = per_pods[4]["comm"]["cross_pod_upload_bytes"]
    # the WAN term scales with the pod count (framing overhead ~1%)
    scale_ok = 1.5 < cross4 / max(cross2, 1) < 2.6
    # and stays under the flat star's central link (8 sites vs 2/4 pods)
    central_flat = flat_rec["comm"]["upload_bytes"]
    wan_below_flat = cross2 < central_flat
    # expected: pods × rounds × model bytes (leaders re-upload fp32)
    expect2 = 2 * rounds * model_nbytes
    expect_ok = abs(cross2 - expect2) / expect2 < 0.05
    # the compressed leader path must shrink the cross-pod upload link
    cross2_int8 = int8_rec["comm"]["cross_pod_upload_bytes"]
    compressed_ok = cross2_int8 < 0.6 * cross2

    out = {
        "bench": f"pod_scaling ({rounds}-round thread fedavg, {SITES} sites;"
                 " cross-pod bytes vs pod count)",
        "rounds": rounds, "sites": SITES, "model_nbytes": model_nbytes,
        "flat": flat_rec,
        "pods": {str(p): rec for p, rec in per_pods.items()},
        "stacked_pods2_simulated": sim_rec,
        "pods2_int8": int8_rec,
        "note": "cross_pod bytes = one partial up + one global down per "
                "active pod per round — the WAN term scales with P while "
                "the flat star's central link scales with S; intra_pod "
                "bytes are unchanged by P.",
        "checks": {
            "cross_pod_scales_with_P": bool(scale_ok),
            "cross_pod_below_flat_central": bool(wan_below_flat),
            "cross_pod_matches_P_rounds_model": bool(expect_ok),
            "cross_pod_compressed_shrinks": bool(compressed_ok),
            "pods_flat_parity": bool(parity_ok),
        },
    }
    (ARTIFACTS / "BENCH_pod_scaling.json").write_text(json.dumps(out, indent=2))
    derived = (f"cross2={cross2}B;cross4={cross4}B;int8={cross2_int8}B;"
               f"flat_central={central_flat}B;parity={parity_ok}")
    return derived, out


if __name__ == "__main__":
    print(run(quick="--quick" in sys.argv)[0])
