"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer FL rounds (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (agg_engine, comm_bytes, dose_prediction,
                            gossip_robustness, parallel_scaling, pod_scaling,
                            privacy_tradeoff, robust_agg, roofline,
                            round_engine, strategy_compare)
    benches = [
        ("dose_prediction_fig7_8_9", dose_prediction.run),
        ("strategy_compare_fig11_12", strategy_compare.run),
        ("gossip_robustness_fig15", gossip_robustness.run),
        ("comm_bytes_table1", comm_bytes.run),
        ("agg_engine_eq1", agg_engine.run),
        ("round_engine_scan", round_engine.run),
        ("pod_scaling_two_tier", pod_scaling.run),
        ("privacy_tradeoff_eps", privacy_tradeoff.run),
        ("robust_agg_byzantine", robust_agg.run),
        ("parallel_scaling_sec3a4", parallel_scaling.run),
        ("cross_device_scaling", parallel_scaling.cross_device),
        ("roofline_dryrun", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            derived, _ = fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            derived = f"ERROR:{e!r}"
        us = (time.time() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
