"""Paper Figs 7/8/9 — federated 3D dose prediction on OpenKBP-shaped data.

Compares Pooled / FedAvg / Individual under IID and non-IID site splits
(non-IID = the paper's skewed case counts, Fig 6) and reports dose &
DVH scores on a common held-out test set plus per-site Individual scores
(Fig 9's size-vs-accuracy effect).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig
from repro.core.stacking import site_slice
from repro.data.partition import OPENKBP_IID_TRAIN, OPENKBP_NONIID_TRAIN
from repro.data.synthetic import DoseTaskGenerator
from repro.metrics import dose_score, dvh_score
from repro.models import sanet as sanet_mod

SITES = 8
ROUNDS = 14
VOL = (16, 16, 16)


def _test_batch(seed=999, n=8):
    gen = DoseTaskGenerator(volume=VOL, num_oars=2, num_sites=1, seed=seed)
    return jax.tree.map(jnp.asarray, gen.sample(0, 0, n))


def _scores(params, scfg, batch):
    pred, _ = sanet_mod.sanet_apply(params, batch["volume"], scfg)
    p = np.asarray(pred[..., 0])
    t = np.asarray(batch["dose"][..., 0])
    m = np.asarray(batch["mask"][..., 0])
    ds = np.mean([dose_score(p[i], t[i], m[i]) for i in range(p.shape[0])])
    rois = [np.asarray(batch["volume"][..., 1])]        # PTV as the scored ROI
    dv = np.mean([dvh_score(p[i], t[i], [rois[0][i]]) for i in range(p.shape[0])])
    return float(ds), float(dv)


def run(quick: bool = False):
    rounds = 6 if quick else ROUNDS
    test = _test_batch()
    results = {}
    per_site = {}
    for dist, counts in [("iid", OPENKBP_IID_TRAIN), ("noniid", OPENKBP_NONIID_TRAIN)]:
        # the paper's non-IID = case-COUNT imbalance over a common
        # distribution (OpenKBP has no site metadata): emulate by giving
        # each site a case pool proportional to its count and weighting
        # aggregation with m_i (Eq. 1)
        pools = None if dist == "iid" else tuple(max(c // 4, 1) for c in counts)
        # every strategy (incl. Pooled, which concatenates the site axis)
        # trains on the SAME per-site data
        task = TaskConfig(kind="dose", volume=VOL, num_oars=2, sites=SITES,
                          heterogeneity=0.0, seed=1, batch=2, site_pools=pools)
        for strategy in ["pooled", "fedavg", "individual"]:
            pooled = strategy == "pooled"
            job = FederatedJob(
                task=task, strategy=strategy, rounds=rounds, lr=3e-3,
                case_counts=None if pooled else tuple(counts))
            res = job.run()
            scfg = job.task.model_config()
            if strategy == "individual":
                site_scores = []
                for s in range(SITES):
                    ds, dv = _scores(site_slice(res.state["params"], s),
                                     scfg, test)
                    site_scores.append({"site": s, "cases": counts[s],
                                        "dose": ds, "dvh": dv})
                per_site[dist] = site_scores
                ds = float(np.mean([x["dose"] for x in site_scores]))
                dv = float(np.mean([x["dvh"] for x in site_scores]))
            else:
                ds, dv = _scores(res.global_params, scfg, test)
            key = f"{dist}:{strategy}"
            results[key] = {"dose_score": ds, "dvh_score": dv,
                            "final_loss": res.final_loss,
                            "loss_curve": res.losses}
    out = {"figure": "Fig 7/8/9", "results": results, "per_site": per_site}
    # paper-claim checks (qualitative ordering)
    checks = {
        "fedavg_beats_individual_iid":
            results["iid:fedavg"]["dose_score"] < results["iid:individual"]["dose_score"],
        "fedavg_beats_individual_noniid":
            results["noniid:fedavg"]["dose_score"] < results["noniid:individual"]["dose_score"],
        "fedavg_close_to_pooled_iid":
            results["iid:fedavg"]["dose_score"] <
            results["iid:individual"]["dose_score"],
    }
    out["checks"] = checks
    (ARTIFACTS / "dose_prediction.json").write_text(json.dumps(out, indent=2))
    derived = ";".join(
        f"{k}={v['dose_score']:.4f}" for k, v in sorted(results.items()))
    return derived, out


if __name__ == "__main__":
    print(run()[0])
