"""Bytes-on-the-wire per FL round, measured on the real job path.

The seed's version of this table priced raw codec payloads in isolation
— numbers that couldn't drift *with* the stack because they never went
through it.  This version runs :class:`repro.api.FederatedJob` on the
actual transports and reads ``result.comm``: on the socket transports
those are the framed bytes the ``AggregationServer`` counted crossing
real TCP sockets (`WireStats`); on the stacked simulator they are the
equivalent encoded payload bytes.  Compression therefore shows up for
free, and the table doubles as the paper's communication-efficiency
claim made measurable:

  * upload bytes per round per codec (none / int8 / fp8 / topk-sparse)
  * compression ratio vs the uncompressed run, per transport
  * accuracy-vs-compression: final synthetic-dose loss per codec
  * server-resident memory: the O(N) streaming accumulator vs O(S·N)

With ``down_compression`` the broadcast rides the codec seam too, so
the second table prices the full ROUND TRIP: fp32 up+down vs the
bidirectional delta stream.  Two honest numbers matter there:

  * the *total* ratio includes every dense bootstrap round-trip a new
    site costs (its first download, and for sparsifiers its first
    upload), so it understates long-run savings on short runs;
  * the *steady-state* ratio excludes those bootstraps — it is the
    per-round price once every site is inside the server's reference
    window, the regime a months-long federation actually pays.

int8 both ways tops out near 4× (1 byte can't beat 4 bytes by more);
the ≥10× round-trip claim is carried by ``topk-fixed(fraction=0.04)``
both ways — 8 B/kept entry · 0.04 ≈ 0.32 B/param/direction = 12.5×
steady state — checked as ``roundtrip_ge_10x`` in the report.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig
from repro.comms.compression import TopKFixedCodec, resolve_codec

CODECS = ["none", "int8", "fp8", "topk-sparse"]

#: the round-trip variants: (label, up codec, down codec)
ROUNDTRIP = [
    ("int8/int8", "int8", "int8"),
    ("topk0.04/topk0.04", TopKFixedCodec(fraction=0.04),
     TopKFixedCodec(fraction=0.04)),
]


def _steady_roundtrip(comm: dict, sites: int, nbytes: float) -> float:
    """Steady-state round-trip ratio from a run's payload counters:
    subtract the dense bootstraps (every site's first download; the
    first upload too for dense-bootstrap sparsifiers), then price the
    remaining per-site round trip against 2·nbytes fp32."""
    up_pay = comm.get("site_payload_bytes", comm["upload_bytes"])
    dn_pay = comm.get("download_payload_bytes", comm["download_bytes"])
    up_boot = sites if getattr(resolve_codec(comm["compression"]),
                               "dense_bootstrap", False) else 0
    up = (up_pay - up_boot * nbytes) / max(comm["upload_count"] - up_boot, 1)
    dn = (dn_pay - sites * nbytes) / max(comm["download_count"] - sites, 1)
    return 2 * nbytes / max(up + dn, 1.0)


def run(quick: bool = False):
    rounds = 2 if quick else 5
    sites = 3
    # base_filters=16 ≈ 172k params — small enough for CI, big enough
    # that per-leaf header overhead stops masking the codec ratio (the
    # paper's SA-Net is in the millions)
    task = TaskConfig(kind="dose", sites=sites, batch=2, volume=(16, 16, 16),
                      base_filters=16, heterogeneity=0.3, seed=0)
    base = FederatedJob(task=task, strategy="fedavg", rounds=rounds,
                        lr=2e-3, seed=0)
    transports = ["stacked", "thread"]
    rows = {}
    dense = None
    for codec in CODECS:
        for transport in transports:
            res = base.replace(compression=codec, transport=transport).run()
            comm = res.comm
            if codec == "none" and transport == "stacked":
                dense = comm
            uploads = max(comm["upload_count"], 1)
            rows[f"{codec}/{transport}"] = {
                "final_loss": round(res.final_loss, 6),
                "upload_bytes": comm["upload_bytes"],
                "bytes_per_upload": comm["upload_bytes"] // uploads,
                "download_bytes": comm["download_bytes"],
                "measured_on_wire": not comm["simulated"],
            }
    for codec in CODECS:
        for transport in transports:
            none_row = rows[f"none/{transport}"]
            row = rows[f"{codec}/{transport}"]
            row["upload_ratio_vs_none"] = round(
                none_row["upload_bytes"] / max(row["upload_bytes"], 1), 3)
    # -- bidirectional round trip (down_compression) ------------------------
    # quick mode keeps sockets to the in-process threads; the full run
    # prices one real tcp job (one OS process per site) as well
    rt_transports = ["stacked", "thread"] if quick \
        else ["stacked", "thread", "tcp"]
    dense_loss = rows["none/stacked"]["final_loss"]
    # raw fp32 model bytes per payload, from the dense run's accounting
    nbytes = dense["upload_bytes"] / max(dense["upload_count"], 1)
    roundtrip = {}
    for label, up_c, down_c in ROUNDTRIP:
        for transport in rt_transports:
            res = base.replace(compression=up_c, down_compression=down_c,
                               transport=transport).run()
            comm = res.comm
            raw_rt = 2 * comm["upload_count"] * nbytes
            enc_rt = (comm.get("site_payload_bytes", comm["upload_bytes"])
                      + comm.get("download_payload_bytes",
                                 comm["download_bytes"]))
            roundtrip[f"{label}/{transport}"] = {
                "final_loss": round(res.final_loss, 6),
                "roundtrip_bytes": int(enc_rt),
                "roundtrip_raw_bytes": int(raw_rt),
                "roundtrip_ratio_total": round(raw_rt / max(enc_rt, 1), 3),
                "roundtrip_ratio_steady": round(
                    _steady_roundtrip(comm, sites, nbytes), 3),
                "loss_delta_vs_dense": round(
                    abs(res.final_loss - dense_loss), 6),
                "measured_on_wire": not comm["simulated"],
            }
    topk = [v for k, v in roundtrip.items() if k.startswith("topk")]
    checks = {
        # the headline: sparsified round trips clear 10× vs fp32 once
        # past the dense bootstraps, on the simulator AND a real wire
        "roundtrip_ge_10x": all(r["roundtrip_ratio_steady"] >= 10.0
                                for r in topk),
        # int8 both ways lands where 1-byte physics says it must (~4×)
        "int8_roundtrip_ge_3x": all(
            v["roundtrip_ratio_steady"] >= 3.0
            for k, v in roundtrip.items() if k.startswith("int8")),
        # compression must not cost the model: final dose loss within
        # 15% of the dense run on every bidirectional variant
        "bidir_loss_within_tol": all(
            r["loss_delta_vs_dense"] <= 0.15 * abs(dense_loss) + 1e-3
            for r in roundtrip.values()),
    }
    # server-resident mid-round state: the seed held every decoded upload
    # (O(S·N)); the streaming accumulator holds one fp32 model (O(N))
    from repro.core.agg_engine import StreamingAccumulator
    from repro.models.sanet import sanet_init
    params = sanet_init(jax.random.PRNGKey(0), task.model_config())
    raw = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    acc = StreamingAccumulator()
    acc.fold(jax.tree.map(lambda x: np.asarray(x, np.float32), params), 1.0)
    out = {"table": "Table 1 / comm volume (measured on FederatedJob)",
           "task": "dose", "sites": sites, "rounds": rounds,
           "rows": rows, "roundtrip": roundtrip, "checks": checks,
           "dense_loss": dense_loss,
           "server_resident_bytes_streaming": acc.nbytes,
           "server_resident_bytes_per_site_naive": raw}
    (ARTIFACTS / "BENCH_comm_bytes.json").write_text(json.dumps(out, indent=2))
    int8 = rows["int8/thread"]
    topk_key = next(k for k in roundtrip if k.startswith("topk"))
    derived = (f"int8_wire_ratio={int8['upload_ratio_vs_none']:.2f};"
               f"roundtrip_steady="
               f"{roundtrip[topk_key]['roundtrip_ratio_steady']:.1f}x;"
               f"int8_loss={int8['final_loss']:.4f};"
               f"none_loss={rows['none/thread']['final_loss']:.4f}")
    return derived, out


if __name__ == "__main__":
    import sys
    print(run(quick="--quick" in sys.argv)[0])
