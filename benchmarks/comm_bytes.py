"""Bytes-on-the-wire per FL round, measured on the real job path.

The seed's version of this table priced raw codec payloads in isolation
— numbers that couldn't drift *with* the stack because they never went
through it.  This version runs :class:`repro.api.FederatedJob` on the
actual transports and reads ``result.comm``: on the socket transports
those are the framed bytes the ``AggregationServer`` counted crossing
real TCP sockets (`WireStats`); on the stacked simulator they are the
equivalent encoded payload bytes.  Compression therefore shows up for
free, and the table doubles as the paper's communication-efficiency
claim made measurable:

  * upload bytes per round per codec (none / int8 / fp8 / topk-sparse)
  * compression ratio vs the uncompressed run, per transport
  * accuracy-vs-compression: final synthetic-dose loss per codec
  * server-resident memory: the O(N) streaming accumulator vs O(S·N)
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import ARTIFACTS
from repro.api import FederatedJob, TaskConfig

CODECS = ["none", "int8", "fp8", "topk-sparse"]


def run(quick: bool = False):
    rounds = 2 if quick else 5
    sites = 3
    # base_filters=16 ≈ 172k params — small enough for CI, big enough
    # that per-leaf header overhead stops masking the codec ratio (the
    # paper's SA-Net is in the millions)
    task = TaskConfig(kind="dose", sites=sites, batch=2, volume=(16, 16, 16),
                      base_filters=16, heterogeneity=0.3, seed=0)
    base = FederatedJob(task=task, strategy="fedavg", rounds=rounds,
                        lr=2e-3, seed=0)
    transports = ["stacked", "thread"]
    rows = {}
    for codec in CODECS:
        for transport in transports:
            res = base.replace(compression=codec, transport=transport).run()
            comm = res.comm
            uploads = max(comm["upload_count"], 1)
            rows[f"{codec}/{transport}"] = {
                "final_loss": round(res.final_loss, 6),
                "upload_bytes": comm["upload_bytes"],
                "bytes_per_upload": comm["upload_bytes"] // uploads,
                "download_bytes": comm["download_bytes"],
                "measured_on_wire": not comm["simulated"],
            }
    for codec in CODECS:
        for transport in transports:
            none_row = rows[f"none/{transport}"]
            row = rows[f"{codec}/{transport}"]
            row["upload_ratio_vs_none"] = round(
                none_row["upload_bytes"] / max(row["upload_bytes"], 1), 3)
    # server-resident mid-round state: the seed held every decoded upload
    # (O(S·N)); the streaming accumulator holds one fp32 model (O(N))
    from repro.core.agg_engine import StreamingAccumulator
    from repro.models.sanet import sanet_init
    params = sanet_init(jax.random.PRNGKey(0), task.model_config())
    raw = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    acc = StreamingAccumulator()
    acc.fold(jax.tree.map(lambda x: np.asarray(x, np.float32), params), 1.0)
    out = {"table": "Table 1 / comm volume (measured on FederatedJob)",
           "task": "dose", "sites": sites, "rounds": rounds,
           "rows": rows,
           "server_resident_bytes_streaming": acc.nbytes,
           "server_resident_bytes_per_site_naive": raw}
    (ARTIFACTS / "comm_bytes.json").write_text(json.dumps(out, indent=2))
    int8 = rows["int8/thread"]
    derived = (f"int8_wire_ratio={int8['upload_ratio_vs_none']:.2f};"
               f"int8_loss={int8['final_loss']:.4f};"
               f"none_loss={rows['none/thread']['final_loss']:.4f}")
    return derived, out


if __name__ == "__main__":
    print(run()[0])
