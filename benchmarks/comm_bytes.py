"""Table 1 analogue — measured wire bytes per FL round per strategy and
topology, using the actual codec (what crosses the paper's gRPC channel)
and the SA-Net backbone's real parameter count.

Centralized (FedAvg/FedProx): every active site uploads weights and
downloads the global model → 2·S·N bytes through the server (the single
point of failure the paper criticizes).  Decentralized (GCML): ⌊S/2⌋
direct P2P transfers, no server, bytes scale with *pairs*.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import ARTIFACTS
from repro.comms.codec import encode_message
from repro.models.sanet import SANetConfig, sanet_init


def run(quick: bool = False):
    scfg = SANetConfig(in_channels=11, out_channels=1, base_filters=24,
                       num_levels=4)
    params = sanet_init(jax.random.PRNGKey(0), scfg)
    host_tree = jax.tree.map(np.asarray, params)
    wire = len(encode_message("model", {"site": 0, "round": 1}, host_tree))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    raw = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    # server-resident mid-round state: the seed held every decoded upload
    # (O(S·N)); the streaming accumulator holds one fp32 model (O(N))
    from repro.core.agg_engine import StreamingAccumulator
    acc = StreamingAccumulator()
    acc.fold(jax.tree.map(np.copy, host_tree), 1.0)
    acc_bytes = acc.nbytes
    rows = {}
    for s in [5, 8, 16, 32]:
        rows[s] = {
            "fedavg_server_bytes": 2 * s * wire,
            "fedprox_server_bytes": 2 * s * wire,
            "gcml_p2p_bytes": (s // 2) * wire,
            "gcml_vs_fedavg_ratio": (s // 2) / (2 * s),
            "server_resident_bytes_before": s * raw,
            "server_resident_bytes_after": acc_bytes,
        }
    out = {"table": "Table 1 / comm model",
           "sanet_params": int(n_params),
           "wire_bytes_per_model": wire,
           "overhead_vs_raw": wire / (n_params * 4),
           "streaming_accumulator_bytes": acc_bytes,
           "per_site_count": rows}
    (ARTIFACTS / "comm_bytes.json").write_text(json.dumps(out, indent=2))
    derived = f"wire_bytes={wire};overhead={out['overhead_vs_raw']:.4f};" \
              f"gcml_ratio_8sites={rows[8]['gcml_vs_fedavg_ratio']:.3f}"
    return derived, out


if __name__ == "__main__":
    print(run()[0])
