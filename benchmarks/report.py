"""Render EXPERIMENTS.md tables from dry-run/benchmark artifacts.

    PYTHONPATH=src python -m benchmarks.report        # prints markdown
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ARTIFACTS
from benchmarks.roofline import model_flops_for


def dryrun_table() -> str:
    rows = []
    for fn in sorted(ARTIFACTS.glob("dryrun_*.json")):
        rec = json.loads(fn.read_text())
        m = rec["memory"]
        hbm = (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]) / 2 ** 30
        coll = rec["collective_bytes"]
        rows.append((rec["name"], rec["devices"], hbm, rec["flops"],
                     rec["bytes_accessed"],
                     sum(v for k, v in coll.items() if k != "count"),
                     rec["compile_s"]))
    out = ["| combo | chips | HBM/dev (GiB) | HLO FLOPs/dev | HLO bytes/dev | collective B/dev | compile (s) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r[0]} | {r[1]} | {r[2]:.2f} | {r[3]:.3e} | {r[4]:.3e} "
                   f"| {r[5]:.3e} | {r[6]:.0f} |")
    return "\n".join(out)


def roofline_table() -> str:
    out = ["| combo | compute (s) | memory (s) | collective (s) | bound | "
           "MODEL_FLOPS | useful ratio |",
           "|---|---|---|---|---|---|---|"]
    for fn in sorted(ARTIFACTS.glob("dryrun_*.json")):
        rec = json.loads(fn.read_text())
        r = rec["roofline"]
        mf = model_flops_for(rec)
        hlo_total = rec["flops"] * rec["devices"]
        ratio = mf / hlo_total if hlo_total else 0.0
        out.append(f"| {rec['name']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
                   f"| {r['collective_s']:.4f} | **{r['bound']}** | {mf:.3e} "
                   f"| {ratio:.3f} |")
    return "\n".join(out)


def round_engine_table() -> str:
    fn = ARTIFACTS / "BENCH_round_engine.json"
    if not fn.exists():
        return "_run benchmarks.round_engine first_"
    rec = json.loads(fn.read_text())
    out = [f"_{rec['rounds']}-round stacked FedAvg, {rec['sites']} sites "
           "(CPU, small config); exec = wall − compile, one fresh process "
           "per variant_\n",
           "| path | rounds/s | exec (s) | compile (s) |",
           "|---|---|---|---|"]
    for name, key in [("per-round loop (retired)", "loop"),
                      ("scan engine (host batches)", "scan"),
                      ("scan engine (device data)", "scan_device_data"),
                      ("per-round loop, int8", "loop_int8"),
                      ("scan engine, int8 on-device", "scan_int8"),
                      ("per-round loop, buffered", "loop_buffered"),
                      ("scan engine, buffered traced", "scan_buffered")]:
        r = rec.get(key)
        if r is None:
            continue
        out.append(f"| {name} | {r['rounds_per_s']:.1f} | {r['exec_s']:.2f} "
                   f"| {r['compile_s']:.1f} |")
    sp = rec["speedup"]
    out.append(f"\nSpeedup (wall − compile): int8 **{sp['int8_exec']:.1f}×**"
               f", buffered **{sp.get('buffered_exec', 0):.1f}×**, sync "
               f"**{sp['sync_exec']:.1f}×** (compute-floor-bound on this "
               "container — see the JSON note).  Chunk sweep (rounds/s): "
               + ", ".join(f"K={k}: {v:.1f}"
                           for k, v in rec["chunk_sweep_rounds_per_s"].items()))
    return "\n".join(out)


def pod_scaling_table() -> str:
    fn = ARTIFACTS / "BENCH_pod_scaling.json"
    if not fn.exists():
        return "_run benchmarks.pod_scaling first_"
    rec = json.loads(fn.read_text())
    out = [f"_{rec['rounds']}-round thread FedAvg, {rec['sites']} sites; "
           "bytes are measured WireStats_\n",
           "| topology | wall (s) | intra-pod up (B) | cross-pod up (B) | "
           "cross-pod down (B) |",
           "|---|---|---|---|---|"]
    flat = rec["flat"]
    out.append(f"| flat | {flat['wall_s']:.1f} | "
               f"{flat['comm'].get('upload_bytes', 0)} | — | — |")
    for p, r in sorted(rec["pods"].items(), key=lambda kv: int(kv[0])):
        c = r["comm"]
        out.append(f"| pods:{p} | {r['wall_s']:.1f} | "
                   f"{c['intra_pod_upload_bytes']} | "
                   f"{c['cross_pod_upload_bytes']} | "
                   f"{c['cross_pod_download_bytes']} |")
    sim = rec["stacked_pods2_simulated"]["comm"]
    out.append(f"\nStacked-simulated pods:2 split predicts the measured "
               f"one: cross-pod up {sim['cross_pod_upload_bytes']} B "
               "(payload) vs measured framed bytes above.  The WAN term "
               "scales with the pod count, not the site count.")
    return "\n".join(out)


def privacy_table() -> str:
    fn = ARTIFACTS / "BENCH_privacy.json"
    if not fn.exists():
        return "_run benchmarks.privacy_tradeoff first_"
    rec = json.loads(fn.read_text())
    out = [f"_{rec['rounds']}-round FedAvg, {rec['sites']} sites, "
           f"clip C={rec['clip']}; ε is per site from the Rényi "
           "accountant_\n",
           "| σ (noise mult.) | ε (δ=1e-5) | final loss |",
           "|---|---|---|"]
    for r in rec["dp_sweep"]:
        eps = "∞ (no DP)" if r["epsilon"] is None else f"{r['epsilon']:.2f}"
        out.append(f"| {r['sigma']} | {eps} | {r['final_loss']:.4f} |")
    sa = rec["secure_agg"]
    out.append(f"\nSecure aggregation (thread transport, same job): masked "
               f"uploads {sa['masked']['upload_bytes']} B vs plain "
               f"{sa['plain']['upload_bytes']} B "
               f"({sa['byte_ratio']:.2f}× — int64 fixed point vs fp32), "
               f"final loss {sa['masked']['final_loss']:.4f} vs "
               f"{sa['plain']['final_loss']:.4f} (identical to fixed-point "
               "precision).")
    return "\n".join(out)


def robustness_table() -> str:
    fn = ARTIFACTS / "BENCH_robustness.json"
    if not fn.exists():
        return "_run benchmarks.robust_agg first_"
    rec = json.loads(fn.read_text())
    f = rec["f"]
    out = [f"_{rec['rounds']}-round stacked FedAvg, {rec['sites']} sites, "
           f"f={f} malicious (⌊S/4⌋); final loss per attack × aggregator; "
           f"clean reference {rec['clean_loss']:.4f}_\n",
           f"| attack | fedavg | trimmed:{f} | median |",
           "|---|---|---|---|"]
    for attack, row in rec["grid"].items():
        if attack == "none":
            continue
        out.append(f"| {attack} | {row['fedavg']:.4f} | "
                   f"{row[f'trimmed:{f}']:.4f} | {row['median']:.4f} |")
    out.append("\nsign_flip shrinks the global toward the zero model "
               "((S−2f)/S per round) — near-harmless on short synthetic "
               "runs where uniform logits are close to achievable loss; "
               "scale/noise attacks push off-manifold and blow plain "
               "fedavg up while the rank rules hold at clean level.  The "
               "tcp chaos smoke (examples/chaos_smoke.py) reproduces the "
               "trimmed-vs-clean tolerance over sockets with a flaky "
               "channel and a SIGKILLed site.")
    return "\n".join(out)


def cross_device_table() -> str:
    fn = ARTIFACTS / "BENCH_cross_device.json"
    if not fn.exists():
        return "_run benchmarks.parallel_scaling --cross-device first_"
    rec = json.loads(fn.read_text())
    out = [f"_{rec['rounds']}-round sharded stacked FedAvg at "
           f"{rec['sampling']} sampling, {rec['task']}_\n",
           "| sites | participants/round | step (s) | wall (s) | "
           "compile (s) | upload (B) |",
           "|---|---|---|---|---|---|"]
    for s, r in sorted(rec["sites"].items(), key=lambda kv: int(kv[0])):
        out.append(f"| {s} | {r['participants_per_round']} | "
                   f"{r['step_s']:.3f} | {r['wall_s']:.1f} | "
                   f"{r['compile_s']:.1f} | {r['upload_bytes']} |")
    d = rec["dense_contrast"]
    out.append(f"\nDense contrast at S={d['sites']}: every-site rounds cost "
               f"{d['step_s']:.3f} s/round vs the sampled row above — round "
               "cost follows the participant count, upload bytes per "
               "participant are constant across the whole site axis.")
    return "\n".join(out)


def comm_table() -> str:
    fn = ARTIFACTS / "BENCH_comm_bytes.json"
    if not fn.exists():
        return "_run benchmarks.comm_bytes first_"
    rec = json.loads(fn.read_text())
    out = [f"_{rec['rounds']}-round FedAvg, {rec['sites']} sites, dose "
           "task; total includes dense bootstrap round-trips, steady "
           "state excludes them (the long-run per-round price)_\n",
           "| up/down codec | transport | round-trip total | "
           "round-trip steady | Δloss vs dense | on wire |",
           "|---|---|---|---|---|---|"]
    for key, r in rec.get("roundtrip", {}).items():
        label, transport = key.rsplit("/", 1)
        out.append(f"| {label} | {transport} | "
                   f"{r['roundtrip_ratio_total']:.2f}× | "
                   f"{r['roundtrip_ratio_steady']:.2f}× | "
                   f"{r['loss_delta_vs_dense']:.4f} | "
                   f"{'✅' if r['measured_on_wire'] else 'sim'} |")
    ok = rec.get("checks", {}).get("roundtrip_ge_10x")
    out.append("\n`roundtrip_ge_10x` (topk-fixed 0.04 both ways, steady "
               f"state ≥ 10× vs fp32): {'✅' if ok else '❌'}.  int8 both "
               "ways sits at its 1-byte physics ceiling (~4×); the "
               "sparsified stream carries the ≥10× claim.")
    return "\n".join(out)


def checks_table() -> str:
    out = ["| benchmark | check | pass |", "|---|---|---|"]
    for fn in sorted(ARTIFACTS.glob("*.json")):
        if fn.name.startswith(("dryrun_", "roofline_")):
            continue
        rec = json.loads(fn.read_text())
        for k, v in rec.get("checks", {}).items():
            out.append(f"| {fn.stem} | {k} | {'✅' if v else '❌'} |")
        if "claim_no_significant_loss" in rec:
            out.append(f"| {fn.stem} | anova_p={rec['anova_p']:.4f} (paper 0.9097) "
                       f"| {'✅' if rec['claim_no_significant_loss'] else '❌'} |")
    return "\n".join(out)


def hillclimb_table() -> str:
    fn = ARTIFACTS / "hillclimb.json"
    if not fn.exists():
        return "_run repro.launch.hillclimb first_"
    log = json.loads(fn.read_text())
    out = []
    for pair, entries in log.items():
        out.append(f"\n### {pair}\n")
        out.append("| variant | compute (s) | memory (s) | collective (s) | "
                   "bound | HBM GiB/dev | verdict |")
        out.append("|---|---|---|---|---|---|---|")
        base = None
        for e in entries:
            if "skipped" in e:
                out.append(f"| {e['variant']} | — | — | — | — | — | "
                           f"refuted (see hypothesis log) |")
                continue
            r = e["roofline"]
            dom = r["bound"]
            if base is None:
                base = r
                verdict = "baseline (paper-faithful)"
            else:
                key = base["bound"] + "_s"
                delta = (r[key] - base[key]) / max(base[key], 1e-9)
                verdict = f"{'-' if delta < 0 else '+'}{abs(delta) * 100:.0f}% on baseline-dominant term"
            out.append(f"| {e['variant']} | {r['compute_s']:.2f} | "
                       f"{r['memory_s']:.2f} | {r['collective_s']:.2f} | "
                       f"{dom} | {e['memory_gib']:.1f} | {verdict} |")
        out.append("\nHypotheses (verbatim, written before measuring):\n")
        for e in entries:
            out.append(f"* **{e['variant']}** — {e['hypothesis']}")
    return "\n".join(out)


if __name__ == "__main__":
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline\n")
    print(roofline_table())
    print("\n## §Compiled round engine\n")
    print(round_engine_table())
    print("\n## §Pod scaling (two-tier topology)\n")
    print(pod_scaling_table())
    print("\n## §Privacy tier (DP-SGD ε sweep + secure aggregation)\n")
    print(privacy_table())
    print("\n## §Cross-device scaling (sampled + sharded stacked)\n")
    print(cross_device_table())
    print("\n## §Byzantine robustness (attack × aggregator)\n")
    print(robustness_table())
    print("\n## §Bidirectional compression (round-trip wire bytes)\n")
    print(comm_table())
    print("\n## §Perf hillclimb\n")
    print(hillclimb_table())
    print("\n## Paper-claim checks\n")
    print(checks_table())
