"""End-to-end behaviour tests reproducing the paper's qualitative claims
on CPU-scale synthetic tasks (the quantitative runs live in benchmarks/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederationConfig, MeshConfig
from repro.core import federation as F
from repro.core.dropout import SiteAvailability
from repro.data.synthetic import TokenTaskGenerator
from repro.models import transformer as T
from repro.configs.registry import get_arch
from repro.optim import adamw


def _build(strategy, sites=4, het=0.0, seed=0, max_dropout=0,
           scenario="disconnect"):
    cfg = get_arch("smollm_135m").reduced()
    gen = TokenTaskGenerator(vocab_size=cfg.vocab_size, num_sites=sites,
                             heterogeneity=het, seed=seed)
    fed = FederationConfig(num_sites=sites, strategy=strategy,
                           local_steps=4, max_dropout_sites=max_dropout,
                           dropout_scenario=scenario)
    ctx = F.FLContext(
        fed=fed, mesh=MeshConfig(sites_per_pod=sites, fsdp=16 // sites),
        case_weights=jnp.asarray(fed.case_weights()),
        loss_fn=lambda p, b: T.next_token_loss(p, b, cfg),
        logits_fn=lambda p, b: (T.forward(p, b["tokens"], cfg)[0][:, :-1],
                                b["tokens"][:, 1:]),
        optimizer=adamw(1e-2), grad_clip=1.0, dcml_lr=5e-3)
    state = F.init_fl_state(ctx, lambda k: T.init(k, cfg),
                            jax.random.PRNGKey(seed))
    rnd = jax.jit(F.build_fl_round(ctx))
    return cfg, gen, ctx, state, rnd


def _run(strategy, rounds=12, sites=4, het=0.0, max_dropout=0, seed=0,
         scenario="disconnect"):
    cfg, gen, ctx, state, rnd = _build(strategy, sites, het, seed,
                                       max_dropout, scenario)
    avail = SiteAvailability(sites, max_dropout, seed=seed + 1)
    rng = np.random.default_rng(seed)
    losses = []
    for r in range(rounds):
        b = jax.tree.map(jnp.asarray, gen.stacked_batches(r, 4, 4, 64))
        ri = F.make_round_inputs(ctx, avail, rng, r)
        if strategy == "gcml":
            ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
            ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
        state, m = rnd(state, b, ri)
        losses.append(float(jnp.mean(m["loss"])))
    return losses, state, ctx


def test_federated_training_improves_loss():
    losses, _, _ = _run("fedavg", rounds=12)
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "gcml"])
def test_all_strategies_train(strategy):
    losses, state, _ = _run(strategy, rounds=8)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    for leaf in jax.tree.leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf)).all()


def test_gcml_robust_to_dropout():
    """Fig 15's qualitative claim: GCML keeps training under 40% dropout."""
    base, _, _ = _run("gcml", rounds=10, sites=5, max_dropout=0, seed=3)
    drop, _, _ = _run("gcml", rounds=10, sites=5, max_dropout=2, seed=3,
                      scenario="shutdown")
    assert drop[-1] < drop[0]                       # still converging
    assert drop[-1] < base[0]                       # meaningfully below start


def test_global_model_serves_after_training():
    _, state, ctx = _run("fedavg", rounds=5)
    g = F.global_model(state, ctx)
    cfg = get_arch("smollm_135m").reduced()
    toks = jax.random.randint(jax.random.PRNGKey(0), (1, 8), 0, cfg.vocab_size)
    _, caches = T.prefill(g, toks, cfg, cache_capacity=16, moe_impl="dense")
    logits, caches = T.decode_step(
        g, toks[:, -1:], caches, cfg, moe_impl="dense")
    assert np.isfinite(np.asarray(logits)).all()


def test_fl_train_driver_cli():
    """The launch/train.py entrypoint runs a tiny federation end to end."""
    from repro.launch.train import make_parser, run
    args = make_parser().parse_args(
        ["--arch", "granite-3-2b", "--reduced", "--sites", "2", "--rounds", "3",
         "--batch", "2", "--seq", "16", "--strategy", "fedprox"])
    args.verbose = False
    res = run(args)
    assert len(res["history"]) == 3
    assert np.isfinite(res["final_loss"])


def test_sanet_fl_dose_task():
    """The paper's own task: federated SA-Net dose prediction trains."""
    from repro.launch.train import make_parser, run
    args = make_parser().parse_args(
        ["--task", "dose", "--sites", "2", "--rounds", "4", "--batch", "1",
         "--strategy", "fedavg", "--lr", "3e-3"])
    args.verbose = False
    res = run(args)
    assert res["history"][-1]["loss"] < res["history"][0]["loss"] * 1.05
