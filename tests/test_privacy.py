"""The privacy tier: DP-SGD + accountant + secure aggregation.

Covers the subsystem's three contracts (the property-based mask
cancellation suite lives in ``test_privacy_properties.py``):

  * the Rényi accountant's grid ε matches the analytic Gaussian
    composition closed form;
  * DP-SGD noise streams are a pure function of (seed, round, site,
    step) — scan ↔ loop ↔ socket trajectories match and crash-resume
    replays rather than re-draws;
  * masked runs reproduce plaintext runs over the real wire (thread and
    tcp, flat and pods), the server never sees a plaintext upload, and
    a lease-expired site is repaired by seed recovery.
"""
import time

import jax
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.privacy import (DPConfig, SecureAggClient, SecureAggState,
                           analytic_gaussian_epsilon, gaussian_epsilon,
                           masked_values)


def _job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=3, batch=2,
                        seq=16, seed=0),
        strategy="fedavg", rounds=3, local_steps=2, lr=1e-3, seed=0,
        verbose=False)
    base.update(kw)
    return FederatedJob(**base)


def _assert_trees_close(a, b, rtol=1e-4, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Accountant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sigma,steps,delta", [
    (0.5, 10, 1e-5), (0.8, 6, 1e-5), (1.1, 100, 1e-6), (2.0, 40, 1e-5),
])
def test_rdp_epsilon_matches_analytic(sigma, steps, delta):
    """The order-grid minimum reproduces the closed-form optimum of the
    Gaussian RDP→DP objective (the grid can only be ≥, and within 1%)."""
    grid = gaussian_epsilon(sigma, steps, delta)
    ref = analytic_gaussian_epsilon(sigma, steps, delta)
    assert np.isfinite(grid)
    assert grid >= ref - 1e-9
    assert grid <= ref * 1.01


def test_epsilon_edge_cases():
    assert gaussian_epsilon(0.0, 10, 1e-5) == float("inf")
    assert gaussian_epsilon(1.0, 0, 1e-5) == 0.0
    assert gaussian_epsilon(2.0, 10, 1e-5) < gaussian_epsilon(1.0, 10, 1e-5)
    with pytest.raises(ValueError):
        gaussian_epsilon(1.0, 10, 0.0)


def test_dp_config_validation():
    with pytest.raises(ValueError, match="clip"):
        DPConfig(clip=0.0, noise_multiplier=1.0)
    with pytest.raises(ValueError, match="mode"):
        DPConfig(clip=1.0, mode="per-batch")
    # clip-only (σ=0) is valid: bounded sensitivity, no noise, ε = ∞
    DPConfig(clip=1.0, noise_multiplier=0.0)


def test_job_privacy_report_matches_analytic():
    res = _job(dp_clip=0.5, dp_noise_multiplier=0.8).run()
    p = res.privacy
    assert p["mechanism"] == "dp-sgd"
    assert p["steps"] == 3 * 2                    # rounds × local_steps
    assert np.isfinite(p["epsilon"])
    ref = analytic_gaussian_epsilon(0.8, 6, 1e-5)
    assert ref - 1e-9 <= p["epsilon"] <= ref * 1.01
    assert _job().run().privacy is None


def test_noise_without_clip_rejected():
    with pytest.raises(ValueError, match="clip"):
        _job(dp_noise_multiplier=1.0).run()


# ---------------------------------------------------------------------------
# Privacy amplification by Poisson client sampling
# ---------------------------------------------------------------------------


def test_subsampled_epsilon_never_exceeds_dense():
    """ε under poisson:q must be ≤ the unsampled ε for every q — the
    accountant takes the tighter of the subsampled integer-order bound
    and the (always valid) dense bound, and q=1 reduces exactly."""
    sigma, steps, delta = 0.8, 60, 1e-5
    dense = gaussian_epsilon(sigma, steps, delta)
    for q in (0.001, 0.01, 0.1, 0.25, 0.5, 0.9, 0.99, 0.999):
        sub = gaussian_epsilon(sigma, steps, delta, sampling_rate=q)
        assert np.isfinite(sub) and sub > 0
        assert sub <= dense + 1e-12, (q, sub, dense)
    assert gaussian_epsilon(sigma, steps, delta, sampling_rate=1.0) == dense


def test_subsampled_epsilon_monotone_in_rate():
    """Sampling less often is never worse: ε(q) non-decreasing in q,
    and strongly amplified at small rates (ε(0.01) ≪ ε(1))."""
    sigma, steps, delta = 1.1, 100, 1e-6
    qs = [0.01, 0.05, 0.1, 0.25, 0.5, 1.0]
    eps = [gaussian_epsilon(sigma, steps, delta, sampling_rate=q)
           for q in qs]
    assert all(a <= b + 1e-9 for a, b in zip(eps, eps[1:]))
    assert eps[0] < 0.5 * eps[-1]


def test_subsampled_rdp_reduces_to_dense_at_q1():
    """The Mironov–Talwar–Zhang bound collapses to α/(2σ²) exactly when
    every site is sampled every round."""
    from repro.privacy import rdp_subsampled_gaussian
    from repro.privacy.accountant import SUBSAMPLED_ORDERS, rdp_gaussian
    sub = rdp_subsampled_gaussian(1.0, 0.9, 12, SUBSAMPLED_ORDERS)
    np.testing.assert_allclose(sub, rdp_gaussian(0.9, 12, SUBSAMPLED_ORDERS))
    with pytest.raises(ValueError):
        rdp_subsampled_gaussian(1.2, 0.9, 12, SUBSAMPLED_ORDERS)  # q > 1
    with pytest.raises(ValueError):                     # fractional orders
        rdp_subsampled_gaussian(0.5, 0.9, 12, np.array([1.5, 2.5]))


def test_job_privacy_report_amplifies_under_poisson():
    """End to end: a poisson-sampled DP job reports the subsampled
    accountant and a strictly smaller ε; uniform:K (no amplification
    theorem) conservatively keeps the dense accounting."""
    dense = _job(dp_clip=0.5, dp_noise_multiplier=0.8).run().privacy
    amp = _job(dp_clip=0.5, dp_noise_multiplier=0.8, sample="poisson:0.5",
               dropout_scenario="shutdown").run().privacy
    assert dense["accountant"] == "rdp-gaussian"
    assert amp["accountant"] == "rdp-sgm-poisson"
    assert amp["sampling_rate"] == 0.5
    assert amp["epsilon"] <= dense["epsilon"]
    uni = _job(dp_clip=0.5, dp_noise_multiplier=0.8, sample="uniform:2",
               dropout_scenario="shutdown").run().privacy
    assert uni["accountant"] == "rdp-gaussian"
    assert uni["epsilon"] == dense["epsilon"]


# ---------------------------------------------------------------------------
# DP-SGD determinism across engines, transports, and resume
# ---------------------------------------------------------------------------


DP_KW = dict(dp_clip=0.5, dp_noise_multiplier=0.8)


def test_dp_scan_runs_compiled_and_matches_loop():
    """DP-SGD traces into the fused lax.scan — round_engine='scan'
    raising would mean the noise injection fell back to the host — and
    the streams (keyed off the carried round counter) make the two
    engines trajectory-identical."""
    scan = _job(**DP_KW, round_engine="scan").run()
    loop = _job(**DP_KW, round_engine="loop").run()
    np.testing.assert_allclose(scan.losses, loop.losses, rtol=1e-4)
    _assert_trees_close(scan.global_params, loop.global_params)


def test_dp_noise_actually_perturbs():
    noisy = _job(**DP_KW).run()
    clean = _job().run()
    assert not np.allclose(noisy.losses, clean.losses, rtol=1e-6)


def test_dp_clip_only_differs_from_noise():
    clip_only = _job(dp_clip=0.5).run()
    noisy = _job(**DP_KW).run()
    assert clip_only.privacy["epsilon"] == float("inf")
    assert not np.allclose(clip_only.losses, noisy.losses, rtol=1e-6)


def test_dp_per_example_mode_runs():
    res = _job(**DP_KW, dp_mode="per-example").run()
    assert np.isfinite(res.losses).all()
    assert res.privacy["mode"] == "per-example"
    # a different clipping unit is a different mechanism
    assert not np.allclose(res.losses, _job(**DP_KW).run().losses, rtol=1e-6)


def test_dp_thread_transport_matches_stacked():
    """Socket workers derive noise from GLOBAL site ids (dp_site_base),
    so the 1-site-per-worker deployment draws the stacked twin's exact
    streams."""
    stacked = _job(**DP_KW).run()
    threaded = _job(**DP_KW, transport="thread").run()
    np.testing.assert_allclose(threaded.losses, stacked.losses, rtol=1e-4)
    _assert_trees_close(stacked.global_params, threaded.global_params)


@pytest.mark.parametrize("engine", ["scan", "loop"])
def test_dp_resume_replays_noise_stream(tmp_path, engine):
    """Same-seed DP runs are loss-trajectory-identical across --resume
    re-entry: the noise key folds in the carried round counter, so a
    resumed run replays the interrupted stream instead of re-drawing."""
    kw = dict(**DP_KW, rounds=5, ckpt_every=2, round_engine=engine)
    ref = _job(**kw).run()
    job = _job(**kw, checkpoint_dir=str(tmp_path / engine))
    job.run(rounds=3)
    res = job.run(resume=True)
    assert res.resumed_from == 2
    np.testing.assert_allclose(res.losses, ref.losses[3:], rtol=1e-5)
    _assert_trees_close(res.global_params, ref.global_params)


def test_dp_resume_refuses_mechanism_change(tmp_path):
    job = _job(**DP_KW, rounds=4, ckpt_every=2,
               checkpoint_dir=str(tmp_path))
    job.run(rounds=3)
    with pytest.raises(ValueError, match="DP settings"):
        job.replace(dp_noise_multiplier=0.3).run(resume=True)


# ---------------------------------------------------------------------------
# Secure aggregation on the wire
# ---------------------------------------------------------------------------


def test_masked_upload_is_uniform_words():
    """A single masked upload carries no usable plaintext: its words
    spread over the full 2^64 range and decorrelate from the model."""
    x = {"w": np.linspace(-1, 1, 4096).astype(np.float32)}
    enc, meta = SecureAggClient("k", "site", 0).encode(x, 1.0, [0, 1], 7)
    assert meta["masked"] and meta["mask_round"] == 7
    words = jax.tree.leaves(masked_values(enc))[0].astype(np.float64)
    assert words.std() > 2 ** 61
    c = np.corrcoef(words, x["w"].astype(np.float64))[0, 1]
    assert abs(c) < 0.1


def test_secure_agg_requires_socket_transport():
    with pytest.raises(ValueError, match="stacked"):
        _job(secure_agg=True).run()


def test_secure_agg_rejects_compression_and_buffered():
    with pytest.raises(ValueError, match="compression"):
        _job(secure_agg=True, transport="thread", compression="int8").run()
    with pytest.raises(ValueError, match="sync"):
        _job(secure_agg=True, transport="thread", scheduler="buffered").run()


def test_thread_secure_agg_matches_plain():
    task = TaskConfig(kind="tokens", sites=4, batch=2, seq=16)
    plain = _job(transport="thread", max_dropout=1, task=task).run()
    masked = _job(transport="thread", max_dropout=1, secure_agg=True,
                  task=task).run()
    np.testing.assert_allclose(masked.losses, plain.losses, rtol=1e-4)
    _assert_trees_close(plain.global_params, masked.global_params)
    assert masked.privacy == {"secure_agg": True, "mechanism": "none"}


def test_thread_secure_agg_pods_matches_plain():
    kw = dict(transport="thread", topology="pods:2",
              task=TaskConfig(kind="tokens", sites=4, batch=2, seq=16))
    plain = _job(**kw).run()
    masked = _job(**kw, secure_agg=True).run()
    np.testing.assert_allclose(masked.losses, plain.losses, rtol=1e-4)
    _assert_trees_close(plain.global_params, masked.global_params)
    assert masked.comm["pods"] == 2


def test_thread_secure_agg_with_dp_composes():
    """DP-SGD inside the site update + masks on the wire: the masked
    run's trajectory equals the unmasked DP run's (same noise stream,
    fixed-point transport error only)."""
    plain = _job(**DP_KW, transport="thread").run()
    masked = _job(**DP_KW, transport="thread", secure_agg=True).run()
    np.testing.assert_allclose(masked.losses, plain.losses, rtol=1e-4)
    assert masked.privacy["secure_agg"] is True
    assert masked.privacy["mechanism"] == "dp-sgd"


def test_tcp_secure_agg_matches_plain():
    kw = dict(transport="tcp", rounds=2,
              task=TaskConfig(kind="tokens", sites=2, batch=2, seq=16))
    plain = _job(**kw).run()
    masked = _job(**kw, secure_agg=True).run()
    np.testing.assert_allclose(masked.losses, plain.losses, rtol=1e-4)
    _assert_trees_close(plain.global_params, masked.global_params)


def test_tcp_secure_agg_pods_matches_plain():
    kw = dict(transport="tcp", rounds=2, topology="pods:2",
              task=TaskConfig(kind="tokens", sites=2, batch=2, seq=16))
    plain = _job(**kw).run()
    masked = _job(**kw, secure_agg=True).run()
    np.testing.assert_allclose(masked.losses, plain.losses, rtol=1e-4)
    _assert_trees_close(plain.global_params, masked.global_params)


def test_no_plaintext_crosses_the_wire(monkeypatch):
    """With secure_agg on, every 'upload' request the clients encode is
    a MaskedTensor tree — no float payload leaf ever reaches
    encode_message on the upload path (thread transport shares our
    process, so the spy sees every site's wire encode)."""
    from repro.comms import transport as transport_mod
    from repro.comms.codec import MaskedTensor, encode_message
    violations = []

    def spy(kind, meta, tree):
        if kind == "upload":
            leaves = jax.tree.leaves(
                tree, is_leaf=lambda x: isinstance(x, MaskedTensor))
            violations.extend(
                x for x in leaves if not isinstance(x, MaskedTensor))
        return encode_message(kind, meta, tree)

    monkeypatch.setattr(transport_mod, "encode_message", spy)
    res = _job(transport="thread", secure_agg=True).run()
    assert np.isfinite(res.losses).all()
    assert not violations


def test_masked_dropout_mid_round_seed_recovery():
    """A site that joins the round's schedule then dies mid-round
    (lease expiry) leaves its pairwise masks uncancelled; the server
    regenerates exactly those pair streams and the surviving sum is the
    exact weighted mean of the sites that DID report."""
    from repro.comms.coordinator import AggregationServer
    from repro.comms.peer import Peer
    rng = np.random.default_rng(0)
    models = [{"w": rng.normal(size=(64,)).astype(np.float32)}
              for _ in range(3)]
    weights = [1.0, 2.0, 3.0]
    sa = SecureAggState("s", "site", np.ones((1, 3), bool))
    srv = AggregationServer("127.0.0.1", 0, num_sites=3,
                            case_weights=weights, download_timeout=5.0,
                            lease_ttl=0.3, secure_agg=sa)
    peers = [Peer(i) for i in range(3)]
    try:
        for i in range(3):
            peers[i].request(srv.addr, "join", {"site": i})
        for i in (0, 2):          # site 1 dies after joining the schedule
            enc, meta = SecureAggClient("s", "site", i).encode(
                models[i], weights[i], [0, 1, 2], 0)
            ack = peers[i].upload(srv.addr, enc, 1, active_sites=3,
                                  meta_extra=meta)
            assert not ack["stale"]
        deadline = time.time() + 5.0
        g = None
        while time.time() < deadline:
            try:
                g, _ = peers[0].download(srv.addr, 1, with_meta=True)
                break
            except RuntimeError:
                pass
        assert g is not None, "lease expiry never unblocked the round"
        expect = (weights[0] * models[0]["w"] + weights[2] * models[2]["w"]) \
            / (weights[0] + weights[2])
        np.testing.assert_allclose(g["w"], expect, rtol=1e-6, atol=1e-6)
        assert sa.recovered == [(0, 1)]
    finally:
        for p in peers:
            p.close()
        srv.stop()


def test_masked_upload_rejected_without_server_state():
    """A masked payload hitting a server that has no SecureAggState
    errors out instead of silently folding garbage."""
    from repro.comms.coordinator import AggregationServer
    from repro.comms.peer import Peer
    srv = AggregationServer("127.0.0.1", 0, num_sites=2,
                            download_timeout=2.0)
    peer = Peer(0)
    try:
        enc, meta = SecureAggClient("s", "site", 0).encode(
            {"w": np.ones(4, np.float32)}, 1.0, [0, 1], 0)
        with pytest.raises(RuntimeError, match="secure aggregation"):
            peer.upload(srv.addr, enc, 1, active_sites=2, meta_extra=meta)
    finally:
        peer.close()
        srv.stop()
