"""Cross-device client sampling: the :mod:`repro.core.sampling` seam.

Pins the contracts the sharded simulator and the privacy accountant
lean on: masks are a pure function of (seed, round); Horvitz–Thompson
``1/π`` reweighting keeps the Eq. 1 estimator unbiased; composition
with the Algorithm-2 dropout chain never produces an all-zero-weight
round; and the trivial sampler ``uniform:S`` takes the dense code path
bit for bit, on every engine and across a ``--resume`` re-entry.
"""
import jax
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.core.dropout import SiteAvailability
from repro.core.sampling import (NONE_SAMPLER, ClientSampler,
                                 compose_participation, resolve_sampler)

# ---------------------------------------------------------------------------
# Resolver + spec round-trip
# ---------------------------------------------------------------------------


def test_resolver_specs_roundtrip():
    assert resolve_sampler(None) is NONE_SAMPLER
    assert resolve_sampler("none") is NONE_SAMPLER
    s = resolve_sampler("uniform:3")
    assert (s.kind, s.count) == ("uniform", 3) and s.spec == "uniform:3"
    p = resolve_sampler("poisson:0.25")
    assert (p.kind, p.rate) == ("poisson", 0.25) and p.spec == "poisson:0.25"
    # a ClientSampler passes through untouched
    assert resolve_sampler(s) is s


@pytest.mark.parametrize("spec", [
    "uniform:x", "uniform:0", "uniform:-2", "poisson:zero", "poisson:0",
    "poisson:-0.5", "bernoulli:0.5",
])
def test_resolver_rejects_bad_specs(spec):
    with pytest.raises(ValueError):
        resolve_sampler(spec)


def test_trivial_samplers_and_inclusion_probability():
    assert NONE_SAMPLER.is_trivial(8)
    assert resolve_sampler("uniform:8").is_trivial(8)       # K >= S
    assert resolve_sampler("poisson:1").is_trivial(8)       # q >= 1
    assert not resolve_sampler("uniform:3").is_trivial(8)
    assert resolve_sampler("uniform:2").inclusion_probability(8) == 0.25
    assert resolve_sampler("poisson:0.4").inclusion_probability(8) == 0.4
    assert resolve_sampler("uniform:9").inclusion_probability(8) == 1.0


# ---------------------------------------------------------------------------
# Mask determinism: pure function of (seed, round)
# ---------------------------------------------------------------------------


def test_round_mask_is_pure_function_of_seed_and_round():
    s = resolve_sampler("poisson:0.3")
    a = s.round_mask(16, seed=7, round_index=5)
    b = s.round_mask(16, seed=7, round_index=5)
    np.testing.assert_array_equal(a, b)
    # and masks() is literally the stack of round_mask calls, so a
    # resumed job re-entering at round r replays the identical schedule
    stacked = s.masks(16, seed=7, rounds=8)
    np.testing.assert_array_equal(stacked[5], a)
    # different rounds draw from disjoint streams
    assert any(not np.array_equal(stacked[r], stacked[r + 1])
               for r in range(7))


def test_uniform_mask_exact_count_every_round():
    s = resolve_sampler("uniform:3")
    masks = s.masks(10, seed=0, rounds=50)
    np.testing.assert_array_equal(masks.sum(axis=1), 3)


def test_sampler_stream_disjoint_from_dropout_chain():
    """The sampler draws from (seed + offset, round), not the Algorithm-2
    chain's stream — same seed must not force correlated draws."""
    avail = SiteAvailability(16, 4, seed=3)
    chain = np.stack([avail.step() for _ in range(20)])
    sched = resolve_sampler("poisson:0.5").masks(16, seed=3, rounds=20)
    assert not np.array_equal(chain, sched)


# ---------------------------------------------------------------------------
# Horvitz–Thompson / Hájek unbiasedness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", ["uniform:4", "poisson:0.25"])
def test_horvitz_thompson_sum_is_unbiased(spec):
    """E[Σ_{i∈sampled} v_i/π] = Σ_i v_i — the numerator (and, with
    v = case weights, the denominator) of the Hájek estimator."""
    num_sites, rounds = 16, 4000
    s = resolve_sampler(spec)
    rng = np.random.default_rng(0)
    v = rng.uniform(0.5, 2.0, num_sites)
    inv_pi = 1.0 / s.inclusion_probability(num_sites)
    masks = s.masks(num_sites, seed=1, rounds=rounds)
    est = (masks * v[None]).sum(axis=1) * inv_pi
    np.testing.assert_allclose(est.mean(), v.sum(), rtol=0.03)


def test_hajek_mean_unbiased_under_uniform_sampling():
    """With uniform case weights, uniform:K self-normalizes to the mean
    of the K sampled values — exactly unbiased for the dense mean."""
    num_sites, rounds = 12, 4000
    s = resolve_sampler("uniform:3")
    rng = np.random.default_rng(1)
    v = rng.normal(size=num_sites)
    masks = s.masks(num_sites, seed=2, rounds=rounds)
    w = masks / s.inclusion_probability(num_sites)       # HT weights
    hajek = (w * v[None]).sum(axis=1) / w.sum(axis=1)    # self-normalized
    np.testing.assert_allclose(hajek.mean(), v.mean(), atol=0.05)
    # per-round the estimator is the plain mean of the sampled triple
    r0 = masks[0].astype(bool)
    np.testing.assert_allclose(hajek[0], v[r0].mean(), rtol=1e-12)


# ---------------------------------------------------------------------------
# Composition with the dropout chain
# ---------------------------------------------------------------------------


def _chain_masks(num_sites, max_dropout, seed, rounds):
    chain = SiteAvailability(num_sites, max_dropout, seed)
    return np.stack([chain.step() for _ in range(rounds)])


def test_compose_trivial_sampler_is_availability():
    avail = _chain_masks(8, 2, seed=0, rounds=10)
    part, scale = compose_participation(NONE_SAMPLER, avail, seed=0)
    np.testing.assert_array_equal(part, avail)
    np.testing.assert_array_equal(scale, avail.astype(np.float32))


def test_compose_intersection_and_scale():
    avail = _chain_masks(16, 4, seed=5, rounds=40)
    s = resolve_sampler("poisson:0.4")
    part, scale = compose_participation(s, avail, seed=5)
    sched = s.masks(16, seed=5, rounds=40)
    inv_pi = 1.0 / s.inclusion_probability(16)
    for r in range(40):
        inter = sched[r] & avail[r]
        if inter.any():                                 # normal round
            np.testing.assert_array_equal(part[r], inter)
            np.testing.assert_allclose(scale[r], inter * inv_pi)
        else:                                           # fallback round
            np.testing.assert_array_equal(part[r], avail[r])
            np.testing.assert_allclose(scale[r],
                                       avail[r].astype(np.float32))


def test_compose_never_yields_zero_weight_round():
    """Whatever the (sampler, dropout, seed) draw, every round keeps at
    least one participant with positive scale — the sync barrier and
    the Eq. 1 denominator both need one."""
    for seed in range(20):
        for spec in ("uniform:1", "poisson:0.05"):
            avail = _chain_masks(6, 5, seed=seed, rounds=30)
            part, scale = compose_participation(
                resolve_sampler(spec), avail, seed=seed)
            assert (part & avail).sum(axis=1).min() >= 1
            assert (part <= avail).all()                # never a dead site
            assert (scale > 0).sum(axis=1).min() >= 1
            np.testing.assert_array_equal(scale > 0, part)


# ---------------------------------------------------------------------------
# Hypothesis battery (optional dev extra, mirrors test_properties.py).
# Guarded with a conditional define — NOT a module-level importorskip —
# so the deterministic battery above still runs without the extra.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _spec_strategy = st.one_of(
        st.integers(1, 48).map(lambda k: f"uniform:{k}"),
        st.floats(0.01, 1.5, allow_nan=False).map(lambda q: f"poisson:{q}"))

    @settings(max_examples=40, deadline=None)
    @given(num_sites=st.integers(2, 48), seed=st.integers(0, 500),
           rounds=st.integers(1, 20), spec=_spec_strategy)
    def test_masks_shape_determinism_and_bounds(num_sites, seed, rounds,
                                                spec):
        s = resolve_sampler(spec)
        a = s.masks(num_sites, seed, rounds)
        b = s.masks(num_sites, seed, rounds)
        np.testing.assert_array_equal(a, b)             # deterministic
        assert a.shape == (rounds, num_sites) and a.dtype == bool
        if s.kind == "uniform":
            np.testing.assert_array_equal(a.sum(axis=1),
                                          min(s.count, num_sites))
        if s.is_trivial(num_sites):
            assert a.all()

    @settings(max_examples=40, deadline=None)
    @given(num_sites=st.integers(2, 24), max_dropout=st.integers(0, 6),
           seed=st.integers(0, 500), rounds=st.integers(1, 30),
           spec=st.one_of(
               st.integers(1, 24).map(lambda k: f"uniform:{k}"),
               st.floats(0.01, 1.0, exclude_max=True, allow_nan=False).map(
                   lambda q: f"poisson:{q}")))
    def test_composition_invariants(num_sites, max_dropout, seed, rounds,
                                    spec):
        """∀ draws: participate ⊆ available, ≥1 participant per round,
        scale strictly positive exactly on participating rows, and the
        non-fallback scale is the constant 1/π."""
        max_dropout = min(max_dropout, num_sites - 1)
        avail = _chain_masks(num_sites, max_dropout, seed, rounds)
        s = resolve_sampler(spec)
        part, scale = compose_participation(s, avail, seed)
        assert part.shape == scale.shape == (rounds, num_sites)
        assert (part <= avail).all()
        assert part.any(axis=1).all()
        np.testing.assert_array_equal(scale > 0, part)
        inv_pi = 1.0 / s.inclusion_probability(num_sites)
        assert np.all(np.isin(np.round(scale[part], 5),
                              np.round([1.0, inv_pi], 5)))


# ---------------------------------------------------------------------------
# End-to-end: dense-path equivalence + engine/resume determinism
# ---------------------------------------------------------------------------


def _job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4, batch=2,
                        seq=16, seed=0),
        strategy="fedavg", rounds=4, lr=1e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def _flat(tree):
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree.leaves(tree)])


def test_uniform_full_count_bit_exact_vs_dense():
    """uniform:S schedules everyone → the job takes the dense code path
    verbatim: identical jaxprs, bit-identical global model and losses."""
    dense = _job().run()
    full = _job(sample="uniform:4").run()
    assert np.array_equal(_flat(dense.global_params),
                          _flat(full.global_params))
    np.testing.assert_array_equal(dense.losses, full.losses)


def test_sampled_scan_matches_loop():
    """The compiled multi-round scan and the retired host loop replay
    the identical sampled schedule and agree numerically."""
    kw = dict(sample="uniform:2", max_dropout=1,
              dropout_scenario="shutdown", rounds=5)
    scan = _job(**kw).run()
    loop = _job(round_engine="loop", **kw).run()
    np.testing.assert_allclose(_flat(scan.global_params),
                               _flat(loop.global_params),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(scan.losses, loop.losses, rtol=1e-5)


def test_sampled_resume_replays_schedule(tmp_path):
    """A --resume re-entry mid-job re-derives the same sampled masks
    from (seed, round) and lands on the reference trajectory."""
    kw = dict(sample="poisson:0.6", max_dropout=1,
              dropout_scenario="shutdown", rounds=5, ckpt_every=2)
    ref = _job(**kw).run()
    job = _job(checkpoint_dir=str(tmp_path), **kw)
    job.run(rounds=3)
    res = job.run(rounds=5, resume=True)
    assert res.resumed_from == 2
    np.testing.assert_allclose(res.losses, ref.losses[3:], rtol=1e-5)
    np.testing.assert_allclose(_flat(res.global_params),
                               _flat(ref.global_params),
                               rtol=1e-4, atol=1e-5)


def test_sampled_round_participants_recorded():
    """history[r].active reflects the sampled∩available participants,
    not the availability mask alone."""
    res = _job(sample="uniform:2", rounds=4).run()
    for rec in res.history:
        assert rec["active"] == 2
