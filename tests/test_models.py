"""Per-architecture smoke tests (assigned-arch deliverable f) and
model-level correctness (decode consistency, blockwise attention, caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.models import transformer as T
from repro.models.attention import causal_mask, sdpa, sdpa_blockwise

LLM_ARCHS = [a for a in ARCH_IDS if a != "sanet_openkbp"]


def _tokens(cfg, b, l, key):
    shape = (b, l) if cfg.num_codebooks == 1 else (b, l, cfg.num_codebooks)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch_id", LLM_ARCHS)
def test_arch_smoke_forward_and_train_step(arch_id):
    """Reduced variant (≤2 layers, d_model≤512, ≤4 experts): one forward +
    one train step on CPU; asserts shapes and no NaNs."""
    mod = get_arch(arch_id)
    cfg = mod.reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = T.init(key, cfg)
    toks = _tokens(cfg, 2, 16, key)
    logits, aux = jax.jit(lambda p, t: T.forward(p, t, cfg))(params, toks)
    want = (2, 16, cfg.padded_vocab) if cfg.num_codebooks == 1 \
        else (2, 16, cfg.num_codebooks, cfg.padded_vocab)
    assert logits.shape == want
    # padded logit rows are masked to -inf; real rows finite
    real = np.asarray(logits)[..., : cfg.vocab_size]
    assert np.isfinite(real).all()

    def step(p):
        loss, _ = T.next_token_loss(p, {"tokens": toks}, cfg)
        return loss
    loss, grads = jax.value_and_grad(step)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch_id", LLM_ARCHS)
def test_decode_matches_forward(arch_id):
    """prefill(L-1) + decode(1) logits == full forward's last-position logits."""
    mod = get_arch(arch_id)
    cfg = mod.reduced()
    key = jax.random.PRNGKey(1)
    params = T.init(key, cfg)
    b, l = 2, 12
    toks = _tokens(cfg, b, l, key)
    full_logits, _ = T.forward(params, toks, cfg)
    _, caches = T.prefill(params, toks[:, : l - 1], cfg, cache_capacity=l,
                          moe_impl="dense")
    last = toks[:, l - 1: l]
    dec_logits, _ = T.decode_step(params, last, caches, cfg, moe_impl="dense")
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch_id", LLM_ARCHS)
def test_multi_step_decode_consistency(arch_id):
    """Prefill then 3 decode steps == teacher-forced forward logits."""
    mod = get_arch(arch_id)
    cfg = mod.reduced()
    key = jax.random.PRNGKey(2)
    params = T.init(key, cfg)
    b, l, extra = 1, 8, 3
    toks = _tokens(cfg, b, l + extra, key)
    full_logits, _ = T.forward(params, toks, cfg)
    _, caches = T.prefill(params, toks[:, :l], cfg, cache_capacity=l + extra,
                          moe_impl="dense")
    for i in range(extra):
        nxt = toks[:, l + i: l + i + 1]
        dec_logits, caches = T.decode_step(params, nxt, caches, cfg, moe_impl="dense")
        np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                                   np.asarray(full_logits[:, l + i]),
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"step {i}")


def test_blockwise_attention_matches_reference():
    key = jax.random.PRNGKey(0)
    for (b, lq, lk, hq, hkv, d, win, ch) in [
            (2, 64, 64, 4, 2, 32, None, 16), (1, 128, 128, 8, 8, 16, 48, 32),
            (2, 32, 512, 6, 3, 64, None, 128), (1, 96, 96, 9, 3, 64, 17, 32)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, lq, hq, d))
        k = jax.random.normal(ks[1], (b, lk, hkv, d))
        v = jax.random.normal(ks[2], (b, lk, hkv, d))
        ref = sdpa(q, k, v, causal_mask(lq, lk, win))
        blk = sdpa_blockwise(q, k, v, causal=True, window=win, chunk=ch)
        np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_scan_group_planning():
    """Layer grouping matches each architecture's published structure."""
    cases = {
        "deepseek_v2_236b": (1, 1, 59),    # dense layer 0 + 59 MLA/MoE
        "jamba_1p5_large_398b": (0, 8, 9),  # 8-layer period x 9
        "gemma3_1b": (2, 6, 4),            # 2 unrolled + 4 periods of 6
        "qwen3_8b": (0, 1, 36),
    }
    for arch_id, (n_prefix, period, reps) in cases.items():
        cfg = get_arch(arch_id).CONFIG
        prefix, group = T.plan_groups(cfg)
        assert len(prefix) == n_prefix, arch_id
        assert group.period == period and group.n_repeats == reps, arch_id


def test_jamba_layer_pattern():
    cfg = get_arch("jamba_1p5_large_398b").CONFIG
    specs = cfg.layer_specs()
    attn_layers = [i for i, s in enumerate(specs) if s.mixer == "attn"]
    assert attn_layers == list(range(4, 72, 8))          # 1:7 interleave
    moe_layers = [i for i, s in enumerate(specs) if s.ffn == "moe"]
    assert moe_layers == list(range(1, 72, 2))           # MoE every other layer


def test_gemma3_window_pattern():
    cfg = get_arch("gemma3_1b").CONFIG
    specs = cfg.layer_specs()
    for i, s in enumerate(specs):
        if (i + 1) % 6 == 0:
            assert s.sliding_window is None, i           # global
        else:
            assert s.sliding_window == 512, i            # local


def test_param_counts_match_model_cards():
    expected = {
        "deepseek_v2_236b": (236e9, 0.02),
        "jamba_1p5_large_398b": (398e9, 0.02),
        "qwen3_8b": (8.2e9, 0.05),
        "qwen3_moe_30b_a3b": (30.5e9, 0.05),
        "chameleon_34b": (34e9, 0.05),
        "gemma3_1b": (1.0e9, 0.1),
        "smollm_135m": (135e6, 0.05),
        "granite_3_2b": (2.5e9, 0.1),
        "musicgen_medium": (1.5e9, 0.15),
        "rwkv6_7b": (7.6e9, 0.1),
    }
    for arch_id, (want, tol) in expected.items():
        n = T.count_params(get_arch(arch_id).CONFIG)
        assert abs(n - want) / want < tol, (arch_id, n, want)


def test_moe_active_params():
    cfg = get_arch("qwen3_moe_30b_a3b").CONFIG
    active = T.count_params(cfg, active_only=True)
    assert abs(active - 3.3e9) / 3.3e9 < 0.1, active    # "A3B"


def test_moe_implementations_agree():
    """dense einsum, token-gather, and grouped capacity dispatch compute the
    same function (capacity high enough that nothing drops)."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import (moe_apply, moe_apply_dispatch,
                                  moe_apply_sparse, moe_init)
    cfg = MoEConfig(num_experts=4, top_k=2, d_expert=32,
                    num_shared_experts=1, d_shared=16)
    params = moe_init(jax.random.PRNGKey(0), 24, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 24))
    yd, auxd = moe_apply(params, x, cfg)
    yc, auxc = moe_apply_dispatch(params, x, cfg, capacity_factor=4.0,
                                  group_size=8)
    ys, auxs = moe_apply_sparse(params, x, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), atol=1e-4)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys), atol=1e-4)
    np.testing.assert_allclose(float(auxd), float(auxc), rtol=1e-5)


def test_moe_dispatch_drops_overflow():
    """With capacity_factor << 1 the dispatch path drops tokens (standard
    GShard semantics) but stays finite and shape-correct."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_apply_dispatch, moe_init
    cfg = MoEConfig(num_experts=4, top_k=2, d_expert=16)
    params = moe_init(jax.random.PRNGKey(0), 12, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 12))
    y, aux = moe_apply_dispatch(params, x, cfg, capacity_factor=0.25,
                                group_size=16)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
