"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import fedagg_ref, flash_attention_ref, rwkv6_scan_ref

KEY = jax.random.PRNGKey(7)

# The federation kernels normally run under the auto-selected interpret
# mode (the Pallas interpreter on this CPU container).  Setting
# REPRO_PALLAS_COMPILED=1 additionally sweeps the compiled
# interpret=False lowering — opt-in, for hardware that can lower it.
INTERPRET_MODES = [None] + ([False] if os.environ.get(
    "REPRO_PALLAS_COMPILED") == "1" else [])


@pytest.mark.parametrize("b,hq,hkv,l,d", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 32), (1, 8, 8, 128, 64),
    (1, 6, 3, 384, 64), (2, 1, 1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, hq, hkv, l, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, l, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, l, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, l, d), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)
    assert out.dtype == dtype


@pytest.mark.parametrize("window", [32, 100, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,l,d,chunk", [
    (1, 2, 64, 16, 16), (2, 4, 128, 32, 32), (1, 1, 256, 64, 128),
    (1, 2, 96, 64, 96),
])
def test_rwkv6_scan_shapes(b, h, l, d, chunk):
    ks = jax.random.split(KEY, 5)
    r, k, v = [jax.random.normal(kk, (b, h, l, d)) for kk in ks[:3]]
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, l, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    out = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
    ref, _ = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_rwkv6_scan_bf16():
    ks = jax.random.split(KEY, 5)
    r, k, v = [jax.random.normal(kk, (1, 2, 64, 32), jnp.bfloat16) for kk in ks[:3]]
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (1, 2, 64, 32))) * 0.5 + 0.45
         ).astype(jnp.bfloat16)
    u = (jax.random.normal(ks[4], (2, 32)) * 0.1).astype(jnp.bfloat16)
    out = ops.rwkv6_scan(r, k, v, w, u, chunk=32)
    ref, _ = rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0.05, atol=0.05)


@pytest.mark.parametrize("s,n,block", [(4, 1024, 256), (8, 4096, 4096),
                                       (16, 512, 512), (2, 65536, 65536)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("interpret", INTERPRET_MODES)
def test_fedagg_sweep(s, n, block, dtype, interpret):
    x = jax.random.normal(KEY, (s, n), dtype)
    w = jax.nn.softmax(jax.random.normal(KEY, (s,)))
    out = ops.fedagg(x, w, block_n=block, interpret=interpret)
    ref = fedagg_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("s,c,chunk,block_c", [(3, 7, 256, 4), (4, 16, 128, 16),
                                               (2, 1, 128, 32)])
@pytest.mark.parametrize("interpret", INTERPRET_MODES)
def test_fedagg_dequant_fuses_decode_and_fold(s, c, chunk, block_c, interpret):
    """The compressed round engine's one-pass server step: dequantize +
    Eq. 1 fold + error-feedback residual, vs the separate numpy codec."""
    from repro.comms.compression import MIN_SCALE
    rng = np.random.default_rng(7)
    u = (rng.normal(size=(s, c, chunk)) * 0.1).astype(np.float32)
    w = rng.dirichlet(np.ones(s)).astype(np.float32)
    scale = np.maximum(np.max(np.abs(u), axis=-1) / 127.0,
                       MIN_SCALE).astype(np.float32)
    q = np.clip(np.rint(u / scale[..., None]), -127, 127).astype(np.int8)
    deq = q.astype(np.float32) * scale[..., None]
    g, r = ops.fedagg_dequant(jnp.asarray(q), jnp.asarray(scale),
                              jnp.asarray(u), jnp.asarray(w),
                              block_c=block_c, interpret=interpret)
    np.testing.assert_allclose(np.asarray(g), np.einsum("s,sct->ct", w, deq),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(r), u - deq, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("interpret", INTERPRET_MODES)
def test_fedagg_dequant_matches_jnp_quantize_path(interpret):
    """Kernel quantize → fused fold agrees with the traced jnp twin the
    CPU engine path uses (quantize_dequantize_ref + einsum fold)."""
    from repro.kernels.quantize import quantize_dequantize_ref
    rng = np.random.default_rng(8)
    u = jnp.asarray((rng.normal(size=(4, 5, 128)) * 0.02).astype(np.float32))
    w = jnp.asarray(rng.dirichlet(np.ones(4)).astype(np.float32))
    q, sc = ops.quantize_int8(u.reshape(20, 128), interpret=interpret)
    g, _ = ops.fedagg_dequant(q.reshape(4, 5, 128), sc.reshape(4, 5), u, w,
                              interpret=interpret)
    deq = quantize_dequantize_ref(u)
    np.testing.assert_allclose(np.asarray(g),
                               np.einsum("s,sct->ct", np.asarray(w),
                                         np.asarray(deq)),
                               rtol=1e-6, atol=1e-7)


def test_fedagg_pytree_matches_eq1():
    """Kernel aggregation == Eq. 1 weighted mean on a realistic param tree."""
    from repro.core.stacking import weighted_mean
    key = jax.random.PRNGKey(3)
    tree = {"layer": {"w": jax.random.normal(key, (8, 32, 48)),
                      "b": jax.random.normal(key, (8, 48))},
            "head": jax.random.normal(key, (8, 48, 100))}
    w = jnp.asarray(np.random.default_rng(0).dirichlet(np.ones(8)), jnp.float32)
    out = ops.fedagg_pytree(tree, w)
    ref = weighted_mean(tree, w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s,n,f,block", [
    (4, 1024, 1, 256), (8, 4096, 2, 4096), (5, 512, 2, 512),
    (16, 300, 5, 512), (3, 65536, 1, 65536),
])
@pytest.mark.parametrize("interpret", INTERPRET_MODES)
def test_trimmed_mean_kernel_matches_ref(s, n, f, block, interpret):
    """Pallas coordinate-wise trimmed mean == the jnp twin, bit-exact
    (same op sequence per block), with every row active."""
    from repro.kernels.robust import trimmed_mean_ref
    x = jax.random.normal(KEY, (s, n), jnp.float32) * 3.0
    active = jnp.ones((s,), bool)
    out = ops.trimmed_mean(x, active, f, block_n=block, interpret=interpret)
    ref = trimmed_mean_ref(x, active, f)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("s,n,block", [(4, 1024, 256), (7, 2048, 2048),
                                       (9, 513, 1024)])
@pytest.mark.parametrize("interpret", INTERPRET_MODES)
def test_masked_median_kernel_matches_ref_and_numpy(s, n, block, interpret):
    """Pallas masked median == jnp twin bit-exact, and == np.median on
    the active rows (the trim-at-max-depth construction is a real
    median for odd AND even active counts)."""
    from repro.kernels.robust import masked_median_ref
    x = jax.random.normal(KEY, (s, n), jnp.float32) * 2.0
    mask = np.ones(s, bool)
    mask[:: max(s // 2, 1)] = True          # keep all, then drop one row
    mask[s - 1] = False
    active = jnp.asarray(mask)
    out = ops.masked_median(x, active, block_n=block, interpret=interpret)
    ref = masked_median_ref(x, active)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np_med = np.median(np.asarray(x)[mask], axis=0)
    np.testing.assert_allclose(np.asarray(out), np_med, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("interpret", INTERPRET_MODES)
def test_trimmed_mean_kernel_masked_rows(interpret):
    """Inactive rows are invisible: trimming over a masked [S, N] buffer
    equals trimming the compacted active-only buffer."""
    from repro.kernels.robust import trimmed_mean_ref
    s, n, f = 8, 768, 1
    x = jax.random.normal(KEY, (s, n), jnp.float32)
    mask = np.array([1, 0, 1, 1, 0, 1, 1, 1], bool)
    out = ops.trimmed_mean(x, jnp.asarray(mask), f, interpret=interpret)
    compact = ops.trimmed_mean(jnp.asarray(np.asarray(x)[mask]),
                               jnp.ones(int(mask.sum()), bool), f,
                               interpret=interpret)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(compact))
    ref = trimmed_mean_ref(x, jnp.asarray(mask), f)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("b,l,di,ds,chunk,blk", [
    (1, 64, 32, 8, 16, 16), (2, 128, 64, 16, 64, 32), (1, 96, 48, 8, 96, 48),
])
def test_mamba_scan_kernel(b, l, di, ds, chunk, blk):
    from repro.kernels.ref import mamba_scan_ref
    ks = jax.random.split(KEY, 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, l, di)) - 1.0)
    b_mat = jax.random.normal(ks[1], (b, l, ds))
    c_mat = jax.random.normal(ks[2], (b, l, ds))
    x = jax.random.normal(ks[3], (b, l, di))
    log_a = jnp.log(jnp.broadcast_to(jnp.arange(1.0, ds + 1.0), (di, ds)))
    out = ops.mamba_scan(dt, b_mat, c_mat, x, log_a, chunk=chunk, block_di=blk)
    ref, _ = mamba_scan_ref(dt, b_mat, c_mat, x, log_a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
