"""Two-tier pod federation (ISSUE 5 tentpole): the Topology config, the
engine's segment-reduce by pod id, flat↔pods parity on all three
transports and both stacked engines, pod-tier Algorithm-2 churn, the
per-tier scheduler seam, and the intra/cross-pod byte split."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.core.agg_engine import AggregationEngine
from repro.core.session import BufferedScheduler, availability_masks
from repro.core.topology import (FLAT, Topology, active_pod_counts,
                                 pod_availability_masks, resolve_topology)


def _token_job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4, batch=2,
                        seq=16, heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=3, lr=1e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def _assert_trees_close(a, b, rtol=2e-3, atol=1e-4):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Topology config units
# ---------------------------------------------------------------------------


def test_resolve_topology():
    assert resolve_topology(None) is FLAT
    assert resolve_topology("flat") is FLAT
    t = resolve_topology("pods:3")
    assert t.is_pods and t.num_pods == 3
    assert resolve_topology(t) is t
    with pytest.raises(ValueError, match="pods:<K>"):
        resolve_topology("pods")
    with pytest.raises(KeyError):
        resolve_topology("ring")
    with pytest.raises(ValueError, match="kind"):
        Topology(kind="mesh")
    with pytest.raises(ValueError, match="combine"):
        Topology.pods(2, intra="median")


def test_pod_assignment():
    t = Topology.pods(2)
    np.testing.assert_array_equal(t.pod_of(4), [0, 0, 1, 1])
    np.testing.assert_array_equal(t.pod_of(5), [0, 0, 0, 1, 1])
    np.testing.assert_array_equal(FLAT.pod_of(3), [0, 0, 0])
    custom = Topology.pods(2, assignment=(1, 0, 1, 0))
    np.testing.assert_array_equal(custom.pod_of(4), [1, 0, 1, 0])
    with pytest.raises(ValueError, match="covers"):
        custom.pod_of(5)
    with pytest.raises(ValueError, match="pod ids"):
        Topology.pods(2, assignment=(0, 0, 2, 1)).pod_of(4)
    with pytest.raises(ValueError, match="empty pods"):
        Topology.pods(5).pod_of(3)
    with pytest.raises(ValueError, match="no sites"):
        Topology.pods(2, assignment=(0, 0, 0)).validate(3)


# ---------------------------------------------------------------------------
# Engine: segment-reduce by pod id == flat Eq. 1 (weighted means compose)
# ---------------------------------------------------------------------------


def _random_stacked(s, key=0):
    rng = np.random.default_rng(key)
    return {"a": jnp.asarray(rng.normal(size=(s, 7, 3)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(s, 11)), jnp.float32)}


def test_engine_pods_equals_flat_arbitrary_assignment():
    """Case-weighted per-pod means recombined at the pod weights equal
    the flat case-weighted mean — for ANY assignment, with churn."""
    s = 6
    tree = _random_stacked(s)
    cw = jnp.asarray([3.0, 1.0, 2.0, 5.0, 1.0, 4.0])
    active = jnp.asarray([True, True, False, True, True, True])
    eng = AggregationEngine()
    _, flat_g = eng.aggregate(tree, cw, active)
    for pod_ids, npods in ([[0, 0, 0, 1, 1, 1], 2], [[2, 0, 1, 0, 2, 1], 3],
                           [[0] * 6, 1]):
        new, g = eng.aggregate_pods(tree, cw, jnp.asarray(pod_ids), npods,
                                    active)
        _assert_trees_close(flat_g, g, rtol=1e-5, atol=1e-6)
        # inactive sites keep their local weights
        np.testing.assert_array_equal(np.asarray(new["a"][2]),
                                      np.asarray(tree["a"][2]))


def test_hierarchical_rejects_ragged_sites_per_pod():
    """A non-dividing sites_per_pod must fail loudly (the tail site
    would otherwise silently fall out of every pod's mean)."""
    eng = AggregationEngine()
    tree = _random_stacked(5)
    with pytest.raises(ValueError, match="divide"):
        eng.aggregate_hierarchical(tree, jnp.ones(5), sites_per_pod=2)


def test_engine_pods_uniform_tiers():
    """uniform intra/inter combines are means over members/pods — a
    different (valid) statistic from the case-weighted flat mean."""
    s = 4
    tree = _random_stacked(s)
    cw = jnp.asarray([10.0, 1.0, 1.0, 1.0])
    eng = AggregationEngine()
    _, g_uni = eng.aggregate_pods(tree, cw, jnp.asarray([0, 0, 1, 1]), 2,
                                  intra="uniform", inter="uniform")
    expect = jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)
    _assert_trees_close(expect, g_uni, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Pod-tier Algorithm-2 churn
# ---------------------------------------------------------------------------


def test_pod_availability_masks():
    topo = Topology.pods(3)
    m = pod_availability_masks(topo, 6, 1, seed=7, rounds=40)
    m2 = pod_availability_masks(topo, 6, 1, seed=7, rounds=40)
    np.testing.assert_array_equal(m, m2)            # deterministic replay
    pod_of = topo.pod_of(6)
    for r in range(40):
        off = {p for p in range(3) if not m[r][pod_of == p].any()}
        # a pod is off as a unit, and at most pod_dropout pods at once
        for p in range(3):
            assert m[r][pod_of == p].all() or not m[r][pod_of == p].any()
        assert len(off) <= 1
    assert (~m).any()                               # churn actually happens
    with pytest.raises(ValueError, match="num_pods"):
        pod_availability_masks(topo, 6, 3, seed=0, rounds=2)


def test_masks_compose_site_and_pod_tiers():
    topo = Topology.pods(2)
    combined = availability_masks(4, 1, seed=3, rounds=30, topology=topo,
                                  pod_dropout=1)
    site_only = availability_masks(4, 1, seed=3, rounds=30)
    pod_only = pod_availability_masks(topo, 4, 1, seed=3, rounds=30)
    raw = site_only & pod_only
    nonempty = raw.any(axis=1)
    np.testing.assert_array_equal(combined[nonempty], raw[nonempty])
    counts = active_pod_counts(topo, combined)
    assert counts.min() >= 1                        # never a dead federation


def test_empty_intersection_falls_back_to_pod_tier():
    """Each Algorithm-2 chain guarantees survivors; their intersection
    does not (all surviving sites can sit in dropped pods).  Such rounds
    would deadlock the sync barriers and zero the Eq. 1 weights, so the
    pod-tier mask takes precedence there — deterministically."""
    topo = Topology.pods(2)
    for seed in range(100):
        site = availability_masks(2, 1, seed=seed, rounds=40)
        pod = pod_availability_masks(topo, 2, 1, seed=seed, rounds=40)
        raw = site & pod
        empty = ~raw.any(axis=1)
        if empty.any():
            combined = availability_masks(2, 1, seed=seed, rounds=40,
                                          topology=topo, pod_dropout=1)
            assert combined.any(axis=1).all()       # no dead rounds
            np.testing.assert_array_equal(combined[empty], pod[empty])
            np.testing.assert_array_equal(combined[~empty], raw[~empty])
            return
    pytest.fail("no seed produced an empty intersection to exercise")


def test_pod_dropout_requires_pods():
    with pytest.raises(ValueError, match="pods"):
        _token_job(pod_dropout=1).masks(3)


# ---------------------------------------------------------------------------
# Flat ↔ pods parity, all transports (acceptance criterion)
# ---------------------------------------------------------------------------


def test_stacked_pods_equals_flat_uniform_weights():
    """With uniform weights and fedavg at both tiers, the 2-tier global
    is the flat global — on the scan engine and the loop oracle."""
    flat = _token_job().run()
    pods = _token_job(topology="pods:2").run()
    _assert_trees_close(flat.global_params, pods.global_params,
                        rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(flat.losses, pods.losses, rtol=1e-4)
    one = _token_job(topology="pods:1").run()
    _assert_trees_close(flat.global_params, one.global_params,
                        rtol=1e-4, atol=1e-6)


def test_stacked_pods_equals_flat_case_weighted():
    """Nonuniform m_i: per-pod partials at case weights recombined at the
    pod totals still equal flat Eq. 1 (the composition law, end to end)."""
    flat = _token_job(case_counts=(5, 1, 2, 8)).run()
    pods = _token_job(case_counts=(5, 1, 2, 8), topology="pods:2").run()
    _assert_trees_close(flat.global_params, pods.global_params,
                        rtol=1e-4, atol=1e-6)


def test_scan_matches_loop_pods_with_churn():
    job = _token_job(topology="pods:2", max_dropout=1, pod_dropout=1,
                     rounds=4, seed=3)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(loop.losses, scan.losses, rtol=1e-4)
    assert loop.comm["cross_pod_upload_bytes"] == \
        scan.comm["cross_pod_upload_bytes"]


def test_thread_pods_matches_stacked_and_splits_bytes():
    """The two-tier server stack (pod servers + leader relays + root)
    reproduces the stacked pods global, and JobResult.comm reports
    intra-pod vs cross-pod wire bytes separately."""
    job = _token_job(topology="pods:2")
    stacked = job.run()
    threaded = job.replace(transport="thread").run()
    _assert_trees_close(stacked.global_params, threaded.global_params)
    comm = threaded.comm
    assert not comm["simulated"] and comm["pods"] == 2
    assert comm["intra_pod_upload_bytes"] > 0
    assert comm["cross_pod_upload_bytes"] > 0
    # 4 sites upload per round intra; only 2 pod partials cross — the
    # cross-pod (WAN) link carries about half the intra volume here
    assert comm["cross_pod_upload_bytes"] < comm["intra_pod_upload_bytes"]
    assert comm["upload_bytes"] == (comm["intra_pod_upload_bytes"]
                                    + comm["cross_pod_upload_bytes"])
    # the stacked simulator predicts the same split shape
    assert stacked.comm["pods"] == 2
    assert stacked.comm["cross_pod_upload_bytes"] < \
        stacked.comm["intra_pod_upload_bytes"]


def test_tcp_pods_end_to_end():
    """One OS process per site, two pod servers, root combiner — the
    full 2-tier deployment shape matches the flat stacked run under
    identity settings."""
    job = _token_job(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=2, batch=2,
                        seq=16, seed=0),
        rounds=2, topology="pods:2")
    flat = job.replace(topology="flat").run()
    tcp = job.replace(transport="tcp").run()
    _assert_trees_close(flat.global_params, tcp.global_params)
    assert tcp.comm["pods"] == 2
    assert tcp.comm["cross_pod_upload_bytes"] > 0


def test_thread_pods_survives_whole_pod_dropout():
    """A fully-offline pod (Algorithm-2 churn at the pod tier) skips its
    partial and root upload for the round; the surviving pods' barrier
    uses the active-pod count, so nothing deadlocks."""
    job = _token_job(rounds=4, seed=3, topology="pods:2", pod_dropout=1,
                     transport="thread")
    masks = job.masks(4)
    pod_of = job.topo.pod_of(4)
    assert any(not masks[r][pod_of == p].any()
               for r in range(4) for p in range(2))   # seed picked to churn
    res = job.run()
    assert np.isfinite(np.asarray(res.losses)).all()
    assert res.comm["upload_count"] == int(masks.sum())


# ---------------------------------------------------------------------------
# Per-tier scheduler seam
# ---------------------------------------------------------------------------


def test_per_tier_scheduler_compositions_thread():
    """sync-within-pod + buffered-across-pods, and the reverse, both run
    over the socket stack.  buffer_k=2 covers the root-buffer-not-ready
    window: a leader whose want=0 download returns nothing installs its
    own pod partial instead of leaving its barrier sites blocked
    (regression — this used to deadlock round 1)."""
    for topo in (Topology.pods(2, inter_scheduler=BufferedScheduler(buffer_k=2)),
                 Topology.pods(2, inter_scheduler=BufferedScheduler(buffer_k=1)),
                 Topology.pods(2, intra_scheduler=BufferedScheduler(buffer_k=1))):
        res = _token_job(rounds=3, topology=topo, transport="thread").run()
        assert np.isfinite(res.losses).all()
        assert res.comm["cross_pod_upload_bytes"] > 0


def test_stacked_rejects_buffered_pods():
    with pytest.raises(ValueError, match="synchronously"):
        _token_job(topology="pods:2", scheduler="buffered").run()
    with pytest.raises(ValueError, match="synchronously"):
        _token_job(topology=Topology.pods(
            2, inter_scheduler=BufferedScheduler(buffer_k=1))).run()


def test_pods_require_central_strategy():
    with pytest.raises(ValueError, match="fedavg/fedprox"):
        _token_job(strategy="gcml", topology="pods:2").run()
    with pytest.raises(ValueError, match="fedavg/fedprox"):
        _token_job(strategy="individual", topology="pods:2",
                   transport="thread").run()


# ---------------------------------------------------------------------------
# The job surface
# ---------------------------------------------------------------------------


def test_train_cli_topology_flags():
    from repro.launch.train import make_parser
    args = make_parser().parse_args(["--topology", "pods:2",
                                     "--pod-dropout", "1"])
    assert args.topology == "pods:2" and args.pod_dropout == 1
    assert make_parser().parse_args([]).topology == "flat"


def test_uniform_tiers_match_across_transports():
    """intra/inter="uniform" must mean the same statistic on the socket
    stack as on the engine: pod servers fold members at weight 1 and
    leaders re-upload at weight 1 (regression — sockets used to run
    every combine as fedavg silently)."""
    topo = Topology.pods(2, intra="uniform", inter="uniform")
    job = _token_job(case_counts=(5, 1, 2, 8), topology=topo)
    stacked = job.run()
    threaded = job.replace(transport="thread").run()
    _assert_trees_close(stacked.global_params, threaded.global_params)
    # and the knob is not a no-op: it differs from the fedavg combine
    fedavg = _token_job(case_counts=(5, 1, 2, 8),
                        topology=Topology.pods(2)).run()
    delta = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                for a, b in zip(jax.tree.leaves(stacked.global_params),
                                jax.tree.leaves(fedavg.global_params)))
    assert delta > 1e-5


def test_fedprox_pods_thread_matches_stacked():
    """FedProx's proximal anchor follows the pod-installed global on the
    socket path and the aggregate_round global on the stacked path —
    same math, two implementations."""
    job = _token_job(strategy="fedprox", topology="pods:2")
    stacked = job.run()
    threaded = job.replace(transport="thread").run()
    _assert_trees_close(stacked.global_params, threaded.global_params)
