"""AggregationEngine: the single Eq. 1 implementation, on both backends.

The reference is the original per-leaf einsum math (``stacking.
weighted_mean`` + ``where_site``), kept independent of the engine so the
comparison is meaningful.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agg_engine import (AggregationEngine, StreamingAccumulator,
                                   get_engine, normalized_weights)
from repro.core.aggregation import fedavg_aggregate, hierarchical_aggregate
from repro.core.stacking import broadcast_to_sites, weighted_mean, where_site

KEY = jax.random.PRNGKey(11)


def _mixed_tree(s, seed=0):
    """Odd leaf sizes (N = 13·3 + 5 + 111 + 1 = 156... deliberately not a
    block multiple) and mixed dtypes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "conv": {"w": jax.random.normal(ks[0], (s, 13, 3)),
                 "b": jax.random.normal(ks[1], (s, 5)).astype(jnp.float16)},
        "head": jax.random.normal(ks[2], (s, 111)).astype(jnp.bfloat16),
        "scale": (jax.random.normal(ks[3], (s, 1)),),
    }


def _reference(tree, cw, active):
    w = normalized_weights(cw, active)
    g = weighted_mean(tree, w)
    new = where_site(active, broadcast_to_sites(g, cw.shape[0]), tree)
    return new, g


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("s", [2, 4, 7])
@pytest.mark.parametrize("engine_kw", [
    {"use_pallas": False},
    {"use_pallas": True, "interpret": True, "block_n": 64},   # forces padding
])
def test_engine_matches_reference(s, engine_kw):
    rng = np.random.default_rng(s)
    tree = _mixed_tree(s, seed=s)
    cw = jnp.asarray(rng.uniform(0.5, 3.0, s), jnp.float32)
    active = jnp.asarray(rng.random(s) > 0.3)
    if not bool(active.any()):
        active = jnp.ones((s,), bool)
    eng = AggregationEngine(**engine_kw)
    new, g = eng.aggregate(tree, cw, active)
    ref_new, ref_g = _reference(tree, cw, active)
    # fp16/bf16 leaves: tolerance set by the half-precision cast-back
    _assert_trees_close(g, ref_g, rtol=1e-2, atol=1e-2)
    _assert_trees_close(new, ref_new, rtol=1e-2, atol=1e-2)
    # fp32 leaves must match tightly
    np.testing.assert_allclose(np.asarray(g["conv"]["w"]),
                               np.asarray(ref_g["conv"]["w"]),
                               rtol=1e-5, atol=1e-5)


def test_pallas_path_matches_jnp_path_odd_n():
    """Kernel path (padded, interpret) ≡ jnp fallback to ≤1e-5 when N is
    not a multiple of block_n."""
    s, n = 5, 1000                                  # 1000 % 128 != 0
    x = {"w": jax.random.normal(KEY, (s, n))}
    cw = jnp.asarray(np.random.default_rng(1).uniform(0.1, 2.0, s), jnp.float32)
    jnp_eng = AggregationEngine(use_pallas=False)
    pal_eng = AggregationEngine(use_pallas=True, interpret=True, block_n=128)
    _, gj = jnp_eng.aggregate(x, cw)
    _, gp = pal_eng.aggregate(x, cw)
    np.testing.assert_allclose(np.asarray(gp["w"]), np.asarray(gj["w"]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s,spp", [(4, 2), (8, 4)])
def test_hierarchical_equals_flat_through_engine(s, spp):
    tree = _mixed_tree(s, seed=s)
    rng = np.random.default_rng(s)
    cw = jnp.asarray(rng.uniform(0.5, 2.0, s), jnp.float32)
    active = jnp.asarray([True] * (s - 1) + [False])
    flat_new, gf = fedavg_aggregate(tree, cw, active)
    hier_new, gh = hierarchical_aggregate(tree, cw, sites_per_pod=spp,
                                          active=active)
    _assert_trees_close(gf, gh, rtol=1e-2, atol=1e-2)
    _assert_trees_close(flat_new, hier_new, rtol=1e-2, atol=1e-2)


def test_wrappers_route_through_engine():
    """fedavg_aggregate is literally the shared engine (one implementation)."""
    tree = {"w": jnp.arange(12.0).reshape(4, 3)}
    cw = jnp.array([1.0, 2.0, 3.0, 4.0])
    new_w, g_w = fedavg_aggregate(tree, cw)
    new_e, g_e = get_engine().aggregate(tree, cw)
    np.testing.assert_array_equal(np.asarray(g_w["w"]), np.asarray(g_e["w"]))
    np.testing.assert_array_equal(np.asarray(new_w["w"]), np.asarray(new_e["w"]))


def test_engine_inside_jit():
    """post_exchange runs under jit — the engine must be traceable."""
    tree = _mixed_tree(4, seed=9)
    cw = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)

    @jax.jit
    def agg(t, w, active):
        return get_engine().aggregate(t, w, active)[1]

    g = agg(tree, cw, jnp.ones((4,), bool))
    ref = _reference(tree, cw, jnp.ones((4,), bool))[1]
    _assert_trees_close(g, ref, rtol=1e-2, atol=1e-2)


def test_layout_cache_reused():
    eng = AggregationEngine(use_pallas=False)
    tree = _mixed_tree(3)
    l1 = eng.layout_of(tree)
    l2 = eng.layout_of(tree)
    assert l1 is l2
    assert l1.n == sum(x[0].size for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# StreamingAccumulator / AggregationServer O(N) state
# ---------------------------------------------------------------------------


def test_streaming_accumulator_matches_weighted_average():
    rng = np.random.default_rng(0)
    trees = [{"a": rng.normal(size=(17,)).astype(np.float32),
              "b": {"c": rng.normal(size=(4, 3)).astype(np.float16)}}
             for _ in range(5)]
    ws = [1.0, 2.0, 0.5, 3.0, 1.5]
    tot = sum(ws)
    # expectations first: fold() adopts writable fp32 leaves in place
    want_a = sum(np.float32(w / tot) * t["a"] for t, w in zip(trees, ws))
    want_c = sum(np.float32(w / tot) * t["b"]["c"].astype(np.float32)
                 for t, w in zip(trees, ws))
    acc = StreamingAccumulator()
    for t, w in zip(trees, ws):
        acc.fold(t, w)
    g = acc.finalize()
    np.testing.assert_allclose(g["a"], want_a, rtol=1e-5)
    np.testing.assert_allclose(g["b"]["c"], want_c, rtol=1e-3)
    assert acc.count == 0 and acc.nbytes == 0        # reset for next round


def test_accumulator_folds_writable_fp32_in_place():
    x = np.arange(6, dtype=np.float32)
    acc = StreamingAccumulator()
    acc.fold({"w": x}, 2.0)
    # the writable fp32 upload was scaled in place and adopted (no copy)
    assert np.shares_memory(acc._acc[0], x)


def test_aggregation_server_holds_one_accumulator_mid_round():
    """O(N) server state: after S-1 uploads the server retains exactly one
    fp32 model-sized accumulator, not S decoded uploads."""
    from repro.comms.coordinator import AggregationServer
    from repro.comms.peer import Peer

    n = 1024
    model_bytes = n * 4                               # fp32 accumulator
    agg = AggregationServer("127.0.0.1", 0, num_sites=4,
                            case_weights=[1.0, 2.0, 3.0, 4.0])
    peers = [Peer(i) for i in range(4)]
    try:
        for i in range(3):                            # 3 of 4 sites report
            peers[i].upload(agg.addr, {"w": np.full(n, float(i), np.float32)}, 1)
        with agg._lock:
            assert agg._acc.count == 3
            assert agg._acc.nbytes == model_bytes     # one model, not three
            assert not hasattr(agg, "_uploads")       # the O(S·N) dict is gone
        peers[3].upload(agg.addr, {"w": np.full(n, 3.0, np.float32)}, 1)
        g = peers[0].download(agg.addr, 1)
        want = sum(i * (i + 1) for i in range(4)) / 10.0
        np.testing.assert_allclose(g["w"], want, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_aggregation_server_ignores_duplicate_upload():
    from repro.comms.coordinator import AggregationServer
    from repro.comms.peer import Peer

    agg = AggregationServer("127.0.0.1", 0, num_sites=2)
    peers = [Peer(i) for i in range(2)]
    try:
        peers[0].upload(agg.addr, {"w": np.full(3, 2.0, np.float32)}, 1)
        peers[0].upload(agg.addr, {"w": np.full(3, 2.0, np.float32)}, 1)
        with agg._lock:
            assert agg._acc.count == 1                # not double-folded
        peers[1].upload(agg.addr, {"w": np.full(3, 4.0, np.float32)}, 1)
        g = peers[0].download(agg.addr, 1)
        np.testing.assert_allclose(g["w"], 3.0, rtol=1e-6)
    finally:
        for p in peers:
            p.close()
        agg.stop()
