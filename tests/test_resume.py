"""Crash-resumable jobs: ``FederatedJob.run(resume=True)`` re-enters a
killed run from the newest usable checkpoint and continues with a
loss trajectory identical to the uninterrupted run — on the stacked
engines (scan and loop, with/without compression, buffered) and on the
socket transports (driver + per-site sub-stores, common-round rule)."""
import jax
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.core.session import BufferedScheduler


def _job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=3, batch=2,
                        seq=16, seed=0),
        strategy="fedavg", rounds=5, lr=1e-3, seed=0, ckpt_every=2)
    base.update(kw)
    return FederatedJob(**base)


def _flat(tree):
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree.leaves(tree)])


def _assert_resume_parity(job_kw, tmp_path, first_rounds, rounds,
                          expect_from):
    """Uninterrupted run vs run(first_rounds) + run(resume=True): the
    resumed tail must reproduce the reference trajectory exactly-ish and
    land on the same global model."""
    ref = _job(rounds=rounds, **job_kw).run()
    job = _job(rounds=rounds, checkpoint_dir=str(tmp_path), **job_kw)
    job.run(rounds=first_rounds)
    res = job.run(rounds=rounds, resume=True)
    assert res.resumed_from == expect_from
    assert len(res.history) == rounds - expect_from - 1
    np.testing.assert_allclose(res.losses, ref.losses[expect_from + 1:],
                               rtol=1e-5)
    np.testing.assert_allclose(_flat(res.global_params),
                               _flat(ref.global_params),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Stacked engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(),                                            # sync scan
    dict(round_engine="loop"),                         # retired host loop
    dict(compression="int8"),                          # compressed scan
    dict(compression="int8", round_engine="loop"),     # compressed loop
    dict(scheduler=BufferedScheduler(buffer_k=2)),     # FedBuff scan
], ids=["scan", "loop", "int8-scan", "int8-loop", "buffered-scan"])
def test_stacked_resume_parity(kw, tmp_path):
    # first run covers rounds 0..2, driver_state lands on the ckpt grid
    # at rounds 0 and 2 → the resume re-enters from round 2
    _assert_resume_parity(kw, tmp_path, first_rounds=3, rounds=5,
                          expect_from=2)


def test_resume_without_checkpoint_dir_raises():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _job().run(resume=True)


def test_resume_empty_store_is_fresh_start(tmp_path):
    """resume=True with nothing on disk starts from round 0 (the CI
    kill-and-resume job passes --resume unconditionally)."""
    res = _job(checkpoint_dir=str(tmp_path)).run(resume=True)
    assert res.resumed_from is None
    assert len(res.history) == 5


def test_resume_after_completion_is_a_noop_run(tmp_path):
    """A crash-loop supervisor passes --resume unconditionally; resuming
    a job whose final round is already checkpointed executes zero rounds
    and must still report cleanly (final_loss = nan, empty history)."""
    job = _job(checkpoint_dir=str(tmp_path), ckpt_every=1, rounds=3)
    done = job.run()
    res = job.run(resume=True)
    assert res.resumed_from == 2
    assert res.history == []
    assert np.isnan(res.final_loss)
    assert np.isnan(res.to_dict()["final_loss"])
    np.testing.assert_allclose(_flat(res.global_params),
                               _flat(done.global_params), rtol=1e-5)


def test_resume_engine_mismatch_raises(tmp_path):
    """A loop-engine checkpoint cannot seed a scan-engine resume — the
    carries differ; the guard fires before any shaped load."""
    job = _job(checkpoint_dir=str(tmp_path), round_engine="loop")
    job.run(rounds=3)
    with pytest.raises(ValueError, match="engine"):
        _job(checkpoint_dir=str(tmp_path), round_engine="scan").run(
            resume=True)


def test_buffered_loop_resume_rejected(tmp_path):
    """The buffered HOST loop carries a mid-round accumulator that is
    not checkpointable; resuming it is a typed error pointing at the
    scan engine (which checkpoints its full carry)."""
    kw = dict(scheduler=BufferedScheduler(buffer_k=2), round_engine="loop")
    job = _job(checkpoint_dir=str(tmp_path),
               scheduler=BufferedScheduler(buffer_k=2))
    job.run(rounds=3)                      # scan engine writes driver_state
    with pytest.raises(ValueError):
        _job(checkpoint_dir=str(tmp_path), **kw).run(resume=True)


# ---------------------------------------------------------------------------
# Socket transports (driver store + per-site sub-stores)
# ---------------------------------------------------------------------------


def test_thread_transport_resume_parity(tmp_path):
    _assert_resume_parity(
        dict(transport="thread",
             task=TaskConfig(kind="tokens", arch="smollm-135m", sites=2,
                             batch=2, seq=16, seed=0),
             ckpt_every=1),
        tmp_path, first_rounds=2, rounds=4, expect_from=1)


def test_tcp_transport_resume_parity(tmp_path):
    """One OS process per site, killed after 2 of 4 rounds (simulated by
    a short first run): --resume re-enters at the newest round present
    in the driver store AND every site sub-store."""
    _assert_resume_parity(
        dict(transport="tcp",
             task=TaskConfig(kind="tokens", arch="smollm-135m", sites=2,
                             batch=2, seq=16, seed=0),
             ckpt_every=1, io_timeout=120),
        tmp_path, first_rounds=2, rounds=4, expect_from=1)


def test_socket_resume_requires_checkpoint_dir():
    job = _job(transport="thread")
    with pytest.raises(ValueError, match="checkpoint_dir"):
        job.run(resume=True)
