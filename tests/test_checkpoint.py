"""Checkpoint store: atomic payload/manifest writes and crash-window
recovery (a manifest entry whose payload never landed is skipped)."""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.checkpoint.store import load_pytree, save_pytree


def _tree(scale=1.0):
    return {"w": np.arange(6, dtype=np.float32) * scale,
            "b": {"inner": np.ones((2, 3), np.float32) * scale}}


def test_save_pytree_roundtrip_no_droppings(tmp_path):
    """Atomic save leaves exactly the target file — no stray tmp files
    (regression: np.savez given a *name* appends .npz, which forced
    rename juggling that could strand or mispick candidates)."""
    path = tmp_path / "model.npz"
    save_pytree(path, _tree())
    assert sorted(p.name for p in tmp_path.iterdir()) == ["model.npz"]
    loaded = load_pytree(path, _tree(0.0))
    np.testing.assert_array_equal(loaded["w"], _tree()["w"])
    np.testing.assert_array_equal(loaded["b"]["inner"], _tree()["b"]["inner"])


def test_save_pytree_overwrite_is_atomic_replace(tmp_path):
    path = tmp_path / "model.npz"
    save_pytree(path, _tree(1.0))
    save_pytree(path, _tree(2.0))
    assert sorted(p.name for p in tmp_path.iterdir()) == ["model.npz"]
    loaded = load_pytree(path, _tree(0.0))
    np.testing.assert_array_equal(loaded["w"], _tree(2.0)["w"])


def test_store_save_load_and_meta(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save("global", 0, _tree(1.0), meta={"engine": "sync-loop"})
    store.save("global", 2, _tree(3.0))
    assert store.saved_rounds("global") == [0, 2]
    tree, meta = store.load("global", 0, _tree(0.0))
    np.testing.assert_array_equal(tree["w"], _tree(1.0)["w"])
    assert meta == {"engine": "sync-loop"}
    assert store.meta("global", 0) == {"engine": "sync-loop"}
    with pytest.raises(KeyError):
        store.load("global", 1, _tree(0.0))
    with pytest.raises(KeyError):
        store.meta("global", 1)


def test_store_same_round_resave_replaces(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save("global", 4, _tree(1.0))
    store.save("global", 4, _tree(9.0))
    assert store.saved_rounds("global") == [4]
    tree, _ = store.load("global", 4, _tree(0.0))
    np.testing.assert_array_equal(tree["w"], _tree(9.0)["w"])


def test_store_retention_keeps_last_k(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for r in range(5):
        store.save("global", r, _tree(float(r)))
    assert store.saved_rounds("global") == [3, 4]
    # evicted payloads actually removed from disk
    npzs = sorted(p.name for p in tmp_path.glob("global_round*.npz"))
    assert npzs == ["global_round000003.npz", "global_round000004.npz"]


def test_crash_window_latest_skips_lost_payload(tmp_path):
    """Simulated crash between manifest write and payload landing: the
    dangling entry (and any stray *.tmp dropping) must not break
    ``latest`` — it returns the newest entry whose payload survived."""
    store = CheckpointStore(tmp_path)
    store.save("global", 0, _tree(1.0))
    store.save("global", 2, _tree(3.0))
    # crash artifacts: a half-written tmp file + a manifest entry whose
    # payload was lost
    (tmp_path / "garbage.tmp").write_bytes(b"\x00\x01partial")
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    manifest["rounds"]["global"].append(
        {"round": 4, "file": "global_round000004.npz", "meta": {}})
    (tmp_path / "manifest.json").write_text(json.dumps(manifest))
    reopened = CheckpointStore(tmp_path)
    assert reopened.saved_rounds("global") == [0, 2]
    tree, rnd = reopened.latest("global", _tree(0.0))
    assert rnd == 2
    np.testing.assert_array_equal(tree["w"], _tree(3.0)["w"])


def test_latest_on_empty_store(tmp_path):
    store = CheckpointStore(tmp_path)
    tree, rnd = store.latest("global", _tree(0.0))
    assert tree is None and rnd == -1
