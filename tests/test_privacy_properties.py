"""Property-based secure-aggregation suite (needs ``hypothesis``).

The masking construction's core claim, checked over arbitrary inputs:
for ANY participant subset, dropout pattern, fold order, pod
assignment, and round index, the masked fixed-point integer fold —
after seed recovery for scheduled-but-missing ids — equals the
plaintext fixed-point sum BIT-EXACTLY.  Exactness matters: the masks
live in modular uint64 arithmetic, so any off-by-one in the pair-stream
bookkeeping corrupts whole words, not low bits.
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.privacy import (FRAC_BITS, SecureAggClient, SecureAggState,
                           masked_values)  # noqa: E402
from repro.privacy.secure_agg import _fixed_point  # noqa: E402


def _decode(int_leaves, weight_total):
    """The float decode SecureAggState applies to a recovered int sum."""
    inv = 1.0 / (float(2 ** FRAC_BITS) * weight_total)
    return [(x.view(np.int64).astype(np.float64) * inv).astype(np.float32)
            for x in int_leaves]


def _masked_sum(acc, enc):
    ints = jax.tree.leaves(masked_values(enc))
    return ints if acc is None else [a + x for a, x in zip(acc, ints)]


@st.composite
def _mask_cases(draw):
    n = draw(st.integers(2, 6))
    scheduled = sorted(draw(st.sets(st.integers(0, n - 1), min_size=2,
                                    max_size=n)))
    folded = sorted(draw(st.sets(st.sampled_from(scheduled), min_size=1)))
    order = list(draw(st.permutations(folded)))
    weights = [draw(st.floats(0.25, 4.0)) for _ in range(n)]
    round_index = draw(st.integers(0, 50))
    return n, scheduled, order, weights, round_index


@settings(max_examples=60, deadline=None)
@given(_mask_cases())
def test_masked_sum_equals_unmasked_bit_exact(case):
    """Arbitrary subsets / dropout orders / round indices: the unmasked
    fold is the exact plaintext fixed-point sum of the sites that DID
    fold, and dropout repair fires iff someone scheduled went missing."""
    n, scheduled, order, weights, round_index = case
    rng = np.random.default_rng(round_index + 17 * n)
    models = {i: {"a": rng.normal(size=(4,)).astype(np.float32),
                  "b": rng.normal(size=(3,)).astype(np.float32)}
              for i in scheduled}
    masks = np.zeros((round_index + 1, n), bool)
    masks[round_index, scheduled] = True

    acc = None
    for i in order:
        enc, meta = SecureAggClient("k", "site", i).encode(
            models[i], weights[i], scheduled, round_index)
        assert meta["masked"] and meta["mask_round"] == round_index
        acc = _masked_sum(acc, enc)

    state = SecureAggState("k", "site", masks)
    w_total = sum(weights[i] for i in order)
    tdef = jax.tree.structure(models[order[0]])
    got = state.unmask(jax.tree.unflatten(tdef, acc), round_index,
                       set(order), w_total)

    ref_int = None
    for i in order:
        ints = [_fixed_point(x, weights[i])
                for x in jax.tree.leaves(models[i])]
        ref_int = ints if ref_int is None else [a + x for a, x
                                                in zip(ref_int, ints)]
    ref = _decode(ref_int, w_total)
    for g, r in zip(jax.tree.leaves(got), ref):
        assert np.array_equal(g.reshape(-1), r)  # bit-exact
    missing = set(scheduled) - set(order)
    assert state.recovered == [(round_index, d) for d in sorted(missing)]


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 4), st.integers(2, 3), st.integers(0, 20), st.data())
def test_masked_two_tier_pods_equals_flat_mean(pods, per_pod, round_index,
                                               data):
    """Intra-pod masking + pod-tier masking of the partials composes to
    the same global mean as one flat unmasked fold, for arbitrary pod
    sizes and site weights."""
    n = pods * per_pod
    rng = np.random.default_rng(round_index + 1)
    models = [rng.normal(size=(6,)).astype(np.float32) for _ in range(n)]
    weights = [data.draw(st.floats(0.5, 2.0)) for _ in range(n)]
    pod_of = np.repeat(np.arange(pods), per_pod)
    site_masks = np.zeros((round_index + 1, n), bool)
    site_masks[round_index] = True
    pod_masks = np.zeros((round_index + 1, pods), bool)
    pod_masks[round_index] = True

    partials, pod_w = [], []
    for p in range(pods):
        members = [int(i) for i in np.flatnonzero(pod_of == p)]
        acc = None
        for i in members:
            enc, _ = SecureAggClient("k", "site", i).encode(
                {"m": models[i]}, weights[i], members, round_index)
            acc = _masked_sum(acc, enc)
        rows = site_masks & (pod_of == p)[None, :]
        w = sum(weights[i] for i in members)
        part = SecureAggState("k", "site", rows).unmask(
            {"m": acc[0]}, round_index, set(members), w)
        partials.append(part["m"])
        pod_w.append(w)

    acc = None
    for p in range(pods):
        enc, _ = SecureAggClient("k", "pod", p).encode(
            {"m": partials[p]}, pod_w[p], list(range(pods)), round_index)
        acc = _masked_sum(acc, enc)
    glob = SecureAggState("k", "pod", pod_masks).unmask(
        {"m": acc[0]}, round_index, set(range(pods)), sum(pod_w))["m"]

    flat = sum(w * m for w, m in zip(weights, models)) / sum(weights)
    np.testing.assert_allclose(glob, flat, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 6), st.integers(0, 30), st.data())
def test_mid_round_lease_expiry_recovery_property(n, round_index, data):
    """Seed recovery after mid-round expiry, generalized: every site
    masks against the full schedule, an arbitrary nonempty strict
    subset actually folds, and unmask still lands on the survivors'
    exact weighted mean."""
    scheduled = list(range(n))
    folded = sorted(data.draw(
        st.sets(st.integers(0, n - 1), min_size=1, max_size=n - 1)))
    rng = np.random.default_rng(n * 31 + round_index)
    models = [rng.normal(size=(8,)).astype(np.float32) for _ in range(n)]
    weights = [data.draw(st.floats(0.5, 3.0)) for _ in range(n)]
    masks = np.zeros((round_index + 1, n), bool)
    masks[round_index] = True

    acc = None
    for i in folded:
        enc, _ = SecureAggClient("k", "site", i).encode(
            {"m": models[i]}, weights[i], scheduled, round_index)
        acc = _masked_sum(acc, enc)
    state = SecureAggState("k", "site", masks)
    w = sum(weights[i] for i in folded)
    got = state.unmask({"m": acc[0]}, round_index, set(folded), w)["m"]

    expect = sum(weights[i] * models[i] for i in folded) / w
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)
    assert {d for _, d in state.recovered} == set(scheduled) - set(folded)
