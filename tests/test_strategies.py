"""FL strategy semantics: Eq. 1/2/3 math, dropout behavior, hierarchy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederationConfig, MeshConfig
from repro.core import federation as F
from repro.core.aggregation import fedavg_aggregate, hierarchical_aggregate
from repro.core.dcml import contrastive_kl, merge_by_validation
from repro.core.stacking import (broadcast_to_sites, gather_sites,
                                 stack_replicas, weighted_mean)
from repro.core.strategies.fedprox import prox_term
from repro.optim import adamw, sgd


def _toy_ctx(strategy, sites=4, scenario="disconnect", opt=None, **fed_kw):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    def logits_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.stack([pred, -pred], -1), (batch["y"] > 0).astype(jnp.int32)

    fed = FederationConfig(num_sites=sites, strategy=strategy,
                           dropout_scenario=scenario, **fed_kw)
    ctx = F.FLContext(fed=fed, mesh=MeshConfig(sites_per_pod=sites, fsdp=16 // sites),
                      case_weights=jnp.asarray(fed.case_weights()),
                      loss_fn=loss_fn, logits_fn=logits_fn,
                      optimizer=opt or sgd(0.1), grad_clip=0.0, dcml_lr=0.05)
    return ctx


def _init_fn(key):
    return {"w": jax.random.normal(key, (3,))}


def _batches(key, sites, k=1, b=8):
    x = jax.random.normal(key, (sites, k, b, 3))
    y = x @ jnp.array([1.0, -1.0, 0.5])
    return {"x": x, "y": y}


def test_fedavg_aggregation_is_weighted_mean():
    """Eq. 1 exactly: w^{t+1} = Σ m_i/m w_i."""
    params = {"w": jnp.arange(12.0).reshape(4, 3)}
    cw = jnp.array([1.0, 2.0, 3.0, 4.0])
    new, g = fedavg_aggregate(params, cw)
    want = (cw / cw.sum()) @ params["w"]
    np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(want), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.tile(np.asarray(want), (4, 1)), rtol=1e-6)


def test_fedavg_dropout_keeps_local_weights():
    params = {"w": jnp.arange(12.0).reshape(4, 3)}
    cw = jnp.ones(4)
    active = jnp.array([True, False, True, True])
    new, g = fedavg_aggregate(params, cw, active)
    want = np.asarray(params["w"])[[0, 2, 3]].mean(0)
    np.testing.assert_allclose(np.asarray(g["w"]), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new["w"][1]), np.asarray(params["w"][1]))
    np.testing.assert_allclose(np.asarray(new["w"][0]), want, rtol=1e-6)


def test_hierarchical_equals_flat_aggregation():
    """Per-pod then cross-pod weighted means == single weighted mean."""
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 5)),
              "b": jax.random.normal(key, (8,))}
    cw = jnp.asarray(np.random.default_rng(1).uniform(0.5, 2.0, 8), jnp.float32)
    active = jnp.array([True] * 6 + [False, True])
    flat, gf = fedavg_aggregate(params, cw, active)
    hier, gh = hierarchical_aggregate(params, cw, sites_per_pod=4, active=active)
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(hier)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_fedprox_prox_term():
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.0, 0.0])}
    val = prox_term(p, g, mu=0.2)
    np.testing.assert_allclose(float(val), 0.5 * 0.2 * 5.0, rtol=1e-6)


def test_fedavg_all_sites_equal_after_round():
    ctx = _toy_ctx("fedavg")
    state = F.init_fl_state(ctx, _init_fn, jax.random.PRNGKey(0))
    rnd = jax.jit(F.build_fl_round(ctx))
    ri = F.make_round_inputs(ctx)
    state, _ = rnd(state, _batches(jax.random.PRNGKey(1), 4), ri)
    w = np.asarray(state["params"]["w"])
    assert np.allclose(w, w[0])


def test_individual_sites_diverge():
    ctx = _toy_ctx("individual")
    state = F.init_fl_state(ctx, _init_fn, jax.random.PRNGKey(0))
    rnd = jax.jit(F.build_fl_round(ctx))
    for r in range(3):
        ri = F.make_round_inputs(ctx)
        state, _ = rnd(state, _batches(jax.random.PRNGKey(r), 4), ri)
    w = np.asarray(state["params"]["w"])
    assert not np.allclose(w[0], w[1])


def test_fedavg_equals_manual_sgd_average():
    """One round of FedAvg(local_steps=1, SGD) == average of manual per-site
    SGD steps — the literal Eq. 1 composition."""
    ctx = _toy_ctx("fedavg", opt=sgd(0.1))
    state = F.init_fl_state(ctx, _init_fn, jax.random.PRNGKey(0))
    batches = _batches(jax.random.PRNGKey(5), 4)
    w0 = np.asarray(state["params"]["w"][0])
    manual = []
    for i in range(4):
        x, y = np.asarray(batches["x"][i, 0]), np.asarray(batches["y"][i, 0])
        grad = 2 * x.T @ (x @ w0 - y) / len(y)
        manual.append(w0 - 0.1 * grad)
    want = np.mean(manual, axis=0)
    rnd = jax.jit(F.build_fl_round(ctx))
    state, _ = rnd(state, batches, F.make_round_inputs(ctx))
    np.testing.assert_allclose(np.asarray(state["params"]["w"][0]), want,
                               rtol=1e-4, atol=1e-5)


def test_shutdown_freezes_dropped_sites():
    ctx = _toy_ctx("individual", scenario="shutdown")
    state = F.init_fl_state(ctx, _init_fn, jax.random.PRNGKey(0))
    rnd = jax.jit(F.build_fl_round(ctx))
    ri = F.make_round_inputs(ctx)
    ri["active"] = np.array([True, True, False, True])
    before = np.asarray(state["params"]["w"][2])
    state, _ = rnd(state, _batches(jax.random.PRNGKey(2), 4), ri)
    np.testing.assert_allclose(np.asarray(state["params"]["w"][2]), before)
    assert not np.allclose(np.asarray(state["params"]["w"][0]), before)


def test_gcml_receiver_pulls_and_merges():
    ctx = _toy_ctx("gcml", sites=4)
    state = F.init_fl_state(ctx, _init_fn, jax.random.PRNGKey(0))
    rnd = jax.jit(F.build_fl_round(ctx))
    b = _batches(jax.random.PRNGKey(3), 4)
    ri = F.make_round_inputs(ctx, rng=np.random.default_rng(0))
    ri["dcml_batch"] = jax.tree.map(lambda x: x[:, 0], b)
    ri["val_batch"] = jax.tree.map(lambda x: x[:, -1], b)
    state, metrics = rnd(state, b, ri)
    assert "dcml_loss_r" in metrics
    assert np.isfinite(np.asarray(metrics["dcml_loss_r"])).all()


def test_contrastive_kl_sign():
    """Aligning on teacher-correct region decreases, diverging increases."""
    labels = jnp.array([0, 1, 0, 1])
    teacher = jnp.array([[4.0, -4], [-4, 4], [4, -4], [-4, 4]])  # all correct
    student_same = teacher
    student_diff = -teacher
    d_same = contrastive_kl(student_same, teacher, labels)
    d_diff = contrastive_kl(student_diff, teacher, labels)
    assert float(d_same) < float(d_diff)
    teacher_wrong = -teacher                                     # all wrong
    d = contrastive_kl(student_same, teacher_wrong, labels, beta=1.0)
    assert float(d) <= 0.0   # only the diverge term is active


def test_merge_by_validation_prefers_better_model():
    p_good = {"w": jnp.array([1.0])}
    p_bad = {"w": jnp.array([0.0])}
    merged = merge_by_validation(p_good, p_bad, v_r=jnp.array(0.1), v_s=jnp.array(0.9))
    # good model (low val loss 0.1) should dominate: weight = 0.9
    np.testing.assert_allclose(float(merged["w"][0]), 0.9, rtol=1e-6)


def test_gossip_gather_is_permutation():
    params = {"w": jnp.arange(8.0).reshape(4, 2)}
    perm = jnp.array([2, 0, 3, 1])
    out = gather_sites(params, perm)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(params["w"])[[2, 0, 3, 1]])


def test_pooled_single_site():
    ctx = _toy_ctx("pooled", sites=1)
    state = F.init_fl_state(ctx, _init_fn, jax.random.PRNGKey(0))
    rnd = jax.jit(F.build_fl_round(ctx))
    losses = []
    for r in range(10):
        b = _batches(jax.random.PRNGKey(r), 1, b=32)
        state, m = rnd(state, b, F.make_round_inputs(ctx))
        losses.append(float(jnp.mean(m["loss"])))
    assert losses[-1] < losses[0]
