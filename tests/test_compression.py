"""The compression seam: per-codec round-trip error bounds, error-feedback
residual cancellation, the quantized-tensor wire type, server-side delta
decode, and end-to-end convergence of quantized uploads on every
transport (ISSUE 3 tentpole)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.comms import compression as C
from repro.comms.codec import QuantizedTensor, decode_message, encode_message
from repro.comms.coordinator import AggregationServer
from repro.comms.peer import Peer


def _tree(rng, scale=0.01):
    return {"w": (rng.normal(size=(130, 7)) * scale).astype(np.float32),
            "b": {"c": rng.normal(size=(5,)).astype(np.float32)}}


def _max_err(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Codec units
# ---------------------------------------------------------------------------


def test_resolve_codec():
    assert C.resolve_codec(None).name == "none"
    assert C.resolve_codec("int8").name == "int8"
    assert C.resolve_codec("topk-sparse").name == "topk"
    inst = C.Int8Codec(chunk=256)
    assert C.resolve_codec(inst) is inst
    with pytest.raises(KeyError, match="bogus"):
        C.resolve_codec("bogus")


def test_none_codec_is_exact_passthrough():
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    comp = C.UploadCompressor(C.NoneCodec())
    enc, meta = comp.encode(tree)
    assert enc is tree                       # not even a copy
    assert meta == {"compression": "none", "delta": False}
    assert _max_err(C.decode_upload(enc, meta), tree) == 0.0


def test_int8_roundtrip_error_bound():
    """|x − deQ(Q(x))| ≤ scale/2 per chunk, scale = chunk absmax / 127."""
    rng = np.random.default_rng(1)
    x = (rng.normal(size=(3000,)) * rng.uniform(0.01, 10)).astype(np.float32)
    qt = C.Int8Codec(chunk=1024).encode_array(x)
    assert isinstance(qt, QuantizedTensor) and qt.codec == "int8"
    dec = C.decode_array(qt).reshape(-1)
    scales = np.repeat(qt.data["scale"], qt.data["q"].shape[1])[:x.size]
    assert np.all(np.abs(dec - x) <= 0.5 * scales + 1e-7)


def test_fp8_roundtrip_error_bound():
    """e4m3 with absmax→448 scaling: max error ≤ absmax/16 + ulp."""
    rng = np.random.default_rng(2)
    x = (rng.normal(size=(2000,)) * 3.0).astype(np.float32)
    qt = C.Fp8Codec(chunk=1024).encode_array(x)
    dec = C.decode_array(qt).reshape(-1)
    absmax = float(np.max(np.abs(x)))
    assert float(np.max(np.abs(dec - x))) <= absmax / 16 + 1e-6


def test_topk_keeps_largest_entries_exactly():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(500,)).astype(np.float32)
    qt = C.TopKCodec(fraction=0.1).encode_array(x)
    dec = C.decode_array(qt).reshape(-1)
    kept = dec != 0
    assert kept.sum() == 50
    np.testing.assert_array_equal(dec[kept], x[kept])       # exact values
    # the kept set is the magnitude top-k
    assert np.min(np.abs(x[kept])) >= np.max(np.abs(x[~kept]))


def test_small_leaves_do_not_pay_full_chunk_padding():
    qt = C.Int8Codec(chunk=1024).encode_array(np.ones((8,), np.float32))
    assert qt.nbytes <= 8 + 4                # 8 int8 values + one scale


@pytest.mark.parametrize("name,rounds", [("int8", 12), ("fp8", 12),
                                         ("topk", 40)])
def test_error_feedback_telescopes(name, rounds):
    """With EF the sum of everything decoded equals the sum of everything
    encoded minus ONE bounded residual; without EF, a biased input keeps
    the same per-round error and the gap grows linearly with T (for the
    sparsifier the EF residual bound is ~(1−δ)/δ·‖u‖, so more rounds are
    needed before the linear no-EF drift overtakes it)."""
    codec = C.resolve_codec(name)
    rng = np.random.default_rng(4)
    u = {"w": (rng.normal(size=(800,)) * 0.01).astype(np.float32)}
    ref = {"w": np.zeros_like(u["w"])}       # delta stream (u − 0 = u)
    with_ef = C.UploadCompressor(codec, error_feedback=True)
    no_ef = C.UploadCompressor(codec, error_feedback=False)
    sum_ef = np.zeros_like(u["w"])
    sum_no = np.zeros_like(u["w"])
    for _ in range(rounds):                  # constant input = worst bias
        enc, meta = with_ef.encode(u, reference=ref)
        sum_ef += C.decode_upload(enc, meta, reference=ref)["w"]
        enc, meta = no_ef.encode(u, reference=ref)
        sum_no += C.decode_upload(enc, meta, reference=ref)["w"]
    target = rounds * u["w"]
    err_ef = float(np.linalg.norm(sum_ef - target))
    err_no = float(np.linalg.norm(sum_no - target))
    residual = float(np.linalg.norm(with_ef.residual["w"]))
    assert err_ef <= residual + 1e-4         # telescoped to one residual
    assert err_no >= 3 * err_ef              # un-fed-back bias accumulates


def test_quantized_tensor_wire_roundtrip():
    rng = np.random.default_rng(5)
    enc = C.Int8Codec().encode_tree(_tree(rng))
    data = encode_message("upload", {"site": 1, "compression": "int8"}, enc)
    kind, meta, back = decode_message(data, writable=True)
    assert kind == "upload" and meta["compression"] == "int8"
    qt = back["w"]
    assert isinstance(qt, QuantizedTensor)
    assert qt.codec == "int8" and qt.shape == (130, 7)
    np.testing.assert_array_equal(qt.data["q"], enc["w"].data["q"])
    np.testing.assert_array_equal(qt.data["scale"], enc["w"].data["scale"])
    qt.data["q"][:] = 0                      # writable decode


def test_pallas_quantize_kernel_matches_numpy():
    """The Pallas kernel (interpreter on CPU — bit-faithful to the TPU
    program) and the numpy codec path agree exactly (both half-to-even)."""
    from repro.kernels import ops
    rng = np.random.default_rng(6)
    x = (rng.normal(size=(7, 512)) *
         rng.uniform(0.001, 10, size=(7, 1))).astype(np.float32)
    q, s = ops.quantize_int8(jnp.asarray(x))
    ref = C.Int8Codec(use_kernel=False, chunk=512).encode_array(x)
    np.testing.assert_array_equal(np.asarray(q), ref.data["q"].reshape(q.shape))
    np.testing.assert_allclose(np.asarray(s), ref.data["scale"], rtol=1e-7)
    deq = np.asarray(ops.dequantize_int8(q, s))
    np.testing.assert_allclose(
        deq, np.asarray(q, np.float32) * np.asarray(s)[:, None], rtol=1e-7)
    # the kernel-routed codec produces the same wire content
    kt = C.Int8Codec(use_kernel=True, chunk=512).encode_array(x)
    np.testing.assert_array_equal(kt.data["q"], ref.data["q"])


def test_delta_encoding_roundtrip_and_missing_reference():
    rng = np.random.default_rng(7)
    ref = _tree(rng, scale=1.0)
    params = jax.tree.map(lambda x: x + 0.01 * rng.normal(size=x.shape)
                          .astype(np.float32), ref)
    comp = C.UploadCompressor(C.Int8Codec())
    enc, meta = comp.encode(params, reference=ref)
    assert meta["delta"] is True
    dec = C.decode_upload(enc, meta, reference=ref)
    assert _max_err(dec, params) < 1e-3      # delta absmax is small ⇒ fine grid
    with pytest.raises(ValueError, match="reference"):
        C.decode_upload(enc, meta, reference=None)


# ---------------------------------------------------------------------------
# Aggregation server decode (the seam PR 2 built)
# ---------------------------------------------------------------------------


def test_server_decodes_quantized_delta_uploads():
    """Full round 1 (quantized weights), delta round 2 — the decoded
    global matches the mean of the true site models both times."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2)
    peers = [Peer(i) for i in range(2)]
    codec = C.Int8Codec()
    # EF off: this test isolates the server's decode; with EF on, round 2
    # would deliberately re-inject round 1's quantization error
    comps = [C.UploadCompressor(codec, error_feedback=False)
             for _ in range(2)]
    rng = np.random.default_rng(8)
    try:
        models = [_tree(rng, scale=1.0) for _ in range(2)]
        for i, p in enumerate(peers):
            enc, meta = comps[i].encode(models[i], reference=None)
            p.upload(agg.addr, enc, 1, meta_extra={**meta, "base_round": 0})
        g1, meta1 = peers[0].download(agg.addr, 1, with_meta=True)
        want = jax.tree.map(lambda a, b: (a + b) / 2, *models)
        assert _max_err(g1, want) < 2e-2     # full-weights quantization grid
        # round 2: sites drift a little, upload int8 *deltas* vs g1
        g1f = jax.tree.map(lambda x: np.asarray(x, np.float32), g1)
        models = [jax.tree.map(lambda x: x + 0.01 * rng.normal(size=x.shape)
                               .astype(np.float32), g1f) for _ in range(2)]
        for i, p in enumerate(peers):
            enc, meta = comps[i].encode(models[i], reference=g1f)
            assert meta["delta"] is True
            p.upload(agg.addr, enc, 2, meta_extra={**meta, "base_round": 1})
        g2 = peers[0].download(agg.addr, 2)
        want = jax.tree.map(lambda a, b: (a + b) / 2, *models)
        assert _max_err(g2, want) < 2e-4     # delta grid is ~100× finer
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_rejoining_site_recovers_from_evicted_reference():
    """A site that sat out past the keep_globals window cannot upload a
    decodable delta; the sync barrier would wait on it forever.  The
    client-side guard re-sends dense (delta=False) — verify the server
    rejects the undecodable delta and the dense re-send completes the
    round."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=2, keep_globals=1)
    peers = [Peer(i) for i in range(2)]
    codec = C.Int8Codec()
    try:
        w = {"w": np.full(8, 2.0, np.float32)}
        # rounds 1-3: only site 0 active; server prunes old globals
        for r in range(1, 4):
            peers[0].upload(agg.addr, w, r, active_sites=1)
        # site 1 rejoins with a delta anchored to the long-gone round 1
        comp = C.UploadCompressor(codec)
        enc, meta = comp.encode(w, reference={"w": np.zeros(8, np.float32)})
        ack = peers[1].upload(agg.addr, enc, 4, active_sites=2,
                              meta_extra={**meta, "base_round": 1})
        assert ack["stale"] is True          # undecodable — not folded
        # the guard's dense re-send (no reference) IS decodable
        enc, meta = C.UploadCompressor(codec).encode(w, reference=None)
        ack = peers[1].upload(agg.addr, enc, 4, active_sites=2,
                              meta_extra={**meta, "base_round": 0})
        assert ack["stale"] is False
        peers[0].upload(agg.addr, w, 4, active_sites=2)
        g = peers[0].download(agg.addr, 4)   # barrier completes
        np.testing.assert_allclose(g["w"], 2.0, rtol=1e-2)
    finally:
        for p in peers:
            p.close()
        agg.stop()


def test_server_rejects_delta_against_evicted_reference():
    """A delta whose base global left the keep_globals window cannot be
    decoded — the server acks it stale so the site resyncs and re-anchors
    instead of the fold silently corrupting the round."""
    agg = AggregationServer("127.0.0.1", 0, num_sites=1)
    p = Peer(0)
    codec = C.Int8Codec()
    try:
        comp = C.UploadCompressor(codec)
        enc, meta = comp.encode({"w": np.ones(4, np.float32)},
                                reference={"w": np.zeros(4, np.float32)})
        ack = p.upload(agg.addr, enc, 1,
                       meta_extra={**meta, "base_round": 99})
        assert ack["stale"] is True
    finally:
        p.close()
        agg.stop()


# ---------------------------------------------------------------------------
# End to end: convergence and bytes on the wire, per transport
# ---------------------------------------------------------------------------


def _dose_job(**kw):
    base = dict(
        task=TaskConfig(kind="dose", sites=3, batch=2, volume=(16, 16, 16),
                        heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=4, lr=2e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def _token_job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=3, batch=2,
                        seq=16, seed=0),
        strategy="fedavg", rounds=3, lr=5e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def test_compression_none_matches_default_exactly():
    """compression="none" is the identical code path as PR 2 (no codec in
    the loop at all) — bitwise-equal global models."""
    a = _token_job(rounds=2).run()
    b = _token_job(rounds=2, compression="none").run()
    for x, y in zip(jax.tree.leaves(a.global_params),
                    jax.tree.leaves(b.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_int8_ef_converges_on_dose_stacked():
    """Tier-1 acceptance: int8+EF final dose loss within tolerance of the
    uncompressed run, and ≥3× fewer (simulated) upload bytes."""
    none = _dose_job().run()
    int8 = _dose_job(compression="int8").run()
    assert np.isfinite(int8.final_loss)
    assert abs(int8.final_loss - none.final_loss) <= 0.05 * none.final_loss
    assert int8.comm["upload_raw_bytes"] >= 3 * int8.comm["upload_bytes"]


def test_int8_wire_ratio_and_parity_thread():
    """Real TCP wire bytes (thread transport): int8 uploads are ≥3×
    smaller than uncompressed, converge to the same loss, and match the
    stacked simulator's quantized global."""
    none = _token_job(transport="thread").run()
    int8 = _token_job(transport="thread", compression="int8").run()
    assert not int8.comm["simulated"]
    assert none.comm["upload_bytes"] >= 3 * int8.comm["upload_bytes"]
    assert abs(int8.final_loss - none.final_loss) <= 0.05 * none.final_loss
    stacked = _token_job(compression="int8").run()
    for x, y in zip(jax.tree.leaves(stacked.global_params),
                    jax.tree.leaves(int8.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-3, atol=1e-4)


def test_int8_wire_ratio_tcp_dose():
    """One OS process per site over real TCP, dose task: compressed
    uploads cross the wire ≥3× smaller and training stays finite."""
    job = _dose_job(
        task=TaskConfig(kind="dose", sites=2, batch=2, volume=(16, 16, 16),
                        base_filters=16, seed=0),
        rounds=2, transport="tcp")
    none = job.run()
    int8 = job.replace(compression="int8").run()
    assert np.isfinite(int8.final_loss)
    assert none.comm["upload_bytes"] >= 3 * int8.comm["upload_bytes"]
    assert abs(int8.final_loss - none.final_loss) <= 0.05 * none.final_loss


def test_gossip_p2p_compression_thread():
    """Decentralized GCML compresses its sender→receiver pushes too."""
    job = _token_job(task=TaskConfig(kind="tokens", arch="smollm-135m",
                                     sites=4, batch=2, seq=16, seed=0),
                     strategy="gcml", rounds=2, transport="thread",
                     compression="int8")
    res = job.run()
    assert np.isfinite(res.final_loss)
    assert res.comm["compression"] == "int8"
    assert 0 < res.comm["upload_bytes"] < res.comm["upload_raw_bytes"]


def test_buffered_compression_stacked():
    """int8 under the buffered scheduler: version-anchored delta decode
    stays finite and tracks the uncompressed buffered run."""
    from repro.core.session import BufferedScheduler
    sched = BufferedScheduler(buffer_k=2)
    none = _token_job(rounds=4, scheduler=sched).run()
    int8 = _token_job(rounds=4, scheduler=sched, compression="int8").run()
    assert abs(int8.final_loss - none.final_loss) <= 0.05 * none.final_loss
    assert int8.comm["upload_raw_bytes"] >= 3 * int8.comm["upload_bytes"]


def test_stacked_compression_requires_central_strategy():
    """gcml still has no compressed stacked path; fedprox gained one
    (the prox-aware compressed loop/scan — ROADMAP gap closed)."""
    with pytest.raises(ValueError, match="fedavg/fedprox"):
        _token_job(strategy="gcml", compression="int8").run()


def test_job_result_reports_comm():
    res = _token_job(rounds=2).run()
    assert res.comm is not None and res.comm["simulated"] is True
    assert res.to_dict()["comm"]["upload_count"] == res.comm["upload_count"]


# ---------------------------------------------------------------------------
# Downlink compression (PR 10): DownlinkCompressor + decode_download units,
# the bidirectional wire end to end, and the typed-error composition matrix
# ---------------------------------------------------------------------------


def _downlink_run(error_feedback: bool, rounds: int = 30):
    """Drive one site through a moving global; return per-round install
    errors |decoded − true global|."""
    rng = np.random.default_rng(10)
    down = C.DownlinkCompressor(C.Int8Codec(chunk=256),
                                error_feedback=error_feedback)
    g = _tree(rng)
    site_ref = acked = None
    errs = []
    for r in range(1, rounds + 1):
        payload, meta = down.encode(0, g, r, acked_round=acked)
        site_ref = C.decode_download(payload, meta, site_ref)
        acked = r
        errs.append(_max_err(site_ref, g))
        if error_feedback:
            # reference tracking: the server's held copy IS the site's
            # decode, bit for bit — that is what makes EF implicit
            assert _max_err(down.held_state(0)[0], site_ref) == 0.0
        g = jax.tree.map(
            lambda x: x + (rng.normal(size=x.shape) * 0.01
                           ).astype(np.float32), g)
    return errs


def test_downlink_error_feedback_telescopes():
    """held += deQ(Q(delta)) folds each round's quantization error into
    the next delta, so the install error stays at the ONE-step bound
    however long the stream runs (the downlink twin of
    test_error_feedback_telescopes)."""
    errs = _downlink_run(error_feedback=True)
    assert errs[0] == 0.0                      # bootstrap rides dense
    assert max(errs[1:]) < 3e-4                # one-step int8 bound
    # no trend: the late errors look like the early ones
    assert max(errs[-5:]) <= 2.0 * max(errs[1:6])


def test_downlink_without_error_feedback_diverges():
    """held ← g pretends the site decoded exactly, so per-round errors
    random-walk instead of telescoping — kept only to demonstrate why
    reference tracking is load-bearing."""
    ef = _downlink_run(error_feedback=True)
    noef = _downlink_run(error_feedback=False)
    assert noef[-1] > 3.0 * ef[-1]
    assert max(noef) > 3.0 * max(ef[1:])


def test_downlink_dense_on_ack_mismatch():
    """A lost reply (acked_round=None or disagreeing with the server
    record) forces a dense re-sync that restarts the delta stream."""
    rng = np.random.default_rng(11)
    down = C.DownlinkCompressor(C.Int8Codec(chunk=256))
    g = _tree(rng)
    _, m1 = down.encode(0, g, 1, acked_round=None)
    assert m1["delta"] is False and down.dense_sends == 1
    _, m2 = down.encode(0, g, 2, acked_round=1)
    assert m2["delta"] is True
    # site restarted and never acked round 2 -> dense again
    payload, m3 = down.encode(0, g, 3, acked_round=1)
    assert m3["delta"] is False and down.dense_sends == 2
    dec = C.decode_download(payload, m3)       # dense needs no reference
    assert _max_err(dec, g) == 0.0
    # and the dense send reset the reference: the stream resumes
    _, m4 = down.encode(0, g, 4, acked_round=3)
    assert m4["delta"] is True


def test_downlink_evict_forces_dense_bootstrap():
    """Regression for the reference-window bound: a site silent past
    ``keep`` rounds is evicted and its next download bootstraps dense —
    never a KeyError, never a delta against a dropped reference."""
    rng = np.random.default_rng(12)
    down = C.DownlinkCompressor(C.Int8Codec(chunk=256))
    g = _tree(rng)
    down.encode(0, g, 1, acked_round=None)
    down.encode(1, g, 1, acked_round=None)
    keep = C.KEEP_GLOBALS_DEFAULT
    # site 1 keeps downloading; site 0 goes silent
    for r in range(2, keep + 3):
        down.encode(1, g, r, acked_round=r - 1)
        down.evict_stale(r, keep)
    assert down.held_state(0) is None          # evicted
    assert down.held_state(1) is not None      # active site survives
    payload, meta = down.encode(0, g, keep + 3, acked_round=1)
    assert meta["delta"] is False              # dense fallback
    assert _max_err(C.decode_download(payload, meta), g) == 0.0


def test_decode_download_delta_without_reference_raises():
    rng = np.random.default_rng(13)
    down = C.DownlinkCompressor(C.Int8Codec(chunk=256))
    g = _tree(rng)
    down.encode(0, g, 1, acked_round=None)
    payload, meta = down.encode(0, g, 2, acked_round=1)
    assert meta["delta"] is True
    with pytest.raises(ValueError, match="no held global"):
        C.decode_download(payload, meta)


def test_bidirectional_thread_matches_stacked_with_byte_split():
    """int8 BOTH ways: the threaded socket stack and the stacked scan
    engine agree on the model (within wire fold-order noise) and on the
    payload-level byte split exactly."""
    stacked = _token_job(compression="int8", down_compression="int8").run()
    thread = _token_job(compression="int8", down_compression="int8",
                        transport="thread").run()
    for res in (stacked, thread):
        c = res.comm
        assert c["down_compression"] == "int8"
        assert c["download_count"] == c["upload_count"] > 0
    sc, tc = stacked.comm, thread.comm
    assert sc["total_bytes"] == sc["upload_bytes"] + sc["download_bytes"]
    assert tc["total_bytes"] == tc["upload_bytes"] + tc["download_bytes"]
    # payload bytes are transport-invariant (framing overhead is not)
    assert tc["site_payload_bytes"] == sc["upload_bytes"]
    assert tc["download_payload_bytes"] == sc["download_bytes"]
    assert tc["download_raw_bytes"] == sc["download_raw_bytes"]
    # steady-state downloads are deltas: cheaper than their raw fp32
    assert sc["download_bytes"] < sc["download_raw_bytes"]
    for x, y in zip(jax.tree.leaves(stacked.global_params),
                    jax.tree.leaves(thread.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-3, atol=2e-4)


def test_bidirectional_pods_two_hop_install():
    """Under pods:2 BOTH install hops compress (root→leader per-leader
    deltas, pod server→site per-site deltas) and the decoded install
    stays within quantization tolerance of the dense pods run."""
    from repro.core.topology import Topology
    job = _token_job(task=TaskConfig(kind="tokens", arch="smollm-135m",
                                     sites=4, batch=2, seq=16, seed=0),
                     transport="thread", topology=Topology.pods(2))
    dense = job.run()
    bidir = job.replace(compression="int8", down_compression="int8").run()
    c = bidir.comm
    assert c["down_compression"] == "int8" and c["pods"] == 2
    assert c["intra_pod_download_bytes"] < dense.comm["intra_pod_download_bytes"]
    assert c["cross_pod_download_bytes"] < dense.comm["cross_pod_download_bytes"]
    assert np.isfinite(bidir.final_loss)
    for x, y in zip(jax.tree.leaves(dense.global_params),
                    jax.tree.leaves(bidir.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-2, atol=5e-3)


def test_down_only_compression_stacked_matches_loop_bytes():
    """down_compression composes with dense uploads: the scan and loop
    twins agree byte for byte on the asymmetric split."""
    scan = _token_job(down_compression="int8").run()
    loop = _token_job(down_compression="int8", round_engine="loop").run()
    assert scan.comm["compression"] == "none"
    assert scan.comm["download_bytes"] < scan.comm["download_raw_bytes"]
    for k in ("upload_bytes", "download_bytes", "total_bytes",
              "upload_count", "download_count"):
        assert scan.comm[k] == loop.comm[k], k


def test_down_compression_typed_error_matrix():
    """Compositions whose server cannot (or must not) track per-site
    references are typed errors on every transport, never silent dense
    downgrades."""
    from repro.core.session import BufferedScheduler
    base = _token_job(down_compression="int8")
    with pytest.raises(ValueError, match="fedavg/fedprox"):
        base.replace(strategy="gcml").run()
    with pytest.raises(ValueError, match="scheduler='sync'"):
        base.replace(scheduler=BufferedScheduler(buffer_k=2)).run()
    with pytest.raises(ValueError, match="down_compression='none'"):
        base.replace(aggregator="trimmed:1").run()
    with pytest.raises(ValueError, match="down_compression='none'"):
        base.replace(adversary="sign_flip:1").run()
    with pytest.raises(ValueError, match="shard_sites"):
        base.replace(shard_sites=True, sample="uniform:2").run()
    with pytest.raises(ValueError, match="disable one"):
        base.replace(transport="thread", secure_agg=True).run()
