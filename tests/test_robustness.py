"""Byzantine-robust rounds: robust combine rules, the deterministic
adversary harness, server-side upload sanitation, the sync round
deadline, and the corrupt-channel fault path.

The breakdown-point battery runs under hypothesis when available (dev
extra; CI installs it) and falls back to deterministic sweeps when not.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig, _validate_robustness
from repro.core.adversary import AdversaryPlan, parse_adversary
from repro.core.agg_engine import (AggregatorSpec, FEDAVG_SPEC, get_engine,
                                   parse_aggregator, robust_combine_trees,
                                   tree_all_finite, tree_l2_norm)


def _job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=6, batch=2,
                        seq=16, heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=3, lr=1e-3, seed=0, verbose=False)
    base.update(kw)
    return FederatedJob(**base)


def _tree_maxerr(a, b):
    return max(float(np.max(np.abs(np.asarray(x, np.float64)
                                   - np.asarray(y, np.float64))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Spec / plan grammar
# ---------------------------------------------------------------------------


def test_aggregator_grammar():
    assert parse_aggregator(None) is FEDAVG_SPEC
    assert parse_aggregator("fedavg") == FEDAVG_SPEC
    assert parse_aggregator("trimmed:0") is FEDAVG_SPEC   # trims nothing
    s = parse_aggregator("trimmed:2")
    assert (s.name, s.f) == ("trimmed", 2) and s.rank_based and s.robust
    assert parse_aggregator("median").rank_based
    assert parse_aggregator("krum:1").f == 1
    nc = parse_aggregator("normclip:0.5")
    assert (nc.name, nc.c) == ("normclip", 0.5) and not nc.rank_based
    # idempotent + canonical round-trip
    assert parse_aggregator(s) is s
    assert parse_aggregator(s.spec) == s
    for bad in ("trimmed", "krum", "normclip:0", "normclip:-1", "foo",
                "median:2", "trimmed:-1"):
        with pytest.raises(ValueError):
            parse_aggregator(bad)


def test_adversary_grammar():
    assert parse_adversary(None) is None
    assert parse_adversary("none") is None
    p = parse_adversary("sign_flip:2", seed=3)
    assert (p.kind, p.f, p.seed) == ("sign_flip", 2, 3)
    assert p.flips_params and not p.flips_labels
    assert parse_adversary("label_flip:1").flips_labels
    sc = parse_adversary("scale:10:1")
    assert (sc.kind, sc.param, sc.f) == ("scale", 10.0, 1)
    nz = parse_adversary("noise:0.5:2")
    assert (nz.kind, nz.param, nz.f) == ("noise", 0.5, 2)
    assert parse_adversary(p) is p          # idempotent
    for bad in ("sign_flip", "scale:1", "noise:1", "what:1", "sign_flip:0"):
        with pytest.raises(ValueError):
            parse_adversary(bad)


def test_adversary_selection_deterministic():
    p = AdversaryPlan(kind="sign_flip", f=3, seed=7)
    m1 = p.malicious_mask(12)
    m2 = p.malicious_mask(12)
    np.testing.assert_array_equal(m1, m2)
    assert int(m1.sum()) == 3
    assert [p.is_malicious(i, 12) for i in range(12)] == list(m1)
    # different seed, different set (overwhelmingly)
    assert not np.array_equal(m1,
                              AdversaryPlan("sign_flip", 3, seed=8)
                              .malicious_mask(12))


def test_adversary_noise_traced_matches_host():
    """The stacked (vmapped, traced) noise stream and a socket worker's
    host-side stream are the same bits — parity depends on it."""
    p = AdversaryPlan(kind="noise", f=2, param=0.7, seed=5)
    tree = {"w": jnp.ones((4, 3, 2)), "b": jnp.zeros((4, 5))}   # [S=4, ...]
    mask = jnp.asarray(p.malicious_mask(4))
    stacked = p.perturb_stacked(tree, mask, jnp.asarray(2))
    jitted = jax.jit(p.perturb_stacked)(tree, mask, jnp.asarray(2))
    for site in range(4):
        row = jax.tree.map(lambda x, s=site: np.asarray(x[s]), tree)
        host = p.perturb_tree(row, site, 2)
        want = host if mask[site] else row
        for a, j, b in zip(
                jax.tree.leaves(jax.tree.map(
                    lambda x, s=site: np.asarray(x[s]), stacked)),
                jax.tree.leaves(jax.tree.map(
                    lambda x, s=site: np.asarray(x[s]), jitted)),
                jax.tree.leaves(want)):
            np.testing.assert_array_equal(a, b)     # same threefry stream
            # inside jit XLA may fuse x + s·noise into an FMA — the
            # compiled round body is allclose, the stream is identical
            np.testing.assert_allclose(j, b, rtol=1e-6, atol=1e-7)


def test_label_flip_targets():
    p = AdversaryPlan(kind="label_flip", f=1, seed=0)
    b = {"tokens": jnp.arange(6).reshape(1, 2, 3),
         "dose": jnp.ones((1, 2, 2)) * 0.25,
         "volume": jnp.ones((1, 2, 2))}
    out = p.perturb_batch(b)
    np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                  np.asarray(jnp.flip(b["tokens"], axis=-1)))
    np.testing.assert_allclose(np.asarray(out["dose"]), -0.25)
    np.testing.assert_array_equal(np.asarray(out["volume"]), 1.0)  # input


# ---------------------------------------------------------------------------
# Breakdown-point battery on the [S, N] engine seam
# ---------------------------------------------------------------------------


def _honest_envelope_case(s, f, n, seed):
    """f adversarial rows with huge values among s−f honest rows in
    [−1, 1]: the robust combine must land inside the honest envelope."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1.0, 1.0, size=(s, n)).astype(np.float32)
    bad = rng.choice(s, size=f, replace=False)
    x[bad] = rng.uniform(50.0, 100.0, size=(f, n)) * rng.choice(
        [-1.0, 1.0], size=(f, n))
    honest = np.ones(s, bool)
    honest[bad] = False
    return x, honest


@pytest.mark.parametrize("rule", ["trimmed", "median"])
@pytest.mark.parametrize("s,f", [(5, 2), (8, 2), (9, 3), (16, 5)])
def test_breakdown_envelope(rule, s, f):
    """For f < S/2 adversarial rows, trimmed:f / median stay inside the
    coordinate-wise honest min/max envelope — the bounded-influence
    property plain averaging lacks."""
    f = min(f, (s - 1) // 2)
    spec = parse_aggregator("median" if rule == "median" else f"trimmed:{f}")
    eng = get_engine()
    x, honest = _honest_envelope_case(s, f, 64, seed=s * 31 + f)
    out = np.asarray(eng.reduce_robust_flat(
        jnp.asarray(x), jnp.ones(s, bool), spec))
    lo = x[honest].min(axis=0) - 1e-6
    hi = x[honest].max(axis=0) + 1e-6
    assert np.all(out >= lo) and np.all(out <= hi)
    # plain mean is dragged out of the envelope by the same rows
    mean = x.mean(axis=0)
    assert np.any(mean < lo) or np.any(mean > hi)


def test_krum_selects_honest_row():
    s, f, n = 7, 2, 48
    x, honest = _honest_envelope_case(s, f, n, seed=11)
    out = np.asarray(get_engine().reduce_robust_flat(
        jnp.asarray(x), jnp.ones(s, bool), parse_aggregator(f"krum:{f}")))
    assert any(np.array_equal(out, x[i]) for i in np.flatnonzero(honest))


def test_permutation_invariance():
    """Rank rules are symmetric in their inputs: shuffling the site rows
    leaves the combine bit-identical."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(9, 80)).astype(np.float32)
    perm = rng.permutation(9)
    eng = get_engine()
    for spec_str in ("trimmed:2", "median"):
        spec = parse_aggregator(spec_str)
        a = np.asarray(eng.reduce_robust_flat(jnp.asarray(x),
                                              jnp.ones(9, bool), spec))
        b = np.asarray(eng.reduce_robust_flat(jnp.asarray(x[perm]),
                                              jnp.ones(9, bool), spec))
        np.testing.assert_array_equal(a, b)


def test_masked_row_invariance():
    """Masked (dropped-out / unsampled) rows are invisible to the rule,
    whatever garbage they hold — Algorithm-2 composition."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    mask = np.array([1, 1, 0, 1, 1, 0, 1, 1], bool)
    garbage = x.copy()
    garbage[~mask] = 1e30
    eng = get_engine()
    for spec_str in ("trimmed:1", "median", "krum:1"):
        spec = parse_aggregator(spec_str)
        a = np.asarray(eng.reduce_robust_flat(jnp.asarray(x),
                                              jnp.asarray(mask), spec))
        b = np.asarray(eng.reduce_robust_flat(jnp.asarray(garbage),
                                              jnp.asarray(mask), spec))
        np.testing.assert_array_equal(a, b)


def test_trimmed_zero_is_fedavg_spec():
    """``trimmed:0`` parses to THE fedavg spec — bit-exactness with the
    Eq. 1 path is by construction, not numerics."""
    assert parse_aggregator("trimmed:0") is FEDAVG_SPEC
    r0 = _job(aggregator="trimmed:0", rounds=2).run()
    r1 = _job(aggregator="fedavg", rounds=2).run()
    assert _tree_maxerr(r0.global_params, r1.global_params) == 0.0


def test_host_twin_matches_traced():
    """robust_combine_trees (the row-buffered server path) agrees with
    the traced engine rule on the same rows."""
    rng = np.random.default_rng(3)
    s, shapes = 7, {"a": (12,), "b": (3, 5)}
    trees = [{k: rng.normal(size=sh).astype(np.float32)
              for k, sh in shapes.items()} for _ in range(s)]
    flat = jnp.asarray(np.stack(
        [np.concatenate([t[k].ravel() for k in shapes]) for t in trees]))
    eng = get_engine()
    for spec_str in ("trimmed:2", "median"):
        spec = parse_aggregator(spec_str)
        host = robust_combine_trees(trees, spec)
        host_flat = np.concatenate([np.asarray(host[k]).ravel()
                                    for k in shapes])
        traced = np.asarray(eng.reduce_robust_flat(flat, jnp.ones(s, bool),
                                                   spec))
        np.testing.assert_allclose(host_flat, traced, rtol=1e-6, atol=1e-6)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # optional dev extra
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(s=st.integers(3, 12), f=st.integers(1, 5),
           seed=st.integers(0, 10_000),
           spec_str=st.sampled_from(["trimmed", "median"]))
    def test_breakdown_envelope_property(s, f, seed, spec_str):
        f = min(f, (s - 1) // 2)
        spec = parse_aggregator("median" if spec_str == "median"
                                else f"trimmed:{f}")
        x, honest = _honest_envelope_case(s, f, 32, seed)
        out = np.asarray(get_engine().reduce_robust_flat(
            jnp.asarray(x), jnp.ones(s, bool), spec))
        assert np.all(out >= x[honest].min(axis=0) - 1e-5)
        assert np.all(out <= x[honest].max(axis=0) + 1e-5)

    @settings(max_examples=20, deadline=None)
    @given(s=st.integers(2, 10), drop=st.integers(0, 3),
           seed=st.integers(0, 10_000))
    def test_masked_row_invariance_property(s, drop, seed):
        rng = np.random.default_rng(seed)
        drop = min(drop, s - 1)
        x = rng.normal(size=(s, 24)).astype(np.float32)
        mask = np.ones(s, bool)
        mask[rng.choice(s, size=drop, replace=False)] = False
        garbage = x.copy()
        garbage[~mask] = np.inf                # worst case: non-finite
        spec = parse_aggregator("median")
        a = np.asarray(get_engine().reduce_robust_flat(
            jnp.asarray(x), jnp.asarray(mask), spec))
        b = np.asarray(get_engine().reduce_robust_flat(
            jnp.asarray(garbage), jnp.asarray(mask), spec))
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Transport parity under a fixed adversary plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("agg", ["fedavg", "trimmed:1", "median", "krum:1",
                                 "normclip:5.0"])
def test_scan_loop_parity_under_adversary(agg):
    """The compiled scan and the per-round loop replay the same
    adversary and the same robust combine, bit-exactly."""
    j = _job(aggregator=agg, adversary="sign_flip:1", round_engine="auto")
    g_scan = j.run().global_params
    g_loop = j.replace(round_engine="loop").run().global_params
    assert _tree_maxerr(g_scan, g_loop) == 0.0


def test_thread_parity_under_adversary():
    """A real-TCP run under the same plan lands allclose to the stacked
    engine (summation order differs at the server fold)."""
    j = _job(aggregator="trimmed:1", adversary="sign_flip:1", rounds=2)
    g_stacked = j.run().global_params
    g_thread = j.replace(transport="thread").run().global_params
    assert _tree_maxerr(g_stacked, g_thread) < 1e-4


def test_adversary_composes_with_dropout_and_sampling():
    j = _job(aggregator="median", adversary="sign_flip:1", max_dropout=2,
             sample="uniform:4", rounds=3)
    r = j.run()
    assert np.isfinite(r.history[-1]["loss"])
    g_loop = j.replace(round_engine="loop").run().global_params
    assert _tree_maxerr(r.global_params, g_loop) == 0.0


def test_robust_rule_at_pod_tier():
    j = _job(aggregator="trimmed:1", adversary="sign_flip:1",
             topology="pods:2", rounds=2)
    r = j.run()
    assert np.isfinite(r.history[-1]["loss"])
    g_loop = j.replace(round_engine="loop").run().global_params
    assert _tree_maxerr(r.global_params, g_loop) == 0.0


# ---------------------------------------------------------------------------
# Convergence sanity: the acceptance claim in miniature
# ---------------------------------------------------------------------------


def test_trimmed_tracks_clean_while_fedavg_degrades():
    """One noise-injecting site out of 4 visibly poisons plain fedavg
    (the injected N(0, 1) noise dwarfs the ~1e-2-scale weights) while
    trimmed:1 discards the outlier row and tracks the clean reference.

    sign_flip is deliberately NOT used here: on the synthetic tasks it
    shrinks the global toward the zero model, which is near-optimal for
    uniform-ish targets — the noise attack is the one that separates the
    rules quickly.  benchmarks/robust_agg.py covers the full attack grid
    including sign_flip at convergence scale.
    """
    kw = dict(task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4,
                              batch=2, seq=16, heterogeneity=0.3, seed=0),
              rounds=4, local_steps=6, lr=1e-2)
    clean = _job(**kw).run().history[-1]["loss"]
    fedavg = _job(**kw, adversary="noise:1:1").run().history[-1]["loss"]
    trimmed = _job(**kw, adversary="noise:1:1",
                   aggregator="trimmed:1").run().history[-1]["loss"]
    assert fedavg > 1.5 * clean          # measured ~2.1x
    assert abs(trimmed - clean) < 0.1 * clean   # measured ~0.3%


# ---------------------------------------------------------------------------
# Server-side upload sanitation + rejection barrier
# ---------------------------------------------------------------------------


def _mini_server(**kw):
    from repro.comms.coordinator import AggregationServer
    return AggregationServer("127.0.0.1", 0, num_sites=2,
                             case_weights=[1.0, 1.0], **kw)


def test_server_rejects_non_finite_and_proceeds():
    from repro.comms.peer import Peer
    srv = _mini_server()
    try:
        p0, p1 = Peer(0), Peer(1)
        good = {"w": np.ones(4, np.float32)}
        bad = {"w": np.array([1, np.nan, 1, 1], np.float32)}
        ack = p0.upload(srv.addr, bad, 1, active_sites=2)
        assert ack["rejected"] and "non_finite" in ack["reason"]
        ack2 = p1.upload(srv.addr, good, 1, active_sites=2)
        assert not ack2.get("rejected")
        # the rejection shrank the barrier: one honest fold closed it
        g = p1.download(srv.addr, 1)
        np.testing.assert_allclose(np.asarray(g["w"]), 1.0)
        assert srv.rejected_uploads == 1
    finally:
        p0.close(); p1.close(); srv.stop()


def test_server_rejects_norm_outlier():
    from repro.comms.peer import Peer
    srv = _mini_server(max_upload_norm=3.0)
    try:
        p0, p1 = Peer(0), Peer(1)
        ack = p0.upload(srv.addr, {"w": np.full(4, 10.0, np.float32)}, 1,
                        active_sites=2)
        assert ack["rejected"] and "norm_outlier" in ack["reason"]
        ack2 = p1.upload(srv.addr, {"w": np.ones(4, np.float32)}, 1,
                         active_sites=2)
        assert not ack2.get("rejected")
        g = p1.download(srv.addr, 1)
        np.testing.assert_allclose(np.asarray(g["w"]), 1.0)
    finally:
        p0.close(); p1.close(); srv.stop()


def test_all_rejected_round_republishes_and_advances():
    """A round whose every upload is rejected must not deadlock: the
    current global is re-published and the round advances."""
    from repro.comms.peer import Peer
    srv = _mini_server(initial_global={"w": np.zeros(4, np.float32)})
    try:
        p0, p1 = Peer(0), Peer(1)
        bad = {"w": np.full(4, np.nan, np.float32)}
        assert p0.upload(srv.addr, bad, 1, active_sites=2)["rejected"]
        assert p1.upload(srv.addr, bad, 1, active_sites=2)["rejected"]
        g = p0.download(srv.addr, 1)
        np.testing.assert_allclose(np.asarray(g["w"]), 0.0)
        assert srv.rejected_uploads == 2
    finally:
        p0.close(); p1.close(); srv.stop()


def test_rank_server_buffers_rows_and_combines():
    from repro.comms.peer import Peer
    srv = _mini_server(aggregator="median")
    try:
        p0, p1 = Peer(0), Peer(1)
        p0.upload(srv.addr, {"w": np.zeros(4, np.float32)}, 1, active_sites=2)
        p1.upload(srv.addr, {"w": np.full(4, 2.0, np.float32)}, 1,
                  active_sites=2)
        g = p0.download(srv.addr, 1)
        np.testing.assert_allclose(np.asarray(g["w"]), 1.0)   # even-k median
    finally:
        p0.close(); p1.close(); srv.stop()


def test_rank_server_refuses_secure_agg():
    from repro.privacy import SecureAggState
    sa = SecureAggState("s", "site", np.ones((2, 2), bool))
    with pytest.raises(ValueError):
        _mini_server(aggregator="median", secure_agg=sa)


def test_poisoned_global_cascade_contained_by_trimmed():
    """End-to-end: a huge-but-finite scale attack poisons plain fedavg
    (the fold is legal), the poisoned global drives every site
    non-finite, and sanitation rejects the fallout without deadlocking;
    trimmed:1 never folds the attack at all."""
    j = _job(task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4,
                             batch=2, seq=16, seed=0),
             transport="thread", adversary="scale:1e38:1", rounds=3)
    r = j.run()
    assert r.rejected_uploads >= 4            # cascade, but no deadlock
    rr = j.replace(aggregator="trimmed:1").run()
    assert np.isfinite(rr.history[-1]["loss"])
    assert rr.rejected_uploads == 0


# ---------------------------------------------------------------------------
# Round deadline (straggler-tolerant sync barrier)
# ---------------------------------------------------------------------------


def test_round_deadline_proceeds_without_straggler():
    from repro.comms.peer import Peer
    from repro.core.session import SyncScheduler
    srv = _mini_server(scheduler=SyncScheduler(round_deadline_s=0.4))
    try:
        p0, p1 = Peer(0), Peer(1)
        ack = p0.upload(srv.addr, {"w": np.ones(4, np.float32)}, 1,
                        active_sites=2)
        assert not ack.get("stale")
        g = p0.download(srv.addr, 1)           # barrier closes via deadline
        np.testing.assert_allclose(np.asarray(g["w"]), 1.0)
        # the straggler's upload for the closed round is acked stale
        ack2 = p1.upload(srv.addr, {"w": np.zeros(4, np.float32)}, 1,
                         active_sites=2)
        assert ack2.get("stale")
    finally:
        p0.close(); p1.close(); srv.stop()


def test_round_deadline_scheduler_field():
    from repro.core.session import SyncScheduler, resolve_scheduler
    s = SyncScheduler(round_deadline_s=2.0)
    assert s.name == "sync" and s.round_deadline_s == 2.0
    assert resolve_scheduler("sync").round_deadline_s is None


# ---------------------------------------------------------------------------
# Corrupt channel (FlakyChannel corrupt=p + typed decode errors)
# ---------------------------------------------------------------------------


def test_corrupt_channel_self_heals():
    """Seeded byte corruption surfaces as a typed retriable error, not a
    hung barrier: moderate corruption still completes the job."""
    from repro.comms.transport import WireConfig
    j = _job(task=TaskConfig(kind="tokens", arch="smollm-135m", sites=3,
                             batch=2, seq=16, seed=0),
             transport="thread", rounds=2,
             wire=WireConfig(flaky="corrupt=0.05", connect_retries=6))
    r = j.run()
    assert np.isfinite(r.history[-1]["loss"])


def test_corrupt_frame_error_is_typed():
    from repro.comms.transport import (CorruptFrameError, WireError,
                                       _decode_checked)
    assert issubclass(CorruptFrameError, WireError)
    with pytest.raises(CorruptFrameError):
        _decode_checked(b"\x00garbage-that-is-not-a-frame")


def test_total_corruption_fails_loudly():
    """corrupt=1.0 exhausts the retry budget with a ChannelError — the
    failure is a typed error at the caller, never a silent hang."""
    from repro.comms.coordinator import AggregationServer
    from repro.comms.peer import Peer
    from repro.comms.transport import ChannelError, WireConfig
    srv = AggregationServer("127.0.0.1", 0, num_sites=1, case_weights=[1.0])
    try:
        p = Peer(0, wire=WireConfig(flaky="corrupt=1.0", connect_retries=1,
                                    backoff_base=0.01))
        with pytest.raises(ChannelError):
            p.upload(srv.addr, {"w": np.ones(2, np.float32)}, 1,
                     active_sites=1)
        p.close()
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# Typed composition guards
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw,frag", [
    (dict(aggregator="trimmed:3"), "majority"),
    (dict(aggregator="krum:4"), "krum"),
    (dict(aggregator="median", compression="int8"), "compression='none'"),
    (dict(adversary="sign_flip:1", compression="int8"), "compression='none'"),
    (dict(aggregator="median", secure_agg=True), "secure_agg"),
    (dict(max_upload_norm=1.0, secure_agg=True), "ciphertext"),
    (dict(aggregator="median", scheduler="buffered"), "side"),
    (dict(aggregator="median", strategy="gcml"), "central combine"),
    (dict(aggregator="trimmed:1", shard_sites=True), "shard_sites"),
    (dict(adversary="sign_flip:1", shard_sites=True), "shard_sites"),
    (dict(adversary="sign_flip:1", strategy="pooled"), "pooled"),
    (dict(round_deadline_s=1.0, scheduler="buffered"), "barrier"),
])
def test_composition_guards(kw, frag):
    with pytest.raises(ValueError, match=frag):
        _validate_robustness(_job(**kw))


def test_stacked_transport_guards():
    with pytest.raises(ValueError, match="wall-clock"):
        _job(round_deadline_s=5.0).run()
    with pytest.raises(ValueError, match="no server"):
        _job(max_upload_norm=5.0).run()


def test_normclip_allowed_on_gossip():
    """The carve-out: normclip composes with gcml (clip incoming gossip
    deltas) while rank rules do not."""
    _validate_robustness(_job(aggregator="normclip:1.0", strategy="gcml"))
    j = _job(task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4,
                             batch=2, seq=16, seed=0),
             strategy="gcml", aggregator="normclip:0.5", rounds=2)
    assert np.isfinite(j.run().history[-1]["loss"])


# ---------------------------------------------------------------------------
# Host-twin utilities
# ---------------------------------------------------------------------------


def test_tree_finite_and_norm_helpers():
    t = {"a": np.ones(3, np.float32), "n": np.arange(3)}   # int leaf skipped
    assert tree_all_finite(t)
    assert not tree_all_finite({"a": np.array([np.inf], np.float32)})
    assert not tree_all_finite({"a": np.array([np.nan], np.float32)})
    assert abs(tree_l2_norm({"a": np.full(4, 3.0, np.float32)}) - 6.0) < 1e-6
