"""SA-Net (the paper's backbone): shapes, losses, scale attention."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import DoseTaskGenerator, SegTaskGenerator
from repro.models.sanet import (SANetConfig, dose_loss, sanet_apply, sanet_init,
                                scale_attn_apply, segmentation_loss)


def _cfg(task="dose", out=1, cin=3):
    return SANetConfig(in_channels=cin, out_channels=out, base_filters=8,
                       num_levels=3, task=task)


def test_sanet_shapes_and_deep_supervision():
    cfg = _cfg()
    params = sanet_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 16, 3))
    out, ds = sanet_apply(params, x, cfg)
    assert out.shape == (2, 16, 16, 16, 1)
    assert len(ds) == cfg.num_levels - 1          # one head per decoder level
    for o in ds:
        assert o.shape == (2, 16, 16, 16, 1)      # resized to full resolution
        assert np.isfinite(np.asarray(o)).all()


def test_dose_loss_and_grad():
    cfg = _cfg(cin=4)
    params = sanet_init(jax.random.PRNGKey(0), cfg)
    gen = DoseTaskGenerator(volume=(16, 16, 16), num_oars=2, num_sites=2)
    batch = jax.tree.map(jnp.asarray, gen.sample(0, 0, 2))
    loss, grads = jax.value_and_grad(
        lambda p: dose_loss(p, batch, cfg)[0])(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gsum = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gsum > 0


def test_segmentation_loss_and_grad():
    cfg = _cfg(task="segmentation", out=3, cin=2)
    params = sanet_init(jax.random.PRNGKey(0), cfg)
    gen = SegTaskGenerator(volume=(16, 16, 16), in_channels=2, num_classes=3,
                           num_sites=2)
    batch = jax.tree.map(jnp.asarray, gen.sample(0, 0, 2))
    loss, _ = segmentation_loss(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_scale_attention_weights_sum_to_one_over_scales():
    """The softmax is across scales: perturbing one scale's features
    changes the fused output (the block is not a passthrough)."""
    cfg = _cfg()
    params = sanet_init(jax.random.PRNGKey(0), cfg)
    feats = [jax.random.normal(jax.random.PRNGKey(i),
                               (1, 16 // (2 ** i), 16 // (2 ** i), 16 // (2 ** i),
                                cfg.filters(i))) for i in range(cfg.num_levels)]
    out1 = scale_attn_apply(params["scale_attn"][0], feats, cfg, 0)
    feats2 = [feats[0], feats[1] * 2.0] + feats[2:]
    out2 = scale_attn_apply(params["scale_attn"][0], feats2, cfg, 0)
    assert out1.shape == (1, 16, 16, 16, cfg.filters(0))
    assert not np.allclose(np.asarray(out1), np.asarray(out2))


def test_sanet_learns_synthetic_dose():
    """A few SGD steps reduce dose MAE on a fixed batch (learnability)."""
    cfg = SANetConfig(in_channels=4, out_channels=1, base_filters=8,
                      num_levels=2, task="dose")
    params = sanet_init(jax.random.PRNGKey(0), cfg)
    gen = DoseTaskGenerator(volume=(16, 16, 16), num_oars=2, num_sites=1)
    batch = jax.tree.map(jnp.asarray, gen.sample(0, 0, 4))

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: dose_loss(q, batch, cfg)[0])(p)
        p = jax.tree.map(lambda a, b: a - 0.03 * b, p, g)
        return p, loss

    losses = []
    for _ in range(12):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.9, losses
