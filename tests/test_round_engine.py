"""The compiled round engine (ISSUE 4 tentpole): scan↔loop parity across
strategies and codecs, donation safety, chunking invariance, compile-time
accounting, on-device round inputs, and the buffered arrival loop as
device state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import FederatedJob, TaskConfig
from repro.core.round_engine import chunk_plan
from repro.core.session import BufferedScheduler


def _job(**kw):
    base = dict(
        task=TaskConfig(kind="tokens", arch="smollm-135m", sites=4, batch=2,
                        seq=16, heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=3, lr=1e-3, seed=0)
    base.update(kw)
    return FederatedJob(**base)


def _assert_trees_close(a, b, rtol=1e-4, atol=1e-6):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Parity: the scan engine vs the retired per-round loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "gcml"])
def test_scan_matches_loop(strategy):
    """Same seed ⇒ same globals AND same per-round losses, with churn:
    the scan consumes the identical masks/pairings/batches, so fusing K
    rounds into one program must not change the math."""
    job = _job(strategy=strategy, max_dropout=1)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params)
    np.testing.assert_allclose(loop.losses, scan.losses, rtol=1e-4)
    if strategy == "gcml":              # pairing history must match too
        for hl, hs in zip(loop.history, scan.history):
            assert hl["partner"] == hs["partner"]
            assert hl["is_receiver"] == hs["is_receiver"]


@pytest.mark.parametrize("strategy", ["pooled", "individual"])
def test_scan_matches_loop_baselines(strategy):
    job = _job(strategy=strategy, rounds=2)
    loop = job.replace(round_engine="loop").run()
    scan = job.run()                    # auto resolves to the scan engine
    _assert_trees_close(loop.global_params, scan.global_params)


def test_scan_matches_loop_compressed_int8():
    """The on-device codec replicates the wire codec's per-leaf chunk
    layout, so quantized-global parity holds at the same tolerance the
    stacked↔thread test uses — and the simulated byte accounting is
    byte-identical."""
    job = _job(compression="int8", rounds=3)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=2e-3, atol=1e-4)
    assert scan.comm["upload_bytes"] == loop.comm["upload_bytes"]
    assert scan.comm["upload_raw_bytes"] == loop.comm["upload_raw_bytes"]
    assert scan.comm["upload_raw_bytes"] >= 3 * scan.comm["upload_bytes"]
    assert [h["upload_bytes"] for h in scan.history] == \
        [h["upload_bytes"] for h in loop.history]


def test_scan_matches_loop_compressed_fp8():
    """fp8's e4m3 cast can flip near-tie bins between the numpy and XLA
    converters, so parity is behavioral (per-element within one coarse
    fp8 quantization step), not bitwise like int8."""
    job = _job(compression="fp8", rounds=2)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=5e-2, atol=1e-3)


def test_scan_matches_loop_buffered():
    """The traced arrival loop replays the retired loop's order stream,
    discounts and K-of-S finalizations — versions match round for round."""
    job = _job(scheduler=BufferedScheduler(buffer_k=2), rounds=4)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=1e-4, atol=1e-5)
    assert [h["version"] for h in loop.history] == \
        [h["version"] for h in scan.history]
    assert all("step_s" in h for h in scan.history)
    assert all("step_s" in h for h in loop.history)   # satellite fix


def test_scan_matches_loop_buffered_int8():
    """Buffered + quantized deltas: the decode-reference ring lives on
    device; the flat chunk layout differs from the per-leaf wire layout,
    so parity is behavioral (close globals, ≥3× byte ratio)."""
    job = _job(scheduler=BufferedScheduler(buffer_k=2), compression="int8",
               rounds=4)
    loop = job.replace(round_engine="loop").run()
    scan = job.replace(round_engine="scan").run()
    _assert_trees_close(loop.global_params, scan.global_params,
                        rtol=5e-3, atol=5e-4)
    assert scan.comm["upload_count"] == loop.comm["upload_count"]
    assert scan.comm["upload_raw_bytes"] >= 3 * scan.comm["upload_bytes"]


def test_scan_matches_loop_dose_task():
    """Volume tasks have no traced generator — host-generated batches
    still ride the compiled scan, chunk-transferred."""
    job = FederatedJob(
        task=TaskConfig(kind="dose", sites=3, batch=2, volume=(16, 16, 16),
                        heterogeneity=0.3, seed=0),
        strategy="fedavg", rounds=2, seed=0)
    loop = job.replace(round_engine="loop").run()
    scan = job.run()
    _assert_trees_close(loop.global_params, scan.global_params)


# ---------------------------------------------------------------------------
# Chunking, donation, compile accounting
# ---------------------------------------------------------------------------


def test_chunking_invariance():
    """Chunk size is an execution knob, not a semantic one."""
    job = _job(rounds=5)
    ref = job.replace(chunk_rounds=5).run()
    for ck in (1, 2, 3):
        res = job.replace(chunk_rounds=ck).run()
        _assert_trees_close(ref.global_params, res.global_params)
        np.testing.assert_allclose(ref.losses, res.losses, rtol=1e-5)


def test_chunk_plan_alignment():
    assert chunk_plan(20, 8) == [8, 8, 4]
    assert chunk_plan(3, None) == [3]
    assert sum(chunk_plan(100, None)) == 100
    # with checkpointing every 10 rounds, a boundary follows rounds 0/10
    plan = chunk_plan(20, 8, ckpt_every=10)
    ends = np.cumsum(plan)
    assert 1 in ends and 11 in ends and ends[-1] == 20


def test_no_use_after_donate():
    """The carry is donated into every chunk; the returned state must be
    the live one (readable, reusable) even after multiple chunks."""
    job = _job(rounds=4, chunk_rounds=2)
    res = job.run()
    assert res.state is not None
    for leaf in jax.tree.leaves(res.state["params"]):
        assert np.isfinite(np.asarray(leaf)).all()
    # the recorded global equals the state's aggregate (nothing stale)
    from repro.core import federation as F
    ctx = job.context()
    _assert_trees_close(res.global_params, F.global_model(res.state, ctx))


def test_compile_time_reported_separately():
    """Satellite: round 0's step_s no longer absorbs jit compilation —
    on both engines compile_s is reported on the JobResult and step_s
    stays in steady-state range."""
    for engine in ("scan", "loop"):
        res = _job(rounds=3, round_engine=engine).run()
        assert res.compile_s > 0.0
        steps = [h["step_s"] for h in res.history]
        assert max(steps) < res.compile_s      # compile dwarfs a tiny step
        assert res.to_dict()["compile_s"] == res.compile_s


def test_checkpointing_on_scan_engine(tmp_path):
    job = _job(rounds=4, chunk_rounds=4, ckpt_every=2,
               checkpoint_dir=str(tmp_path))
    res = job.run()
    assert np.isfinite(res.final_loss)
    saved = sorted(p.name for p in tmp_path.glob("global_round*.npz"))
    assert saved                        # rounds 0 and 2 materialized
    assert (tmp_path / "manifest.json").exists()


# ---------------------------------------------------------------------------
# On-device round inputs (traced masks / pairings / batches)
# ---------------------------------------------------------------------------


def test_device_data_trains():
    job = _job(rounds=6, lr=5e-3, device_data=True)
    res = job.run()
    assert np.isfinite(res.losses).all()
    assert res.final_loss < res.losses[0]
    assert res.comm["upload_count"] == 6 * 4    # all sites active


def test_device_data_with_churn_and_gossip():
    # odd site count: the traced pairing must leave one site out cleanly
    job = _job(task=TaskConfig(kind="tokens", arch="smollm-135m", sites=5,
                               batch=2, seq=16, heterogeneity=0.3, seed=0),
               strategy="gcml", rounds=4, max_dropout=2, device_data=True)
    res = job.run()
    assert np.isfinite(np.asarray(res.losses)).all()
    for h in res.history:
        assert 3 <= h["active"] <= 5            # S − N_max bound holds
        # receivers always have a distinct partner assigned
        for i, is_r in enumerate(h["is_receiver"]):
            if is_r:
                assert h["partner"][i] != i


def test_device_data_unsupported_combos_raise():
    with pytest.raises(ValueError, match="device_data"):
        _job(device_data=True, compression="int8").run()
    with pytest.raises(ValueError, match="device_data"):
        _job(device_data=True, scheduler=BufferedScheduler(buffer_k=2)).run()
    with pytest.raises(ValueError, match="device_data"):
        FederatedJob(task=TaskConfig(kind="dose", sites=2, batch=1,
                                     volume=(8, 8, 8), base_filters=4,
                                     num_levels=1),
                     rounds=1, device_data=True).run()


@pytest.mark.parametrize("sites", [5, 6])   # odd counts sit one site out
def test_traced_round_inputs_laws(sites):
    """Traced Algorithm-2 churn and gossip pairing respect the host
    invariants: dropout bounded by N_max, pairings are disjoint
    sender/receiver sets among active sites."""
    from repro.core.dropout import availability_step_traced
    from repro.core.gossip import pair_sites_traced
    key = jax.random.PRNGKey(0)
    active = jnp.ones((sites,), bool)
    for r in range(30):
        active = availability_step_traced(jax.random.fold_in(key, r),
                                          active, 2)
        a = np.asarray(active)
        assert sites - 2 <= a.sum() <= sites
    for r in range(10):
        k = jax.random.fold_in(key, 100 + r)
        partner, is_recv, is_send = (np.asarray(x) for x in
                                     pair_sites_traced(k, active))
        a = np.asarray(active)
        assert not (is_recv & is_send).any()
        assert is_recv.sum() == is_send.sum() <= a.sum() // 2
        assert (a[partner[is_recv]]).all()      # senders are active
        assert set(partner[is_recv]) == set(np.flatnonzero(is_send))


# ---------------------------------------------------------------------------
# Engine selection surface
# ---------------------------------------------------------------------------


def test_round_engine_scan_raises_on_unsupported():
    with pytest.raises(ValueError, match="scan"):
        _job(compression="topk-sparse", round_engine="scan").run()


def test_round_engine_unknown_name():
    with pytest.raises(ValueError, match="round_engine"):
        _job(round_engine="bogus").run()


def test_topk_and_wide_staleness_fall_back_to_loop():
    res = _job(compression="topk-sparse", rounds=2).run()
    assert np.isfinite(res.final_loss)
    sched = BufferedScheduler(buffer_k=2, max_staleness=64)
    res = _job(scheduler=sched, compression="int8", rounds=2).run()
    assert np.isfinite(res.final_loss)


def test_train_cli_chunk_rounds_flag():
    from repro.launch.train import make_parser
    args = make_parser().parse_args(["--chunk-rounds", "4"])
    assert args.chunk_rounds == 4
    assert args.round_engine == "auto"
    assert make_parser().parse_args([]).chunk_rounds is None
